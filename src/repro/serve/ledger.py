"""Durable request ledger: write-ahead log + elastic group membership.

Two layers, both serving the same contract — **an accepted request is never
dropped**, now extended across full-fleet crashes:

* :class:`WriteAheadLog` — an append-only, checksummed JSONL log. Every
  record is CRC32-stamped and ``fsync``'d before the append returns, so a
  request is only *acknowledged* once it would survive a power cut. On
  restart :func:`replay` reconstructs the outstanding set; a torn final
  record (the crash landed mid-``write``) is discarded and counted, while a
  corrupt record anywhere else raises :class:`LedgerCorrupt` — silent
  damage in the middle of an intact log is data loss, not a crash artefact.
  A compaction pass (snapshot record + atomic rename) bounds log growth
  from long-running groups and repeated re-routes.

* :class:`GroupLedger` — the shared (thread-safe) request ledger of a
  :class:`~repro.serve.group.ServeGroup`, grown from the PR-1 in-memory
  router log into the **single membership authority**: fault-driven shrink,
  replica join/rejoin and autoscale grow/shrink all propose a new *epoch*
  (member list version) here, and every rank reconfigures by entering the
  highest epoch it observes — exactly one reconfiguration code path. Queued
  work is deterministically re-balanced (``id % n_members`` over the sorted
  member list, the PR-1 re-route rule) whenever the membership widens or
  shrinks, and every submit / route / retirement is mirrored into the WAL
  when one is attached.

Record kinds (all JSON objects with ``seq`` + ``crc`` envelope fields):

``submit``   request payload (prompt, budget, deadline) — written before the
             request is visible to any replica;
``stamp``    arrival time + trace id, written once when a replica first
             accepts the request (so replay preserves latency accounting and
             the causal trace chain across a restart);
``route``    request → rank assignment (initial, re-route, re-balance);
``retire``   full terminal :class:`~repro.serve.queue.Response` payload —
             replay returns answered requests bit-exactly without re-serving;
``epoch``    membership change (epoch number, member list, reason);
``snapshot`` compaction: the live state in one record, everything before it
             superseded.
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

from .queue import Request, Response


class LedgerCorrupt(Exception):
    """A WAL record failed its checksum *before* the final record — the log
    itself is damaged (not a torn tail) and must not be trusted."""


# ------------------------------------------------------------------ records
def _encode(seq: int, record: dict) -> str:
    body = dict(record)
    body["seq"] = seq
    payload = json.dumps(body, sort_keys=True, separators=(",", ":"))
    body["crc"] = zlib.crc32(payload.encode())
    return json.dumps(body, sort_keys=True, separators=(",", ":"))


def _decode(line: str) -> dict:
    body = json.loads(line)
    crc = body.pop("crc")
    payload = json.dumps(body, sort_keys=True, separators=(",", ":"))
    if zlib.crc32(payload.encode()) != crc:
        raise ValueError("crc mismatch")
    return body


def request_record(req: Request) -> dict:
    return {"kind": "submit", "id": req.id, "prompt": list(req.prompt),
            "max_new_tokens": req.max_new_tokens, "deadline": req.deadline}


def request_from(rec: dict, stamp: Optional[dict] = None) -> Request:
    req = Request(id=int(rec["id"]), prompt=tuple(rec["prompt"]),
                  max_new_tokens=int(rec["max_new_tokens"]),
                  deadline=rec.get("deadline"))
    if stamp is not None:
        req.arrival_t = stamp.get("arrival_t")
        req.trace_id = stamp.get("trace_id")
    return req


def response_record(resp: Response) -> dict:
    return {"kind": "retire", "id": resp.id, "status": resp.status,
            "tokens": list(resp.tokens), "latency_s": resp.latency_s,
            "ttft_s": resp.ttft_s, "retries": resp.retries,
            "replica": resp.replica, "detail": resp.detail,
            "trace_id": resp.trace_id}


def response_from(rec: dict) -> Response:
    return Response(id=int(rec["id"]), status=rec["status"],
                    tokens=tuple(rec.get("tokens", ())),
                    latency_s=float(rec.get("latency_s", 0.0)),
                    ttft_s=rec.get("ttft_s"),
                    retries=int(rec.get("retries", 0)),
                    replica=rec.get("replica"),
                    detail=rec.get("detail", ""),
                    trace_id=rec.get("trace_id"))


# ---------------------------------------------------------------------- WAL
class WriteAheadLog:
    """Append-only checksummed JSONL log, fsync'd before acknowledgement."""

    def __init__(self, path: str, *, fsync: bool = True,
                 compact_every: int = 512):
        self.path = path
        self.fsync = bool(fsync)
        self.compact_every = int(compact_every)
        self._lock = threading.Lock()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # reopening an existing log (crash-restart): a torn final record is
        # truncated away here so subsequent appends continue a *valid* log —
        # otherwise the garbage tail would sit mid-file forever and turn a
        # legal crash artefact into fatal corruption at the next replay
        if os.path.exists(path) and os.path.getsize(path):
            records, _, valid_bytes = _scan(path)
            if valid_bytes < os.path.getsize(path):
                with open(path, "r+", encoding="utf-8") as f:
                    f.truncate(valid_bytes)
            self._seq = len(records)
        else:
            self._seq = 0
        self._f = open(path, "a", encoding="utf-8")
        self.appended_since_compact = 0

    def append(self, record: dict) -> None:
        """Durably append one record: the call returns only after the bytes
        are flushed and fsync'd — the WAL's acknowledgement contract."""
        with self._lock:
            self._f.write(_encode(self._seq, record) + "\n")
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
            self._seq += 1
            self.appended_since_compact += 1

    def should_compact(self) -> bool:
        return (self.compact_every > 0
                and self.appended_since_compact >= self.compact_every)

    def rewrite(self, records: Iterable[dict]) -> None:
        """Compaction: atomically replace the log with ``records`` (normally
        one ``snapshot``) via temp file + rename, so a crash mid-compaction
        leaves either the old log or the new one — never a hybrid."""
        with self._lock:
            tmp = self.path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                for i, rec in enumerate(records):
                    f.write(_encode(i, rec) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self._f.close()
            os.replace(tmp, self.path)
            self._f = open(self.path, "a", encoding="utf-8")
            self._seq = _count_records(self.path)
            self.appended_since_compact = 0

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


def _count_records(path: str) -> int:
    with open(path, encoding="utf-8") as f:
        return sum(1 for _ in f)


def _scan(path: str) -> tuple[list[dict], int, int]:
    """Decode a WAL: ``(records, torn, valid_bytes)``.

    ``torn`` counts a truncated/corrupt **final** record (discarded — the
    crash interrupted the write); the same damage earlier raises
    :class:`LedgerCorrupt`. ``valid_bytes`` is the byte length of the valid
    prefix, so a reopening writer can truncate the torn tail away."""
    with open(path, "rb") as f:
        raw_lines = f.read().split(b"\n")
    # ignore a trailing empty segment from the final newline
    if raw_lines and not raw_lines[-1]:
        raw_lines.pop()
    records: list[dict] = []
    valid_bytes = 0
    for i, raw in enumerate(raw_lines):
        line = raw.decode("utf-8", errors="replace").strip()
        try:
            if not line:
                raise ValueError("blank record")
            rec = _decode(line)
            if int(rec.get("seq", -1)) != len(records):
                raise ValueError(
                    f"seq {rec.get('seq')} != expected {len(records)}")
        except (ValueError, KeyError, json.JSONDecodeError) as exc:
            if i == len(raw_lines) - 1:
                return records, 1, valid_bytes
            raise LedgerCorrupt(
                f"{path}: record {i} is corrupt mid-log: {exc}") from exc
        records.append(rec)
        valid_bytes += len(raw) + 1
    return records, 0, valid_bytes


# ------------------------------------------------------------------- replay
@dataclass
class LedgerReplay:
    """Everything :func:`replay` reconstructs from a WAL."""

    requests: dict[int, Request] = field(default_factory=dict)
    responses: dict[int, Response] = field(default_factory=dict)
    routes: dict[int, int] = field(default_factory=dict)   # last known owner
    epoch: int = 0
    members: tuple[int, ...] = ()
    records: int = 0
    torn: int = 0               # truncated/corrupt final records discarded

    def outstanding(self) -> list[Request]:
        """Unanswered accepted requests, id order — the re-submission set."""
        return [self.requests[rid] for rid in sorted(self.requests)
                if rid not in self.responses]


def replay(path: str) -> LedgerReplay:
    """Reconstruct ledger state from a WAL.

    Torn-write recovery: the **final** record may be truncated or
    checksum-corrupt (the crash interrupted the write) — it is discarded and
    counted in ``torn``, never fatal. The same damage anywhere earlier
    raises :class:`LedgerCorrupt`: an fsync'd record that later fails its
    CRC means the log is damaged, and replaying around it would silently
    drop acknowledged requests."""
    out = LedgerReplay()
    submits: dict[int, dict] = {}
    stamps: dict[int, dict] = {}
    decoded, out.torn, _ = _scan(path)
    for rec in decoded:
        kind = rec.get("kind")
        if kind == "submit":
            submits[int(rec["id"])] = rec
        elif kind == "stamp":
            stamps[int(rec["id"])] = rec
        elif kind == "route":
            out.routes[int(rec["id"])] = int(rec["rank"])
        elif kind == "retire":
            out.responses[int(rec["id"])] = response_from(rec)
        elif kind == "epoch":
            out.epoch = int(rec["epoch"])
            out.members = tuple(rec["members"])
        elif kind == "snapshot":
            submits = {int(r["id"]): r for r in rec.get("requests", ())}
            stamps = {int(r["id"]): r for r in rec.get("stamps", ())}
            out.responses = {int(r["id"]): response_from(r)
                             for r in rec.get("responses", ())}
            out.routes = {int(k): int(v)
                          for k, v in rec.get("routes", {}).items()}
            out.epoch = int(rec.get("epoch", 0))
            out.members = tuple(rec.get("members", ()))
        else:
            raise LedgerCorrupt(f"{path}: unknown record kind {kind!r}")
    out.requests = {rid: request_from(rec, stamps.get(rid))
                    for rid, rec in submits.items()}
    out.records = len(decoded)
    return out


# ------------------------------------------------------------- group ledger
class GroupLedger:
    """Shared (thread-safe) request ledger + the group's membership
    authority (see module docstring). Epochs move only forward; every
    membership change — ULFM shrink, join, autoscale — is an epoch proposal,
    and ranks converge on the highest proposed epoch through the health
    exchange."""

    def __init__(self, requests: Sequence[Request], ranks: Sequence[int], *,
                 spares: Sequence[int] = (), wal: Optional[WriteAheadLog] = None,
                 responses: Optional[dict] = None,
                 replayed: Iterable[int] = (),
                 stamped: Iterable[int] = (),
                 epoch0: int = 0, epoch_reason: str = "init",
                 log_submits: bool = True):
        self._lock = threading.Lock()
        self.wal = wal
        self.requests = {r.id: r for r in requests}
        if len(self.requests) != len(requests):
            raise ValueError("duplicate request ids")
        self.responses: dict[int, Response] = dict(responses or {})
        self.replayed = frozenset(replayed)     # ids re-admitted from a WAL
        self.alive = sorted(int(r) for r in ranks)
        self.epoch = int(epoch0)
        self.agreed_epoch = self.epoch          # highest epoch a rank entered
        self._members_by_epoch: dict[int, tuple[int, ...]] = {
            self.epoch: tuple(self.alive)}
        self._epoch_reason: dict[int, str] = {self.epoch: epoch_reason}
        self._entered: set[int] = {self.epoch}
        self.pending: dict[int, deque[Request]] = {
            r: deque() for r in list(self.alive) + [int(s) for s in spares]}
        self.owner: dict[int, int] = {}
        self.rerouted: list[int] = []           # moved by fault re-route
        self.rebalanced: list[int] = []         # moved by epoch re-balance
        self.joined: list[int] = []             # ranks admitted via join
        self.departed: list[int] = []           # ranks that left via autoscale
        self.autoscale_events: list[dict] = []
        self.scale_state = {"hot": 0, "idle": 0, "last_change": -(1 << 30)}
        self._dormant: list[int] = sorted(int(s) for s in spares)
        self._summoned: dict[int, str] = {}     # rank -> reason
        self._pending_joins: set[int] = set()   # scheduled, not yet landed
        self._leaving: Optional[int] = None
        self._stamped: set[int] = set(stamped)
        self.closed = False
        self.crashed = False
        self.state_snapshot: Optional[dict] = None
        if self.wal is not None:
            if log_submits:
                for rid in sorted(self.requests):
                    self.wal.append(request_record(self.requests[rid]))
            self.wal.append({"kind": "epoch", "epoch": self.epoch,
                             "members": list(self.alive),
                             "reason": epoch_reason})
        # initial assignment: round-robin over the sorted member list
        for i, req in enumerate(requests):
            rank = self.alive[i % len(self.alive)]
            self.pending[rank].append(req)
            self.owner[req.id] = rank
            if self.wal is not None:
                self.wal.append({"kind": "route", "id": req.id, "rank": rank})

    # ------------------------------------------------------------ work flow
    def take(self, rank: int, limit: Optional[int] = None) -> list[Request]:
        """Pop up to ``limit`` pending requests assigned to ``rank`` (all of
        them when ``limit`` is None). The elastic serve loop takes lazily —
        bounded by replica capacity — so a widened group finds untaken work
        to re-balance onto the joiner."""
        with self._lock:
            q = self.pending.get(rank)
            if not q:
                return []
            n = len(q) if limit is None else max(0, min(limit, len(q)))
            return [q.popleft() for _ in range(n)]

    def note_stamp(self, req: Request) -> None:
        """Mirror a request's acceptance stamp (arrival time + trace id) into
        the WAL, once — replay then preserves latency accounting and the
        causal trace chain across a restart."""
        if self.wal is None or req.id in self._stamped:
            return
        with self._lock:
            if req.id in self._stamped:
                return
            self._stamped.add(req.id)
            self.wal.append({"kind": "stamp", "id": req.id,
                             "arrival_t": req.arrival_t,
                             "trace_id": req.trace_id})

    def complete(self, resp: Response) -> bool:
        """Retire a request. The WAL record is fsync'd *before* the response
        becomes visible (first terminal answer wins). Returns True when the
        response was newly retired, False for a duplicate — the multihost
        supervisor acks a worker's ``retire`` only on (or after) the durable
        first copy, so a re-routed duplicate never double-counts."""
        with self._lock:
            if resp.id in self.responses:
                return False
            if self.wal is not None:
                self.wal.append(response_record(resp))
            self.responses[resp.id] = resp
            if self.wal is not None and self.wal.should_compact():
                self._compact_locked()
            return True

    def remaining(self) -> int:
        # count ids, don't subtract sizes: a replayed ledger's ``responses``
        # holds pre-crash answers whose ids are not in ``requests``
        with self._lock:
            return sum(1 for rid in self.requests
                       if rid not in self.responses)

    def backlog(self) -> int:
        """Accepted-but-untaken requests — the autoscaler's queue-depth
        signal and the re-balance pool."""
        with self._lock:
            return sum(len(q) for q in self.pending.values())

    # ------------------------------------------------------------ membership
    @property
    def members(self) -> tuple[int, ...]:
        with self._lock:
            return self._members_by_epoch[self.epoch]

    def members_of(self, epoch: int) -> tuple[int, ...]:
        with self._lock:
            return self._members_by_epoch[epoch]

    def reason_of(self, epoch: int) -> str:
        with self._lock:
            return self._epoch_reason.get(epoch, "?")

    def _propose_locked(self, members: Sequence[int], reason: str) -> int:
        members = tuple(sorted(int(m) for m in members))
        if not members:
            raise ValueError("cannot propose an empty membership")
        self.epoch += 1
        self._members_by_epoch[self.epoch] = members
        self._epoch_reason[self.epoch] = reason
        self.alive = list(members)
        if self.wal is not None:
            self.wal.append({"kind": "epoch", "epoch": self.epoch,
                             "members": list(members), "reason": reason})
        return self.epoch

    def on_shrink(self, survivors: Sequence[int]) -> list[tuple]:
        """Fault-driven membership change expressed as a survivor list (the
        value ``Comm.shrink_to_survivors`` hands back)."""
        current = self.members
        return self.on_death(set(current) - set(int(s) for s in survivors))

    def on_death(self, dead: Iterable[int]) -> list[tuple]:
        """Fault-driven membership change (ULFM shrink): drop ``dead`` from
        the current membership and reassign their unanswered requests
        (``id % n_survivors`` over the sorted survivor list). Idempotent: the
        first survivor to observe a given death performs the re-route and
        bumps the epoch; expressed as a death set (not a survivor list) so a
        concurrently proposed join is never mistaken for a failure."""
        with self._lock:
            current = list(self._members_by_epoch[self.epoch])
            dead = {int(d) for d in dead} & set(current)
            if not dead:
                return []
            survivors = [m for m in current if m not in dead]
            self._propose_locked(survivors, "shrink")
            moved = []
            for d in dead:
                self.pending.get(d, deque()).clear()
            for rid, owner in list(self.owner.items()):
                if owner in dead and rid not in self.responses:
                    new = survivors[rid % len(survivors)]
                    self.owner[rid] = new
                    req = self.requests[rid]
                    # the new owner recomputes from scratch: retries consumed
                    # on the dead replica don't count against it (arrival_t is
                    # kept, so latency still spans the recovery)
                    req.retries = 0
                    self.pending[new].append(req)
                    moved.append((rid, owner, new))
                    if self.wal is not None:
                        self.wal.append({"kind": "route", "id": rid,
                                         "rank": new})
            self.rerouted.extend(rid for rid, _, _ in moved)
            return moved

    def request_join(self, rank: int) -> Optional[int]:
        """A warmed-up rank proposes a widened membership. Returns the epoch
        the joiner must enter (the survivors converge on it through the
        health exchange), or None when the group already stopped — a join
        proposed after the final exchange would strand the joiner on a
        collective nobody else will post."""
        with self._lock:
            self._pending_joins.discard(rank)
            if self.closed or self.crashed:
                return None
            members = list(self._members_by_epoch[self.epoch])
            if rank in members:
                return self.epoch
            self._summoned.pop(rank, None)
            self.joined.append(rank)
            return self._propose_locked(members + [rank], "join")

    def depart(self, rank: int) -> int:
        """A drained rank proposes a narrowed membership (autoscale shrink's
        clean-leave half: the victim keeps exchanging until everyone has
        moved past the epoch that excludes it, then goes quiet)."""
        with self._lock:
            members = [m for m in self._members_by_epoch[self.epoch]
                       if m != rank]
            self.departed.append(rank)
            if self._leaving == rank:
                self._leaving = None
            return self._propose_locked(members, "autoscale_shrink")

    def enter_epoch(self, epoch: int) -> list[tuple]:
        """Converge on ``epoch``: the first entrant re-balances every
        untaken request over the epoch's member list (same deterministic
        ``id % n`` rule as the fault re-route) and the rest just observe.
        Returns the (rid, old, new) moves the entrant performed."""
        with self._lock:
            members = self._members_by_epoch[epoch]
            self.agreed_epoch = max(self.agreed_epoch, epoch)
            if epoch in self._entered:
                return []
            self._entered.add(epoch)
            moved = []
            untaken: list[Request] = []
            for q in self.pending.values():
                untaken.extend(q)
                q.clear()
            for req in sorted(untaken, key=lambda r: r.id):
                new = members[req.id % len(members)]
                old = self.owner.get(req.id)
                self.pending[new].append(req)
                self.owner[req.id] = new
                if new != old:
                    moved.append((req.id, old, new))
                    if self.wal is not None:
                        self.wal.append({"kind": "route", "id": req.id,
                                         "rank": new})
            self.rebalanced.extend(rid for rid, _, _ in moved)
            return moved

    # ------------------------------------------------------- spares / summon
    def summon_next(self, reason: str) -> Optional[int]:
        """Wake the lowest dormant spare (join schedule or autoscale grow).

        An operator-*scheduled* summons is a promise: the group defers its
        final close until the joiner lands (or explicitly abandons), so a
        requested regrow cannot silently lose the race against the drain.
        Autoscale summonses carry no such promise — an idle shutdown always
        beats speculative growth."""
        with self._lock:
            if not self._dormant:
                return None
            rank = self._dormant.pop(0)
            self._summoned[rank] = reason
            if reason == "scheduled":
                self._pending_joins.add(rank)
            return rank

    def summoned(self, rank: int) -> Optional[str]:
        with self._lock:
            return self._summoned.get(rank)

    def abandon_join(self, rank: int) -> None:
        """A summoned joiner gave up (fleet stopped mid-transfer, poll
        deadline, …): release the close-deferral promise so the survivors
        are not held open for a joiner that will never arrive."""
        with self._lock:
            self._pending_joins.discard(rank)

    def has_pending_joins(self) -> bool:
        with self._lock:
            return bool(self._pending_joins)

    def request_leave(self, rank: int) -> bool:
        """Mark ``rank`` as the autoscale-shrink victim (one at a time)."""
        with self._lock:
            if self._leaving is not None or rank not in self.alive:
                return False
            self._leaving = rank
            return True

    @property
    def leaving(self) -> Optional[int]:
        with self._lock:
            return self._leaving

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        with self._lock:
            self.closed = True

    def crash(self) -> None:
        with self._lock:
            self.crashed = True

    @property
    def stopped(self) -> bool:
        with self._lock:
            return self.closed or self.crashed

    def publish_state(self, snap: dict) -> None:
        with self._lock:
            self.state_snapshot = snap

    # ------------------------------------------------------------ compaction
    def _compact_locked(self) -> None:
        """Rewrite the WAL as one snapshot record (caller holds the lock)."""
        outstanding = [rid for rid in sorted(self.requests)
                       if rid not in self.responses]
        snap = {
            "kind": "snapshot",
            "epoch": self.epoch,
            "members": list(self._members_by_epoch[self.epoch]),
            "requests": [request_record(self.requests[rid])
                         for rid in outstanding],
            "stamps": [{"kind": "stamp", "id": rid,
                        "arrival_t": self.requests[rid].arrival_t,
                        "trace_id": self.requests[rid].trace_id}
                       for rid in outstanding if rid in self._stamped],
            "routes": {str(rid): self.owner[rid] for rid in outstanding
                       if rid in self.owner},
            "responses": [response_record(r)
                          for _, r in sorted(self.responses.items())],
        }
        self.wal.rewrite([snap])

    def compact(self) -> None:
        with self._lock:
            if self.wal is not None:
                self._compact_locked()
