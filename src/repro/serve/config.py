"""EngineConfig — the one validated construction surface for serving engines.

PRs 1–8 grew the engine surface one keyword at a time: ``window=``,
``overlap=``, ``paged=``/``page_size=``/``page_budget=``/``page_watermark=``,
``speculate=``/``draft_len=``/``draft_layers=``, ``trace=`` — threaded in
parallel through :class:`~repro.serve.replica.Replica`,
:class:`~repro.serve.group.ServeGroup` and the benchmark cells, with the
cross-field rules (speculation needs windows, paging needs windows, …)
re-checked ad hoc at each layer. Adding tensor parallelism (``tp=``) would
have been the eleventh copy of the sprawl, so this dataclass collapses it:

* every *engine-shape* knob lives here, validated once in ``__post_init__``
  (cross-field rules included — a bad combination fails at construction, in
  one place, with one message);
* :meth:`from_flags` subsumes the ``"win=8,spec=1,dlen=3"``-style string
  parsing that benchmarks/CLI entry points used to hand-roll per tool;
* ``Replica(...)``/``ServeGroup(...)`` take ``config=EngineConfig(...)`` —
  the sole construction path. (The PR-9 one-release legacy-kwargs shim has
  been removed; old shape keywords are plain ``TypeError``\\ s now.)

Runtime *wiring* (queues, tracers, shared jitted fns, clocks, injectors)
deliberately stays out: those are per-instance objects, not engine shape, and
an EngineConfig must stay hashable/serialisable so benchmark cells and fuzz
engine kits can be declared as data.

Model-dependent checks (``speculate`` requires
``Model.supports_speculation()``; ``tp`` requires enough devices for the
"model" mesh axis) stay in the Replica, which is the first layer that has the
model/devices in hand — but they are *reached* through exactly one path now.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class EngineConfig:
    """Shape of one serving engine. Frozen, hashable, validated.

    ``tp`` is the tensor-parallel width: ``tp > 1`` shards the decode /
    verify / prefill windows over a ``tp``-way "model" mesh axis (storage
    sharded by :mod:`repro.sharding.rules`, compute replicated after an
    in-program all-gather — DESIGN §3.8), with per-shard error words
    OR-folded across the axis so a fault on any shard latches identically on
    all shards. Requires window mode with overlapped admission (the blocking
    prefill path is not built for TP) and ``tp`` visible devices at
    construction.
    """

    num_slots: int = 4
    max_len: int = 64
    eos_id: Optional[int] = None
    max_request_retries: int = 2
    # ---- decode windows (PR 2/3) --------------------------------------
    window: int = 0
    donate: bool = True
    overlap: bool = True
    prefill_budget: Optional[int] = None
    # ---- paged KV pool (PR 4) -----------------------------------------
    paged: bool = False
    page_size: int = 8
    page_budget: Optional[int] = None
    page_watermark: int = 0
    # ---- speculative windows (PR 5) -----------------------------------
    speculate: bool = False
    draft_len: int = 3
    draft_layers: int = 1
    # ---- tensor parallelism (PR 9) ------------------------------------
    tp: int = 1
    # ---- tracing (PR 6; consumed by ServeGroup — a Replica takes a
    # Tracer object directly) -------------------------------------------
    trace: bool = False
    trace_sample: float = 1.0

    def __post_init__(self):
        if self.num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {self.num_slots}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.max_request_retries < 0:
            raise ValueError("max_request_retries must be >= 0, got "
                             f"{self.max_request_retries}")
        if self.window < 0:
            raise ValueError(f"window must be >= 0, got {self.window}")
        if self.prefill_budget is not None and self.prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1 (or None), got "
                             f"{self.prefill_budget}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.page_budget is not None and self.page_budget < 1:
            raise ValueError("page_budget must be >= 1 (or None), got "
                             f"{self.page_budget}")
        if self.page_watermark < 0:
            raise ValueError("page_watermark must be >= 0, got "
                             f"{self.page_watermark}")
        if self.draft_len < 1:
            raise ValueError(f"draft_len must be >= 1, got {self.draft_len}")
        if self.draft_layers < 1:
            raise ValueError("draft_layers must be >= 1, got "
                             f"{self.draft_layers}")
        if not 0.0 <= self.trace_sample <= 1.0:
            raise ValueError("trace_sample must be in [0, 1], got "
                             f"{self.trace_sample}")
        # cross-field rules — previously scattered through Replica.__init__
        if self.paged and not self.window:
            raise ValueError("paged=True requires window mode (window=K)")
        if self.speculate and not self.window:
            raise ValueError("speculate=True requires window mode (window=K)")
        if self.speculate and not self.overlap:
            raise ValueError(
                "speculate=True requires overlap=True (admission/LFLR must "
                "ride the window: the blocking-prefill patch path assumes a "
                "host-predictable position chain)")
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.tp > 1 and not self.window:
            raise ValueError(
                "tp>1 requires window mode (window=K): the cross-shard "
                "error-word fold lives in the window enumeration")
        if self.tp > 1 and not self.overlap:
            raise ValueError(
                "tp>1 requires overlap=True: admission/LFLR must ride the "
                "sharded windows (the blocking prefill path is single-device)")

    # ------------------------------------------------------------ construction
    @classmethod
    def from_flags(cls, spec: str, **overrides) -> "EngineConfig":
        """Parse ``"win=8,spec=1,dlen=3,tp=2,paged=1,page=16"`` → EngineConfig.

        One parser for every CLI/benchmark entry point (subsumes the
        ``spec/dlen/dlayers`` string parsing the tools used to duplicate).
        Bare keys are boolean shorthand (``"paged,spec"`` ≡
        ``"paged=1,spec=1"``); ``overrides`` are applied on top (a tool's
        fixed ``num_slots`` beats the flag string). Unknown keys raise — a
        typo must not silently configure the default engine.
        """
        bool_fields = {"donate", "overlap", "paged", "speculate", "trace"}
        alias = {
            "win": "window", "window": "window",
            "slots": "num_slots", "num_slots": "num_slots",
            "max_len": "max_len", "eos": "eos_id", "eos_id": "eos_id",
            "retries": "max_request_retries",
            "max_request_retries": "max_request_retries",
            "donate": "donate", "overlap": "overlap",
            "budget": "prefill_budget", "prefill_budget": "prefill_budget",
            "page": "page_size", "page_size": "page_size",
            "paged": "paged", "pages": "page_budget",
            "page_budget": "page_budget",
            "watermark": "page_watermark", "page_watermark": "page_watermark",
            "spec": "speculate", "speculate": "speculate",
            "dlen": "draft_len", "draft_len": "draft_len",
            "dlayers": "draft_layers", "draft_layers": "draft_layers",
            "tp": "tp", "trace": "trace", "trace_sample": "trace_sample",
        }
        kw: dict = {}
        for part in (spec or "").split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            if k not in alias:
                raise ValueError(
                    f"unknown engine flag {k!r} (known: "
                    f"{sorted(set(alias))})")
            field = alias[k]
            if not v:
                if field not in bool_fields and field != "window":
                    raise ValueError(f"engine flag {k!r} needs a value")
                kw[field] = True if field in bool_fields else kw.get(field, 0)
                continue
            if field in bool_fields:
                kw[field] = bool(int(v))
            elif field == "trace_sample":
                kw[field] = float(v)
            else:
                kw[field] = int(v)
            # legacy ``page=16`` meant "paged pool with 16-token pages"
            if k == "page" and int(v) > 0:
                kw["paged"] = True
        kw.update(overrides)
        return cls(**kw)
