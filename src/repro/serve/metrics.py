"""Serving metrics: per-request latency, throughput, fault counters.

Feeds the same :class:`~repro.core.resilient.EventLog` record the training
executor uses, so one post-mortem tool reads both training and serving runs.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.errors import ErrorCode
from ..core.resilient import Event, EventLog
from .queue import OK, Response


@dataclass
class FaultRecord:
    step: int
    code: int
    action: str
    slots: tuple[int, ...] = ()
    t: float = 0.0               # wall clock (metrics clock) of detection


class ServeMetrics:
    """Thread-safe accumulator for one replica (or a whole group)."""

    def __init__(self, clock=time.monotonic):
        self._lock = threading.Lock()
        self.clock = clock
        self.responses: list[Response] = []
        self._resp_t: list[float] = []       # completion wall time per response
                                             # (Response is frozen and carries
                                             # only the latency, so the stamp
                                             # lives here, index-aligned)
        self.faults: list[FaultRecord] = []
        self.decode_steps = 0
        self.prefills = 0
        self.decode_tokens = 0               # all committed tokens (incl. the
        self._t0: Optional[float] = None     # first one, from prefill logits)
        self._t_last: Optional[float] = None
        self.windows = 0                     # decode windows retired
        self.discarded_tokens = 0            # trailing tokens dropped at window
                                             # boundaries (EOS/budget/fault)
        self.prefill_chunks = 0              # prompt chunks fused into windows
        self.prefill_chunk_tokens = 0        # prompt tokens fed via chunks
        self.host_stalls = 0                 # blocking prefills (admission/LFLR
        self.host_stall_s = 0.0              # that froze the dispatch loop)
        self.window_waits = 0                # windows not yet done at retire
                                             # (device-bound, host keeping up)
        self.pages_allocated = 0             # paged KV: pool pages granted
        self.pages_freed = 0                 # paged KV: pool pages reclaimed
        self.page_evictions = 0              # paged KV: lanes preempted +
                                             # requeued under memory pressure
        self.peak_pages_in_use = 0           # paged KV: high-water pool usage
        self.peak_active_slots = 0           # most lanes concurrently serving
                                             # (the paged capacity headline)
        self.draft_tokens = 0                # speculation: tokens proposed by
                                             # the shallow-exit drafter
        self.accepted_draft_tokens = 0       # ... accepted by the full-model
                                             # verify (DRAFT_REJECT lane
                                             # carries the misses in-band)
        self._spec_per_slot: dict[int, list] = {}   # slot -> [drafted, accepted]

    # ------------------------------------------------------------- recording
    def record_step(self, committed_tokens: int) -> None:
        with self._lock:
            self._tick()
            self.decode_steps += 1
            self.decode_tokens += committed_tokens

    def record_window(self, committed_tokens: int, discarded_tokens: int,
                      window: int) -> None:
        """One retired decode window: K deferred device steps, one host sync."""
        with self._lock:
            self._tick()
            self.windows += 1
            self.decode_steps += window
            self.decode_tokens += committed_tokens
            self.discarded_tokens += discarded_tokens

    def record_prefill(self, committed_tokens: int = 1) -> None:
        """A (re-)prefill that committed its first token from prefill logits."""
        with self._lock:
            self._tick()
            self.prefills += 1
            self.decode_tokens += committed_tokens

    def record_chunk(self, tokens_fed: int) -> None:
        """A prompt chunk fused into a decode window (overlapped prefill)."""
        with self._lock:
            self._tick()
            self.prefill_chunks += 1
            self.prefill_chunk_tokens += tokens_fed

    def record_host_stall(self, seconds: float) -> None:
        """Wall time the dispatch loop spent blocked on a synchronous prefill
        — the stall the overlapped engine exists to eliminate."""
        with self._lock:
            self.host_stalls += 1
            self.host_stall_s += max(0.0, seconds)

    def record_window_wait(self) -> None:
        """A window that was still computing when the host came to retire it."""
        with self._lock:
            self.window_waits += 1

    def record_pages(self, *, allocated: int = 0, freed: int = 0,
                     in_use: int = 0) -> None:
        """Paged-KV ledger movement (allocation / reclamation + high-water)."""
        with self._lock:
            self.pages_allocated += allocated
            self.pages_freed += freed
            self.peak_pages_in_use = max(self.peak_pages_in_use, in_use)

    def record_spec(self, drafted: int, accepted: int,
                    per_slot: Optional[dict] = None) -> None:
        """One retired speculative window's draft/verify outcome. ``per_slot``
        maps slot -> (drafted, accepted) so acceptance is attributable per
        lane (a single always-rejecting sequence shows up here, not just as a
        diluted global average)."""
        with self._lock:
            self.draft_tokens += drafted
            self.accepted_draft_tokens += accepted
            for slot, (d, a) in (per_slot or {}).items():
                cell = self._spec_per_slot.setdefault(slot, [0, 0])
                cell[0] += d
                cell[1] += a

    def record_page_eviction(self) -> None:
        """A lane preempted (and requeued) to free pages under pressure."""
        with self._lock:
            self.page_evictions += 1

    def record_active_slots(self, n: int) -> None:
        """Concurrent-lane gauge; the peak is the paged capacity headline."""
        with self._lock:
            self.peak_active_slots = max(self.peak_active_slots, n)

    def _tick(self) -> None:
        now = self.clock()
        if self._t0 is None:
            self._t0 = now
        self._t_last = now

    def record_response(self, resp: Response) -> None:
        with self._lock:
            self.responses.append(resp)
            self._resp_t.append(self.clock())

    def record_fault(self, step: int, code: int | ErrorCode, action: str,
                     slots: tuple[int, ...] = ()) -> None:
        with self._lock:
            self.faults.append(FaultRecord(step, int(code), action, slots,
                                           t=self.clock()))

    # --------------------------------------------------------------- queries
    def by_status(self) -> dict[str, int]:
        with self._lock:
            out: dict[str, int] = {}
            for r in self.responses:
                out[r.status] = out.get(r.status, 0) + 1
            return out

    def fault_counts(self) -> dict[str, int]:
        """Faults keyed by ErrorCode class name (a combined word may count
        several classes)."""
        with self._lock:
            out: dict[str, int] = {}
            for f in self.faults:
                for cls in ErrorCode(f.code).classes() or [ErrorCode.OK]:
                    out[cls.name] = out.get(cls.name, 0) + 1
            return out

    def tokens_per_s(self) -> float:
        """Committed tokens per wall second. Already speculation-adjusted:
        only tokens the verify accepted and the scheduler committed count —
        drafted-but-rejected work never inflates throughput."""
        with self._lock:
            if self._t0 is None or self._t_last is None or self._t_last <= self._t0:
                return 0.0
            return self.decode_tokens / (self._t_last - self._t0)

    def tokens_per_step(self) -> float:
        """Committed tokens per *dispatched* decode step (a window counts K
        steps; each step serves every slot, so multi-slot batching alone
        yields up to ``num_slots``). The speculation headline is therefore a
        same-slot-count comparison: draft-and-verify lifts this ratio above
        the plain engine's on identical traffic."""
        with self._lock:
            if not self.decode_steps:
                return 0.0
            return self.decode_tokens / self.decode_steps

    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the full-model verify accepted."""
        with self._lock:
            if not self.draft_tokens:
                return 0.0
            return self.accepted_draft_tokens / self.draft_tokens

    def acceptance_rate_per_slot(self) -> dict[int, float]:
        with self._lock:
            return {slot: (a / d if d else 0.0)
                    for slot, (d, a) in sorted(self._spec_per_slot.items())}

    def latency_percentiles(self, ps=(50, 99)) -> dict[str, float]:
        with self._lock:
            lats = [r.latency_s for r in self.responses if r.status == OK]
        if not lats:
            return {f"p{p}": float("nan") for p in ps}
        arr = np.asarray(lats)
        return {f"p{p}": float(np.percentile(arr, p)) for p in ps}

    def ttft_percentiles(self, ps=(50, 99)) -> dict[str, float]:
        """Time-to-first-token percentiles over answered requests (the number
        overlapped admission optimises: the first token of a late-admitted
        request must not wait for a blocking full-prompt prefill)."""
        with self._lock:
            tt = [r.ttft_s for r in self.responses
                  if r.status == OK and r.ttft_s is not None]
        if not tt:
            return {f"p{p}": float("nan") for p in ps}
        arr = np.asarray(tt)
        return {f"p{p}": float(np.percentile(arr, p)) for p in ps}

    def summary(self) -> dict:
        out = {
            "requests": len(self.responses),
            "statuses": self.by_status(),
            "decode_steps": self.decode_steps,
            "prefills": self.prefills,
            "decode_tokens": self.decode_tokens,
            "windows": self.windows,
            "discarded_tokens": self.discarded_tokens,
            "prefill_chunks": self.prefill_chunks,
            "prefill_chunk_tokens": self.prefill_chunk_tokens,
            "host_stalls": self.host_stalls,
            "host_stall_s": self.host_stall_s,
            "window_waits": self.window_waits,
            "pages_allocated": self.pages_allocated,
            "pages_freed": self.pages_freed,
            "page_evictions": self.page_evictions,
            "peak_pages_in_use": self.peak_pages_in_use,
            "peak_active_slots": self.peak_active_slots,
            "draft_tokens": self.draft_tokens,
            "accepted_draft_tokens": self.accepted_draft_tokens,
            "rejected_draft_tokens": (self.draft_tokens
                                      - self.accepted_draft_tokens),
            "acceptance_rate": self.acceptance_rate(),
            "acceptance_rate_per_slot": self.acceptance_rate_per_slot(),
            "tokens_per_step": self.tokens_per_step(),
            "tokens_per_s": self.tokens_per_s(),
            "faults": self.fault_counts(),
            "retries": sum(r.retries for r in self.responses),
        }
        out.update({f"latency_{k}_s": v
                    for k, v in self.latency_percentiles().items()})
        out.update({f"ttft_{k}_s": v
                    for k, v in self.ttft_percentiles().items()})
        return out

    # --------------------------------------------------------------- export
    def to_event_log(self) -> EventLog:
        """EventLog-style record: requests as ok/fault events, faults with the
        recovery action taken — same shape the training executor emits.

        Every event carries its real wall-clock stamp ``t`` (the metrics
        clock): a fault's detection time, a response's completion time (its
        span starts ``latency_s`` earlier, at the request's arrival). The
        merged log is emitted in wall order so interleaving several logs —
        training + serving, or one per replica — sorts causally; a request's
        ``step`` is its dispatch position, the engine step a fault names."""
        log = EventLog()
        with self._lock:
            entries = [(f.t, Event(step=f.step, kind="fault", code=f.code,
                                   action=f.action,
                                   detail=f"slots={list(f.slots)}", t=f.t))
                       for f in self.faults]
            resp_order = sorted(zip(self._resp_t, self.responses),
                                key=lambda p: p[0])
            entries += [(t, Event(step=i,
                                  kind="ok" if r.status == OK else "fault",
                                  detail=f"request {r.id}: {r.status}",
                                  duration_s=r.latency_s, t=t))
                        for i, (t, r) in enumerate(resp_order)]
        for _, ev in sorted(entries, key=lambda p: p[0]):
            log.add(ev)
        return log

    # ---------------------------------------------------------------- merging
    @classmethod
    def merged(cls, parts: "list[ServeMetrics]") -> "ServeMetrics":
        """One accumulator equivalent to the union of ``parts`` (e.g. a
        ServeGroup's per-replica metrics): counters sum, peaks take the max,
        responses and faults pool (so percentiles are computed over the whole
        fleet's population, not averaged per replica), and the wall window
        spans min ``t0`` to max ``t_last`` — fleet tokens/s is total tokens
        over the fleet's wall span, replicas being concurrent."""
        out = cls()
        for m in parts:
            with m._lock:
                out.responses.extend(m.responses)
                out._resp_t.extend(m._resp_t)
                out.faults.extend(m.faults)
                out.decode_steps += m.decode_steps
                out.prefills += m.prefills
                out.decode_tokens += m.decode_tokens
                out.windows += m.windows
                out.discarded_tokens += m.discarded_tokens
                out.prefill_chunks += m.prefill_chunks
                out.prefill_chunk_tokens += m.prefill_chunk_tokens
                out.host_stalls += m.host_stalls
                out.host_stall_s += m.host_stall_s
                out.window_waits += m.window_waits
                out.pages_allocated += m.pages_allocated
                out.pages_freed += m.pages_freed
                out.page_evictions += m.page_evictions
                out.peak_pages_in_use = max(out.peak_pages_in_use,
                                            m.peak_pages_in_use)
                out.peak_active_slots = max(out.peak_active_slots,
                                            m.peak_active_slots)
                out.draft_tokens += m.draft_tokens
                out.accepted_draft_tokens += m.accepted_draft_tokens
                for slot, (d, a) in m._spec_per_slot.items():
                    cell = out._spec_per_slot.setdefault(slot, [0, 0])
                    cell[0] += d
                    cell[1] += a
                if m._t0 is not None:
                    out._t0 = (m._t0 if out._t0 is None
                               else min(out._t0, m._t0))
                if m._t_last is not None:
                    out._t_last = (m._t_last if out._t_last is None
                                   else max(out._t_last, m._t_last))
        return out
