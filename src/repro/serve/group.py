"""ServeGroup: N replicas on the thread-rank transport, ULFM fault handling.

Each rank thread owns one :class:`~repro.serve.replica.Replica` and serves its
share of the request ledger. Every round the ranks exchange health + remaining
load through a fault-aware ``Comm.all_reduce`` — the same choke point the
paper routes everything through: the wait either returns the reduction or
raises the unified exceptions.

Hard fault choreography (the acceptance scenario of ISSUE 1):

1. a replica dies (``Transport.kill`` / ``ctx.die`` — simulated node loss);
2. survivors' next health exchange fails; the ULFM protocol revokes, agrees,
   and every survivor raises ``CommCorruptedError`` — *no deadlock*: nobody
   waits on the dead rank;
3. survivors ``shrink_to_survivors`` and re-route: the ledger deterministically
   reassigns the dead rank's unanswered requests across survivors
   (``id % n_survivors`` over the sorted survivor list — no extra communication
   needed, in the spirit of non-collective communicator reparation
   [arXiv 2209.01849]), and serving continues without a global restart
   [arXiv 2212.08755];
4. re-routed requests are recomputed from their prompts on the new owner —
   accepted requests are *answered*, never dropped.

Soft faults stay replica-local (per-sequence LFLR inside ``Replica``); the
group only learns about them through metrics.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax

from ..core import CommCorruptedError, PropagatedError, initialize, run_ranks
from ..core.faults import FaultSchedule
from ..core.transport import RankResult
from ..launch.steps import (
    make_cache_prefill,
    make_decode_window,
    make_prefill_decode_window,
    make_slot_decode_step,
    make_speculative_decode_window,
)
from ..models import build_model
from ..obs.trace import NULL_TRACER, Tracer, merge_traces
from .metrics import ServeMetrics
from .queue import AdmissionPolicy, Request, RequestQueue, Response
from .replica import SERVE_PROBES, Replica


class _Ledger:
    """Shared (thread-safe) request ledger: assignment, completion, re-route.

    This plays the role of the front-end router's durable request log — the
    piece a production deployment keeps outside the serving fleet so that a
    replica loss can never lose an accepted request.
    """

    def __init__(self, requests: Sequence[Request], ranks: Sequence[int]):
        self._lock = threading.Lock()
        self.requests = {r.id: r for r in requests}
        if len(self.requests) != len(requests):
            raise ValueError("duplicate request ids")
        self.alive = sorted(ranks)
        self.pending: dict[int, deque[Request]] = {r: deque() for r in ranks}
        self.owner: dict[int, int] = {}
        self.responses: dict[int, Response] = {}
        self.rerouted: list[int] = []
        for i, req in enumerate(requests):
            rank = self.alive[i % len(self.alive)]
            self.pending[rank].append(req)
            self.owner[req.id] = rank

    def take(self, rank: int) -> list[Request]:
        with self._lock:
            q = self.pending.get(rank)
            out = list(q) if q else []
            if q:
                q.clear()
            return out

    def complete(self, resp: Response) -> None:
        with self._lock:
            # first terminal answer wins (re-routes cannot produce duplicates,
            # but keep the invariant explicit)
            self.responses.setdefault(resp.id, resp)

    def remaining(self) -> int:
        with self._lock:
            return len(self.requests) - len(self.responses)

    def on_shrink(self, survivors: Sequence[int]) -> list[int]:
        """Reassign unanswered requests owned by dead ranks. Idempotent: the
        first survivor to observe a given membership performs the re-route."""
        with self._lock:
            survivors = sorted(survivors)
            if survivors == self.alive:
                return []
            dead = set(self.alive) - set(survivors)
            self.alive = survivors
            moved = []
            for d in dead:
                self.pending.get(d, deque()).clear()
            for rid, owner in list(self.owner.items()):
                if owner in dead and rid not in self.responses:
                    new = survivors[rid % len(survivors)]
                    self.owner[rid] = new
                    req = self.requests[rid]
                    # the new owner recomputes from scratch: retries consumed
                    # on the dead replica don't count against it (arrival_t is
                    # kept, so latency still spans the recovery)
                    req.retries = 0
                    self.pending[new].append(req)
                    moved.append((rid, owner, new))
            self.rerouted.extend(rid for rid, _, _ in moved)
            return moved


@dataclass
class RankReport:
    rank: int
    rounds: int = 0
    events: list = field(default_factory=list)   # ("shrink"|"propagated", round, info)
    metrics: Optional[ServeMetrics] = None


@dataclass
class GroupResult:
    responses: dict[int, Response]
    reports: list[RankResult]                    # raw per-rank harness results
    rerouted: tuple[int, ...] = ()
    tracers: dict[int, Tracer] = field(default_factory=dict)

    @property
    def ok(self) -> dict[int, Response]:
        return {i: r for i, r in self.responses.items() if r.ok}

    def report(self, rank: int) -> Optional[RankReport]:
        rr = self.reports[rank]
        return rr.value if rr.exception is None and not rr.killed else None

    def merged_metrics(self) -> ServeMetrics:
        """Survivor replicas' metrics pooled into one accumulator (sums,
        max-of-peaks, pooled response populations for percentiles)."""
        parts = [rr.value.metrics for rr in self.reports
                 if rr.exception is None and not rr.killed
                 and rr.value is not None and rr.value.metrics is not None]
        return ServeMetrics.merged(parts)

    def summary(self) -> dict:
        """One fleet-level dict: the merged per-replica metrics plus the
        group's own story (replica count, survivors, re-routes)."""
        out = self.merged_metrics().summary()
        out["replicas"] = len(self.reports)
        out["survivors"] = sum(1 for rr in self.reports
                               if rr.exception is None and not rr.killed)
        out["rerouted"] = len(self.rerouted)
        return out

    def trace(self) -> dict:
        """All ranks' tracers (dead ones included — their spans are the cause
        half of the kill → shrink → re-route chain) merged into one
        trace_event object."""
        return merge_traces(*(self.tracers[r] for r in sorted(self.tracers)))


class ServeGroup:
    """A fleet of serving replicas over the simulated multi-rank runtime."""

    def __init__(self, cfg, nranks: int, *, num_slots: int = 2,
                 max_len: int = 64, seed: int = 0, probe_cfg=SERVE_PROBES,
                 max_request_retries: int = 2, eos_id: Optional[int] = None,
                 timeout: float = 30.0, window: int = 0, donate: bool = True,
                 overlap: bool = True,
                 prefill_budget: Optional[int] = None,
                 paged: bool = False, page_size: int = 8,
                 page_budget: Optional[int] = None,
                 page_watermark: int = 0,
                 speculate: bool = False, draft_len: int = 3,
                 draft_layers: int = 1,
                 trace: bool = False, trace_sample: float = 1.0):
        if nranks < 2:
            raise ValueError("a ServeGroup needs >= 2 replicas")
        if paged and not window:
            # fail here, not as N concurrent thread deaths inside serve()
            raise ValueError("paged=True requires window mode (window=K)")
        if speculate and not (window and overlap):
            raise ValueError(
                "speculate=True requires window mode with overlap=True")
        self.cfg = cfg
        self.nranks = nranks
        self.num_slots = num_slots
        self.max_len = max_len
        self.timeout = timeout
        self.max_request_retries = max_request_retries
        self.eos_id = eos_id
        self.window = int(window)
        self.overlap = bool(self.window) and bool(overlap)
        self.prefill_budget = prefill_budget
        self.paged = bool(paged)
        self.page_size = page_size
        self.page_budget = page_budget
        self.page_watermark = page_watermark
        self.speculate = bool(speculate)
        self.draft_len = int(draft_len)
        self.draft_layers = int(draft_layers)
        self.trace = bool(trace)
        self.trace_sample = float(trace_sample)
        self.params = build_model(cfg).init(jax.random.PRNGKey(seed))
        # compile once, share across rank threads (jit dispatch is thread-safe)
        # — each paged replica owns its own pool + table, but the layout (and
        # therefore every jitted program) is identical across the fleet
        if self.paged:
            from ..launch.paging import PagedLayout
            model = build_model(cfg)
            num_pages = (int(page_budget) if page_budget is not None
                         else num_slots * (max_len // page_size))
            self._layout = PagedLayout(model.init_cache(1, max_len), max_len,
                                       page_size=page_size,
                                       num_pages=num_pages)
        else:
            self._layout = None
        self._decode_fn = jax.jit(make_slot_decode_step(cfg, probe_cfg))
        self._prefill_fn = make_cache_prefill(cfg, probe_cfg,
                                              fused=bool(self.window),
                                              paged=self._layout,
                                              donate=bool(self.paged and donate))
        if not self.window:
            self._window_fn = None
        elif self.speculate:
            self._window_fn = make_speculative_decode_window(
                cfg, probe_cfg, window=self.window, draft_len=self.draft_len,
                draft_layers=self.draft_layers, donate=donate,
                paged=self._layout)
        elif self.overlap:
            self._window_fn = make_prefill_decode_window(
                cfg, probe_cfg, window=self.window, donate=donate,
                paged=self._layout)
        else:
            self._window_fn = make_decode_window(
                cfg, probe_cfg, window=self.window, donate=donate,
                paged=self._layout)

    def serve(self, requests: Sequence[Request], *,
              faults: FaultSchedule | None = None,
              max_rounds: int = 10_000) -> GroupResult:
        """Serve ``requests`` to completion across the group.

        ``faults`` uses :class:`FaultSpec` with ``step`` meaning the serving
        *round*: ``kind="kill"`` hard-kills a replica at the top of that round;
        ``kind="state_nan"`` flips a bit in one of its active sequences.
        Returns once every request has a terminal response on the survivors.

        The schedule is fully seeded: wildcard specs (``rank=None``) are
        resolved to concrete ranks up front via the schedule's own seed, and
        the slot a ``state_nan`` poisons is drawn from a per-(rank, round)
        generator derived from the same seed — so a fuzzer trajectory that
        kills "some" replica replays bit-for-bit from ``(specs, seed)``.
        """
        faults = (faults or FaultSchedule()).resolve(range(self.nranks))
        ledger = _Ledger(requests, list(range(self.nranks)))

        # a request that could never fit a replica's page pool must be
        # REJECTED at submit (same clamp Replica applies to its own queue)
        pool_cap = (self._layout.capacity_tokens
                    if self.paged and self._layout.has_paged_leaves
                    else self.max_len)

        tracers: dict[int, Tracer] = {}

        def rank_fn(ctx):
            inst = initialize(ctx, default_timeout=self.timeout)
            comm = inst.comm_world()
            if self.trace:
                tracer = Tracer(pid=ctx.rank, sample=self.trace_sample)
                # registered up front so a killed rank's spans survive it —
                # they are the *cause* half of the kill → shrink → re-route
                # chain the merged trace must show
                tracers[ctx.rank] = tracer
            else:
                tracer = NULL_TRACER
            queue = RequestQueue(AdmissionPolicy(
                max_queue=10_000, max_total_len=pool_cap), tracer=tracer)
            replica = Replica(
                self.cfg, params=self.params, num_slots=self.num_slots,
                max_len=self.max_len, queue=queue, rank=ctx.rank,
                max_request_retries=self.max_request_retries,
                eos_id=self.eos_id,
                decode_fn=self._decode_fn, prefill_fn=self._prefill_fn,
                window=self.window, window_fn=self._window_fn,
                overlap=self.overlap, prefill_budget=self.prefill_budget,
                paged=self.paged, page_size=self.page_size,
                page_budget=self.page_budget,
                page_watermark=self.page_watermark,
                paged_layout=self._layout,
                speculate=self.speculate, draft_len=self.draft_len,
                draft_layers=self.draft_layers)
            report = RankReport(rank=ctx.rank, metrics=replica.metrics)
            for round_i in range(max_rounds):
                for spec in faults.at(round_i, ctx.rank):
                    if spec.kind == "kill":
                        if tracer.enabled:
                            tracer.instant("replica_kill", "group",
                                           rank=ctx.rank, round=round_i)
                        ctx.die()                       # never returns
                    elif spec.kind == "state_nan":
                        slot = replica.inject_state_fault(
                            rng=faults.rng_for(ctx.rank, round_i))
                        if slot is not None:
                            report.events.append(("inject", round_i, slot))
                for req in ledger.take(ctx.rank):
                    rej = replica.submit(req)
                    if rej is not None:
                        ledger.complete(rej)
                for resp in replica.step():
                    ledger.complete(resp)
                report.rounds = round_i + 1
                # fault-aware health/termination exchange: the one wait that
                # either agrees on progress or raises the paper's exceptions
                try:
                    rem = comm.all_reduce(ledger.remaining(), op="max").wait()
                    if rem == 0:
                        break
                except PropagatedError as exc:
                    report.events.append(
                        ("propagated", round_i,
                         [e.rank for e in exc.errors]))
                    continue
                except CommCorruptedError:
                    comm.shrink_to_survivors()
                    survivors = list(comm.context.members)
                    moved = ledger.on_shrink(survivors)
                    if tracer.enabled:
                        tracer.instant("ulfm_shrink", "group", rank=ctx.rank,
                                       round=round_i,
                                       survivors=sorted(survivors))
                        for rid, old, new in moved:
                            tracer.instant(
                                "reroute", "group",
                                trace_id=ledger.requests[rid].trace_id,
                                request=rid, from_rank=old, to_rank=new)
                    report.events.append(("shrink", round_i, len(survivors)))
                    if moved:
                        report.events.append(
                            ("reroute", round_i, [r for r, _, _ in moved]))
                    continue
            else:
                raise RuntimeError(
                    f"rank {ctx.rank}: no global progress in {max_rounds} rounds "
                    f"({ledger.remaining()} requests unanswered)")
            return report

        results = run_ranks(self.nranks, rank_fn, ulfm=True,
                            join_timeout=self.timeout * 4)
        return GroupResult(responses=dict(ledger.responses), reports=results,
                           rerouted=tuple(ledger.rerouted), tracers=tracers)
