"""ServeGroup: N replicas on the thread-rank transport, ULFM fault handling.

Each rank thread owns one :class:`~repro.serve.replica.Replica` and serves its
share of the request ledger. Every round the ranks exchange health + remaining
load through a fault-aware ``Comm.all_reduce`` — the same choke point the
paper routes everything through: the wait either returns the reduction or
raises the unified exceptions.

Hard fault choreography (the acceptance scenario of ISSUE 1):

1. a replica dies (``Transport.kill`` / ``ctx.die`` — simulated node loss);
2. survivors' next health exchange fails; the ULFM protocol revokes, agrees,
   and every survivor raises ``CommCorruptedError`` — *no deadlock*: nobody
   waits on the dead rank;
3. survivors ``shrink_to_survivors`` and re-route: the ledger deterministically
   reassigns the dead rank's unanswered requests across survivors
   (``id % n_survivors`` over the sorted survivor list — no extra communication
   needed, in the spirit of non-collective communicator reparation
   [arXiv 2209.01849]), and serving continues without a global restart
   [arXiv 2212.08755];
4. re-routed requests are recomputed from their prompts on the new owner —
   accepted requests are *answered*, never dropped.

The elastic layer (ISSUE 8) extends the same machinery in both directions and
through time:

* **Epochs, one reconfiguration path.** Every membership change — fault
  shrink, replica join/rejoin, autoscale grow/shrink — is an *epoch*
  proposal on the shared :class:`~repro.serve.ledger.GroupLedger`. The
  per-round health exchange carries ``[remaining, epoch]`` under an
  elementwise max, so all active ranks observe the same highest epoch at the
  same collective and reconfigure together: nobody posts on a stale
  communicator while others moved on. A rank terminates only when the
  exchange agrees both that no work remains *and* that it sits on the newest
  epoch — so a pending joiner is always met on the widened communicator.
* **Non-blocking join** (Bouteiller et al., "Implicit Actions and
  Non-blocking Failure Recovery with MPI"): a joining rank warms up,
  receives weights + the page-pool layout snapshot as a background lane —
  survivors keep decoding throughout — then proposes a widened epoch; the
  ledger deterministically re-balances untaken work onto the widened group.
  Communicators for new epochs come from the *non-collective* reparation
  primitive ``Comm.repair`` [arXiv 2209.01849] — grow and shrink are the
  same operation.
* **Durable ledger.** With ``ledger_path`` every submit / route / retirement
  is a checksummed, fsync'd WAL record; ``serve_from_ledger`` restarts a
  fully crashed fleet from the log alone: answered requests come back
  bit-exact from their ``retire`` records, outstanding ones re-enter through
  the negative-sequence requeue lane with arrival times and trace ids
  preserved — zero drops across the crash.
* **Autoscaler.** The leader (lowest live rank) grows the group on sustained
  backlog / TTFT-p99 pressure and shrinks it on sustained idleness, with
  hysteresis + cooldown — by summoning a dormant spare or draining a victim
  through a *graceful* epoch, driving the very same membership path as a
  fault.

Soft faults stay replica-local (per-sequence LFLR inside ``Replica``); the
group only learns about them through metrics.
"""
from __future__ import annotations

import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax

from ..core import CommCorruptedError, PropagatedError, initialize, run_ranks
from ..core.faults import FaultSchedule
from ..core.transport import RankResult
from ..launch.steps import (
    make_cache_prefill,
    make_decode_window,
    make_prefill_decode_window,
    make_slot_decode_step,
    make_speculative_decode_window,
)
from ..models import build_model
from ..obs.trace import NULL_TRACER, Tracer, merge_traces
from .config import EngineConfig
from .ledger import GroupLedger, WriteAheadLog
from .ledger import replay as replay_ledger
from .metrics import ServeMetrics
from .queue import AdmissionPolicy, Request, RequestQueue, Response
from .replica import SERVE_PROBES, Replica

# chunking of the simulated join-time state transfer: enough chunks (with a
# short host pause each) that the join window spans several decode rounds —
# the survivor-throughput-during-join measurement needs a real window
_TRANSFER_CHUNKS = 6
_TRANSFER_PAUSE_S = 0.002


@dataclass(frozen=True)
class AutoscalePolicy:
    """Hysteresis-guarded elastic sizing policy for a :class:`ServeGroup`.

    The leader samples pressure every round: *hot* when the ledger backlog
    (accepted but unassigned requests) reaches ``queue_high`` or the leader's
    own TTFT p99 exceeds ``ttft_high``; *idle* when the backlog is empty.
    ``grow_sustain`` consecutive hot rounds summon a dormant spare;
    ``shrink_idle`` consecutive idle rounds drain the highest live rank out
    through a graceful epoch. ``cooldown`` rounds must separate consecutive
    membership changes — the hysteresis that stops grow/shrink flapping."""

    queue_high: int = 4
    ttft_high: Optional[float] = None      # seconds, None = queue-depth only
    grow_sustain: int = 3
    shrink_idle: int = 6
    cooldown: int = 8
    min_ranks: int = 2


@dataclass(frozen=True)
class AgreeDecision:
    """Outcome of one agreement round: what a member does with the folded
    ``[remaining, epoch]`` pair."""

    action: str      # "reconfigure" | "hold" | "close" | "continue"
    epoch: int       # the epoch to serve under after acting


def agree_round(rem: int, agreed: int, my_epoch: int, *,
                hold_close: bool = False) -> AgreeDecision:
    """The transport-neutral half of the §3.4 agreement: interpret the
    emax-folded ``[remaining, epoch]`` pair against this member's epoch.

    Both transports run the exact same ladder — the in-process
    ``comm.all_reduce`` group and the multihost socket workers (where the
    supervisor performs the fold in star topology) — so membership semantics
    cannot drift between fault domains:

    * a newer epoch wins over everything (**reconfigure**: enter it before
      serving another round);
    * ``rem == 0`` **close**s the group — unless ``hold_close`` (a pending
      join or a proposal that landed after this round's fold) asks to spin
      one more round;
    * otherwise **continue** serving.
    """
    if agreed > my_epoch:
        return AgreeDecision("reconfigure", agreed)
    if rem == 0:
        return AgreeDecision("hold" if hold_close else "close", my_epoch)
    return AgreeDecision("continue", my_epoch)


@dataclass
class RankReport:
    rank: int
    rounds: int = 0
    events: list = field(default_factory=list)   # ("shrink"|"propagated", round, info)
    metrics: Optional[ServeMetrics] = None


@dataclass
class GroupResult:
    responses: dict[int, Response]
    reports: list[RankResult]                    # raw per-rank harness results
    rerouted: tuple[int, ...] = ()
    tracers: dict[int, Tracer] = field(default_factory=dict)
    rebalanced: tuple[int, ...] = ()             # moved by epoch re-balance
    joined: tuple[int, ...] = ()                 # ranks admitted via join
    autoscale: tuple[dict, ...] = ()             # leader grow/shrink decisions
    epoch: int = 0                               # final membership epoch
    crashed: bool = False                        # fleet stopped mid-serve
    replayed: tuple[int, ...] = ()               # ids re-admitted from a WAL

    @property
    def ok(self) -> dict[int, Response]:
        return {i: r for i, r in self.responses.items() if r.ok}

    def report(self, rank: int) -> Optional[RankReport]:
        rr = self.reports[rank]
        return rr.value if rr.exception is None and not rr.killed else None

    def merged_metrics(self) -> ServeMetrics:
        """Survivor replicas' metrics pooled into one accumulator (sums,
        max-of-peaks, pooled response populations for percentiles)."""
        parts = [rr.value.metrics for rr in self.reports
                 if rr.exception is None and not rr.killed
                 and rr.value is not None and rr.value.metrics is not None]
        return ServeMetrics.merged(parts)

    def summary(self) -> dict:
        """One fleet-level dict: the merged per-replica metrics plus the
        group's own story (replica count, survivors, re-routes)."""
        out = self.merged_metrics().summary()
        # a dormant spare that was never summoned returns None without
        # serving — it participated in nothing and counts as nothing
        out["replicas"] = sum(1 for rr in self.reports
                              if rr.killed or rr.exception is not None
                              or rr.value is not None)
        out["survivors"] = sum(1 for rr in self.reports
                               if rr.exception is None and not rr.killed
                               and rr.value is not None)
        out["rerouted"] = len(self.rerouted)
        if self.joined:
            out["joined"] = len(self.joined)
        if self.rebalanced:
            out["rebalanced"] = len(self.rebalanced)
        if self.autoscale:
            out["autoscale"] = len(self.autoscale)
        if self.crashed:
            out["crashed"] = True
        return out

    def trace(self) -> dict:
        """All ranks' tracers (dead ones included — their spans are the cause
        half of the kill → shrink → re-route chain) merged into one
        trace_event object."""
        return merge_traces(*(self.tracers[r] for r in sorted(self.tracers)))


class ServeGroup:
    """A fleet of serving replicas over the simulated multi-rank runtime."""

    def __init__(self, cfg, nranks: int, *,
                 config: Optional[EngineConfig] = None,
                 seed: int = 0, probe_cfg=SERVE_PROBES,
                 timeout: float = 30.0,
                 max_ranks: Optional[int] = None,
                 autoscale: Optional[AutoscalePolicy] = None,
                 transfer_chunks: int = _TRANSFER_CHUNKS,
                 transfer_pause_s: float = _TRANSFER_PAUSE_S):
        # engine shape comes in through one validated EngineConfig (the
        # historical group default was num_slots=2, preserved here); group
        # wiring (timeouts, elasticity, transfer shape) stays real keywords.
        config = config if config is not None else EngineConfig(num_slots=2)
        self.config = config
        if nranks < 2:
            raise ValueError("a ServeGroup needs >= 2 replicas")
        self.cfg = cfg
        self.nranks = nranks
        self.max_ranks = max(nranks, int(max_ranks or nranks))
        self.autoscale = autoscale
        # join-time state-transfer shape: benchmarks stretch it so the join
        # window spans many decode rounds (the survivor-throughput-during-join
        # cell needs a measurement window wider than one retire burst)
        self.transfer_chunks = int(transfer_chunks)
        self.transfer_pause_s = float(transfer_pause_s)
        self.num_slots = config.num_slots
        self.max_len = config.max_len
        self.timeout = timeout
        self.max_request_retries = config.max_request_retries
        self.eos_id = config.eos_id
        self.window = int(config.window)
        self.overlap = bool(self.window) and bool(config.overlap)
        self.prefill_budget = config.prefill_budget
        self.paged = bool(config.paged)
        self.page_size = config.page_size
        self.page_budget = config.page_budget
        self.page_watermark = config.page_watermark
        self.speculate = bool(config.speculate)
        self.draft_len = int(config.draft_len)
        self.draft_layers = int(config.draft_layers)
        self.tp = int(config.tp)
        self.trace = bool(config.trace)
        self.trace_sample = float(config.trace_sample)
        donate = config.donate
        self.params = build_model(cfg).init(jax.random.PRNGKey(seed))
        # compile once, share across rank threads (jit dispatch is thread-safe)
        # — each paged replica owns its own pool + table, but the layout (and
        # therefore every jitted program) is identical across the fleet
        if self.paged:
            from ..launch.paging import PagedLayout
            model = build_model(cfg)
            num_pages = (int(self.page_budget) if self.page_budget is not None
                         else self.num_slots * (self.max_len // self.page_size))
            self._layout = PagedLayout(model.init_cache(1, self.max_len),
                                       self.max_len,
                                       page_size=self.page_size,
                                       num_pages=num_pages)
        else:
            self._layout = None
        # tensor-parallel fleet: ONE TPContext (mesh + storage specs) shared
        # by the jitted window program below and by every rank's Replica —
        # jax.make_mesh with identical args yields equal Mesh objects, so the
        # per-rank replicas hit the same compilation cache
        self._tp_ctx = None
        if self.tp > 1:
            self._tp_ctx = self._make_tp_ctx()
        self._decode_fn = jax.jit(make_slot_decode_step(cfg, probe_cfg))
        self._prefill_fn = make_cache_prefill(cfg, probe_cfg,
                                              fused=bool(self.window),
                                              paged=self._layout,
                                              donate=bool(self.paged and donate))
        if not self.window:
            self._window_fn = None
        elif self.speculate:
            self._window_fn = make_speculative_decode_window(
                cfg, probe_cfg, window=self.window, draft_len=self.draft_len,
                draft_layers=self.draft_layers, donate=donate,
                paged=self._layout, tp=self._tp_ctx)
        elif self.overlap:
            self._window_fn = make_prefill_decode_window(
                cfg, probe_cfg, window=self.window, donate=donate,
                paged=self._layout, tp=self._tp_ctx)
        else:
            self._window_fn = make_decode_window(
                cfg, probe_cfg, window=self.window, donate=donate,
                paged=self._layout, tp=self._tp_ctx)

    def _make_tp_ctx(self):
        """The fleet-shared :class:`~repro.launch.steps.TPContext`: same mesh
        and storage specs every rank's Replica derives for itself, computed
        once here so the shared window program is sharded at build time.
        Cache specs come from shape templates only — nothing is materialised."""
        from ..launch.steps import TPContext
        from ..sharding.rules import param_specs, tp_storage_specs
        ndev = len(jax.devices())
        if ndev < self.tp:
            raise ValueError(
                f"tp={self.tp} requires {self.tp} devices, found {ndev} "
                "(on CPU, force host devices with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={self.tp})")
        mesh = jax.make_mesh((self.tp,), ("model",))
        one = build_model(self.cfg).init_cache(1, self.max_len)
        if self.paged:
            hybrid = self._layout.init_hybrid(one, self.num_slots)
            cspecs = self._layout.tp_storage_specs(hybrid, mesh)
        else:
            stacked = jax.tree_util.tree_map(
                lambda v: jax.ShapeDtypeStruct((self.num_slots, *v.shape),
                                               v.dtype), one)
            cspecs = tp_storage_specs(stacked, mesh)
        return TPContext(mesh=mesh,
                         param_specs=param_specs(self.params, mesh),
                         cache_specs=cspecs)

    # ------------------------------------------------------------ entry points
    def serve(self, requests: Sequence[Request], *,
              faults: FaultSchedule | None = None,
              max_rounds: int = 10_000,
              ledger_path: Optional[str] = None,
              crash_at: Optional[int] = None,
              joins: Optional[Sequence[int]] = None) -> GroupResult:
        """Serve ``requests`` to completion across the group.

        ``faults`` uses :class:`FaultSpec` with ``step`` meaning the serving
        *round*: ``kind="kill"`` hard-kills a replica at the top of that round;
        ``kind="state_nan"`` flips a bit in one of its active sequences.
        Returns once every request has a terminal response on the survivors.

        The schedule is fully seeded: wildcard specs (``rank=None``) are
        resolved to concrete ranks up front via the schedule's own seed, and
        the slot a ``state_nan`` poisons is drawn from a per-(rank, round)
        generator derived from the same seed — so a fuzzer trajectory that
        kills "some" replica replays bit-for-bit from ``(specs, seed)``.

        Elastic extensions: ``ledger_path`` mirrors the ledger into a durable
        write-ahead log (see :meth:`serve_from_ledger` for the restart half);
        ``crash_at`` stops the *whole fleet* at the top of that round — the
        SIGKILL analogue, every rank dies, only the WAL survives; ``joins``
        lists rounds at which the leader summons a dormant spare rank into
        the group (``max_ranks`` > ``nranks`` provisions the spares).
        """
        wal = WriteAheadLog(ledger_path) if ledger_path else None
        ledger = GroupLedger(
            requests, range(self.nranks),
            spares=range(self.nranks, self.max_ranks), wal=wal)
        return self._run(ledger, actives=tuple(range(self.nranks)),
                         faults=faults, max_rounds=max_rounds,
                         crash_at=crash_at, joins=joins)

    def serve_from_ledger(self, ledger_path: str, *,
                          faults: FaultSchedule | None = None,
                          max_rounds: int = 10_000,
                          crash_at: Optional[int] = None,
                          joins: Optional[Sequence[int]] = None) -> GroupResult:
        """Restart a crashed fleet from its write-ahead log alone.

        :func:`~repro.serve.ledger.replay` reconstructs the ledger (answered
        requests return bit-exact from their ``retire`` records; a torn final
        record is discarded), the last logged epoch's members come back as
        the active set, every other rank up to ``max_ranks`` becomes a spare
        available for regrow, and the outstanding requests re-enter serving
        through the negative-sequence requeue lane with their original
        arrival times and trace ids — so latency accounting and the causal
        trace chain span the crash."""
        rep = replay_ledger(ledger_path)
        if not rep.members:
            raise ValueError(f"{ledger_path}: no epoch record to restart from")
        members = tuple(m for m in rep.members if m < self.max_ranks)
        if len(members) < 2:
            raise ValueError(
                f"{ledger_path}: epoch members {rep.members} leave fewer "
                f"than 2 restartable ranks (max_ranks={self.max_ranks})")
        outstanding = rep.outstanding()
        wal = WriteAheadLog(ledger_path)     # truncates any torn tail
        ledger = GroupLedger(
            outstanding, members,
            spares=[r for r in range(self.max_ranks) if r not in members],
            wal=wal, responses=rep.responses,
            replayed=[r.id for r in outstanding],
            stamped=[r.id for r in outstanding if r.arrival_t is not None],
            epoch0=rep.epoch, epoch_reason="replay", log_submits=False)
        return self._run(ledger, actives=members, faults=faults,
                         max_rounds=max_rounds, crash_at=crash_at,
                         joins=joins, replay_info=rep)

    # ------------------------------------------------------------- the machine
    def _run(self, ledger: GroupLedger, *, actives: tuple[int, ...],
             faults: FaultSchedule | None, max_rounds: int,
             crash_at: Optional[int], joins: Optional[Sequence[int]],
             replay_info=None) -> GroupResult:
        faults = (faults or FaultSchedule()).resolve(sorted(actives))
        policy = self.autoscale
        joins_at = Counter(int(r) for r in (joins or ()))
        launched = self.max_ranks if self.max_ranks > len(actives) else self.nranks
        # elastic mode throttles `take` to replica capacity so a widened
        # group finds untaken work to re-balance; the classic fixed group
        # keeps its drain-everything behavior bit-for-bit
        elastic = (launched > len(actives) or policy is not None
                   or ledger.wal is not None or crash_at is not None
                   or bool(joins_at))

        # a request that could never fit a replica's page pool must be
        # REJECTED at submit (same clamp Replica applies to its own queue)
        pool_cap = (self._layout.capacity_tokens
                    if self.paged and self._layout.has_paged_leaves
                    else self.max_len)

        leaves = jax.tree_util.tree_leaves(self.params)
        ledger.publish_state({
            "params_bytes": int(sum(l.size * l.dtype.itemsize
                                    for l in leaves)),
            "paged": self.paged,
            "num_pages": (self._layout.num_pages if self.paged else 0),
        })

        tracers: dict[int, Tracer] = {}
        epoch0 = ledger.epoch
        leader0 = min(actives)

        def make_tracer(rank: int) -> Tracer:
            if not self.trace:
                return NULL_TRACER
            tracer = Tracer(pid=rank, sample=self.trace_sample)
            # registered up front so a killed rank's spans survive it —
            # they are the *cause* half of the kill → shrink → re-route
            # chain the merged trace must show
            tracers[rank] = tracer
            return tracer

        def build_replica(rank: int, tracer: Tracer) -> Replica:
            queue = RequestQueue(AdmissionPolicy(
                max_queue=10_000, max_total_len=pool_cap), tracer=tracer)
            return Replica(
                self.cfg, params=self.params, config=self.config,
                queue=queue, rank=rank,
                decode_fn=self._decode_fn, prefill_fn=self._prefill_fn,
                window_fn=self._window_fn, paged_layout=self._layout)

        def serve_rounds(ctx, comm, replica, tracer, report, my_epoch, *,
                         inject_faults=True):
            """The per-rank round loop — initial actives and joiners alike.

            ``round_i`` frames are aligned across the initial actives (every
            iteration is one collective exchange), so ``crash_at`` and the
            fault schedule fire coherently; a joiner counts its own rounds
            from 0 and therefore neither re-fires the schedule
            (``inject_faults=False`` — the specs describe the original
            incarnation) nor triggers ``crash_at`` itself — it learns of a
            fleet stop through the ledger flag."""
            for round_i in range(max_rounds):
                # ---- fleet stop (SIGKILL analogue): the WAL is all that
                # survives; every rank dies, joiners learn via the flag
                if (crash_at is not None and round_i == crash_at
                        and inject_faults) or ledger.crashed:
                    ledger.crash()
                    if tracer.enabled:
                        tracer.instant("fleet_stop", "group", rank=ctx.rank,
                                       round=round_i)
                    ctx.die()                           # never returns
                for spec in (faults.at(round_i, ctx.rank)
                             if inject_faults else ()):
                    if spec.kind == "kill":
                        if tracer.enabled:
                            tracer.instant("replica_kill", "group",
                                           rank=ctx.rank, round=round_i)
                        ctx.die()                       # never returns
                    elif spec.kind == "shard_kill":
                        # TP shard loss: one shard of this replica's model
                        # mesh dies. A TP replica is one SPMD program, so the
                        # shard loss is a hard fault of the whole rank — the
                        # survivors see the same RANK_FAILED → shrink →
                        # re-route path a full replica kill drives; the
                        # shard_loss instant records which shard was the cause
                        if tracer.enabled:
                            tracer.instant("shard_loss", "group",
                                           rank=ctx.rank, round=round_i,
                                           shard=spec.shard, tp=self.tp)
                            tracer.instant("replica_kill", "group",
                                           rank=ctx.rank, round=round_i)
                        ctx.die()                       # never returns
                    elif spec.kind == "state_nan":
                        slot = replica.inject_state_fault(
                            rng=faults.rng_for(ctx.rank, round_i))
                        if slot is not None:
                            report.events.append(("inject", round_i, slot))
                leader = min(ledger.members)
                if ctx.rank == leader and not ledger.stopped:
                    for _ in range(joins_at.get(round_i, 0)):
                        summoned = ledger.summon_next("scheduled")
                        if summoned is not None:
                            report.events.append(
                                ("summon", round_i, summoned))
                    if policy is not None:
                        self._autoscale_tick(ledger, policy, replica,
                                             round_i, tracer, report)
                # ---- graceful autoscale leave: drain, then propose the
                # epoch that excludes us and keep exchanging until agreed
                if ledger.leaving == ctx.rank and replica.idle():
                    left = ledger.depart(ctx.rank)
                    if tracer.enabled:
                        tracer.instant("autoscale", "group", action="depart",
                                       rank=ctx.rank, epoch=left,
                                       round=round_i)
                    report.events.append(("depart", round_i, left))
                if ledger.leaving != ctx.rank:
                    limit = (None if not elastic else
                             max(0, 2 * self.num_slots - replica.load()))
                    for req in ledger.take(ctx.rank, limit):
                        if (req.id in ledger.replayed
                                and req.arrival_t is not None):
                            rej = replica.readmit(req)
                        else:
                            rej = replica.submit(req)
                        if rej is None:
                            ledger.note_stamp(req)
                        else:
                            ledger.complete(rej)
                for resp in replica.step():
                    ledger.complete(resp)
                report.rounds = round_i + 1
                # fault-aware health/termination/epoch exchange: the one wait
                # that either agrees on progress or raises the paper's
                # exceptions. Elementwise max makes every rank of the epoch
                # see the same [remaining, newest-epoch] pair at the same
                # collective — the barrier at which reconfiguration happens.
                try:
                    rem, agreed = comm.all_reduce(
                        [ledger.remaining(), ledger.epoch], op="emax").wait()
                except PropagatedError as exc:
                    report.events.append(
                        ("propagated", round_i,
                         [e.rank for e in exc.errors]))
                    continue
                except CommCorruptedError:
                    prev = tuple(comm.context.members)
                    comm.shrink_to_survivors()
                    survivors = list(comm.context.members)
                    moved = ledger.on_death(set(prev) - set(survivors))
                    if tracer.enabled:
                        tracer.instant("ulfm_shrink", "group", rank=ctx.rank,
                                       round=round_i,
                                       survivors=sorted(survivors))
                        for rid, old, new in moved:
                            tracer.instant(
                                "reroute", "group",
                                trace_id=ledger.requests[rid].trace_id,
                                request=rid, from_rank=old, to_rank=new)
                    report.events.append(("shrink", round_i, len(survivors)))
                    if moved:
                        report.events.append(
                            ("reroute", round_i, [r for r, _, _ in moved]))
                    continue
                # hold the final close (serving never stalled — there is
                # simply nothing left to serve) while either (a) an
                # operator-scheduled joiner is still warming up /
                # mid-transfer, so a requested regrow cannot lose the race
                # against the drain, or (b) a membership proposal landed
                # *after* this round's exchange read the epoch — closing on
                # the stale agreement would strand the proposer on a
                # collective nobody posts
                decision = agree_round(
                    rem, agreed, my_epoch,
                    hold_close=(ledger.has_pending_joins()
                                or ledger.epoch > agreed))
                if decision.action == "reconfigure":
                    # first entrant re-balances untaken work over the new
                    # member list, everyone re-keys the comm
                    moved = ledger.enter_epoch(decision.epoch)
                    members = ledger.members_of(decision.epoch)
                    if tracer.enabled:
                        for rid, old, new in moved:
                            tracer.instant(
                                "reroute", "group",
                                trace_id=ledger.requests[rid].trace_id,
                                request=rid, from_rank=old, to_rank=new)
                    if moved:
                        report.events.append(
                            ("rebalance", round_i, [r for r, _, _ in moved]))
                    report.events.append(("epoch", round_i, decision.epoch))
                    if ctx.rank not in members:
                        return report       # our graceful leave is agreed
                    if tuple(sorted(comm.context.members)) != members:
                        comm = comm.repair(members,
                                           ("serve-epoch", decision.epoch))
                    my_epoch = decision.epoch
                    continue    # ≥1 exchange on the new epoch before exit
                if decision.action == "hold":
                    time.sleep(0.002)
                    continue
                if decision.action == "close":
                    ledger.close()
                    return report
            raise RuntimeError(
                f"rank {ctx.rank}: no global progress in {max_rounds} rounds "
                f"({ledger.remaining()} requests unanswered)")

        def join_rank(ctx, inst, tracer, replica, reason: str,
                      t_join0: float):
            """Warm spare → serving member, without stalling survivors:
            receive state as a background lane, propose the widened epoch,
            meet the group on the repaired communicator."""
            snap = ledger.state_snapshot or {}
            t_xfer0 = time.monotonic()
            for _ in range(self.transfer_chunks):
                if ledger.stopped:
                    ledger.abandon_join(ctx.rank)
                    return None             # fleet gone mid-transfer
                time.sleep(self.transfer_pause_s)
            if tracer.enabled:
                tracer.span("state_transfer", "group", t_xfer0,
                            time.monotonic(), rank=ctx.rank,
                            bytes=snap.get("params_bytes", 0),
                            num_pages=snap.get("num_pages", 0),
                            chunks=self.transfer_chunks, reason=reason,
                            complete=True)
            epoch = ledger.request_join(ctx.rank)
            if epoch is None:
                return None                 # group finished while we warmed
            # wait (off the collective path) until the actives entered an
            # epoch that includes us — guarantees somebody will meet our
            # first exchange. A concurrent fault may have pushed the agreed
            # epoch *past* our proposal; every later epoch still contains us
            # (only our own death could remove us), so we enter the newest.
            while ledger.agreed_epoch < epoch:
                if ledger.stopped:
                    ledger.abandon_join(ctx.rank)
                    return None
                time.sleep(0.001)
            epoch = ledger.agreed_epoch
            comm = inst.comm_world().repair(
                ledger.members_of(epoch), ("serve-epoch", epoch))
            if tracer.enabled:
                tracer.span("replica_join", "group", t_join0,
                            time.monotonic(), rank=ctx.rank, epoch=epoch,
                            reason=reason, complete=True)
            report = RankReport(rank=ctx.rank, metrics=replica.metrics)
            report.events.append(("join", epoch, reason))
            return serve_rounds(ctx, comm, replica, tracer, report, epoch,
                                inject_faults=False)

        def rank_fn(ctx):
            if ctx.rank in actives:
                inst = initialize(ctx, default_timeout=self.timeout)
                tracer = make_tracer(ctx.rank)
                if launched == len(actives):
                    comm = inst.comm_world()
                else:
                    comm = inst.comm_world().repair(
                        tuple(sorted(actives)), ("serve-epoch", epoch0))
                if replay_info is not None and ctx.rank == leader0 \
                        and tracer.enabled:
                    tracer.instant(
                        "ledger_replay", "group", rank=ctx.rank,
                        records=replay_info.records, torn=replay_info.torn,
                        epoch=epoch0, outstanding=len(ledger.replayed),
                        answered=len(replay_info.responses))
                replica = build_replica(ctx.rank, tracer)
                report = RankReport(rank=ctx.rank, metrics=replica.metrics)
                return serve_rounds(ctx, comm, replica, tracer, report,
                                    epoch0)
            # dormant spare: pre-warm at spawn (replica build + jit warmup,
            # off the fleet's collective path — a warm standby pool), so a
            # later summons only pays the state-transfer lane; then wait
            # off-path for a summons (join schedule or autoscale grow) and
            # exit quietly if the group stops first
            if ledger.stopped:
                return None
            inst = initialize(ctx, default_timeout=self.timeout)
            tracer = make_tracer(ctx.rank)
            replica = build_replica(ctx.rank, tracer)
            replica.warmup()                # compiles; clears warmup spans
            deadline = time.monotonic() + self.timeout * 3
            while time.monotonic() < deadline:
                if ledger.stopped:
                    ledger.abandon_join(ctx.rank)
                    return None
                if all(m in ctx.t.dead for m in ledger.members):
                    ledger.abandon_join(ctx.rank)
                    return None             # nobody left to join
                reason = ledger.summoned(ctx.rank)
                if reason is not None:
                    return join_rank(ctx, inst, tracer, replica, reason,
                                     time.monotonic())
                time.sleep(0.002)
            ledger.abandon_join(ctx.rank)
            return None

        results = run_ranks(launched, rank_fn, ulfm=True,
                            join_timeout=self.timeout * 4)
        if ledger.wal is not None:
            ledger.wal.close()
        return GroupResult(
            responses=dict(ledger.responses), reports=results,
            rerouted=tuple(ledger.rerouted), tracers=tracers,
            rebalanced=tuple(ledger.rebalanced),
            joined=tuple(ledger.joined),
            autoscale=tuple(ledger.autoscale_events),
            epoch=ledger.epoch, crashed=ledger.crashed,
            replayed=tuple(sorted(ledger.replayed)))

    # -------------------------------------------------------------- autoscaler
    def _autoscale_tick(self, ledger: GroupLedger, policy: AutoscalePolicy,
                        replica: Replica, round_i: int, tracer: Tracer,
                        report: RankReport) -> None:
        """One leader-side policy sample. Grow and shrink both land on the
        ledger's epoch path — the same reconfiguration the fault handler
        drives — so elasticity adds no second membership mechanism."""
        st = ledger.scale_state
        members = ledger.members
        backlog = ledger.backlog()
        rem = ledger.remaining()
        hot = backlog >= policy.queue_high
        if not hot and policy.ttft_high is not None:
            p99 = replica.metrics.ttft_percentiles((99,)).get("p99")
            hot = p99 is not None and p99 > policy.ttft_high
        st["hot"] = st["hot"] + 1 if hot else 0
        st["idle"] = st["idle"] + 1 if (backlog == 0 and not hot) else 0
        since = round_i - st["last_change"]
        if (st["hot"] >= policy.grow_sustain and since >= policy.cooldown
                and len(members) < self.max_ranks):
            rank = ledger.summon_next("autoscale")
            if rank is not None:
                st["hot"] = 0
                st["last_change"] = round_i
                ledger.autoscale_events.append(
                    {"round": round_i, "action": "grow", "rank": rank})
                if tracer.enabled:
                    tracer.instant("autoscale", "group", action="grow",
                                   rank=rank, round=round_i)
                report.events.append(("autoscale", round_i, ("grow", rank)))
        elif (st["idle"] >= policy.shrink_idle and since >= policy.cooldown
                and len(members) > max(2, policy.min_ranks)
                and rem > 0 and ledger.leaving is None):
            victim = max(members)
            if victim != min(members) and ledger.request_leave(victim):
                st["idle"] = 0
                st["last_change"] = round_i
                ledger.autoscale_events.append(
                    {"round": round_i, "action": "shrink", "rank": victim})
                if tracer.enabled:
                    tracer.instant("autoscale", "group", action="shrink",
                                   rank=victim, round=round_i)
                report.events.append(
                    ("autoscale", round_i, ("shrink", victim)))
