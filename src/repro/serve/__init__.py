"""repro.serve — fault-tolerant continuous-batching inference.

The serving layer of the stack (DESIGN.md §2/§3): it applies the paper's
contract — local errors, asynchrony and hard faults become *catchable
exceptions* at a wait, never deadlocks or aborts — to inference traffic.

* :class:`RequestQueue` / :class:`AdmissionPolicy` — deadline-aware (EDF)
  admission; every accepted request gets a terminal :class:`Response`.
* :class:`ContinuousBatchingScheduler` — fixed decode slots, per-step evict +
  backfill over :func:`repro.launch.steps.make_slot_decode_step`.
* :class:`Replica` — wraps every fused step in a ``DeviceFuture``; per-slot
  error words + the paper's enumeration give ``(slot, code)`` attribution, so
  ``STATE_FAULT`` triggers per-sequence LFLR re-prefill (recompute, don't
  restart) and a :class:`~repro.core.recovery.RecoveryPolicy` escalates. With
  ``window=K`` the hot path is the zero-sync decode window; with ``overlap``
  (default) admission and LFLR ride the windows as background chunked-prefill
  lanes — the token stream never stalls on a blocking prefill (DESIGN §3.2).
* :class:`ServeGroup` — N replicas over the thread-rank transport; a killed
  replica raises on the survivors via the ULFM protocol, the group shrinks and
  re-routes its in-flight requests.
* :class:`MultiHostSupervisor` — the same fault contract across real OS
  processes: localhost subprocess workers (one replica each) under a
  phi-accrual heartbeat failure detector; a SIGKILL'd worker is detected,
  mapped to ``RANK_FAILED`` on the survivors, and repaired through the same
  :func:`agree_round` epoch ladder over a length-prefixed socket transport
  (DESIGN §3.9).
* :class:`ServeMetrics` — latency percentiles, tokens/s, fault counters, and
  an ``EventLog`` export matching the training executor's records.
* Tracing (``repro.obs``) — pass ``tracer=Tracer(...)`` to a replica (or
  ``trace=True`` to a :class:`ServeGroup`) and every request's life becomes a
  causal span chain: submit → slot → prefill chunks → decode windows →
  (faults → recovery lanes →) terminal response, exported as Perfetto
  ``trace_event`` JSON (DESIGN §3.5).
"""
from .config import EngineConfig  # noqa: F401
from .group import (  # noqa: F401
    AgreeDecision,
    GroupResult,
    RankReport,
    ServeGroup,
    agree_round,
)
from .metrics import FaultRecord, ServeMetrics  # noqa: F401
from .multihost import (  # noqa: F401
    MultiHostResult,
    MultiHostSupervisor,
    PhiAccrualDetector,
    sim_tokens,
)
from .queue import (  # noqa: F401
    EXPIRED,
    FAILED,
    OK,
    REJECTED,
    AdmissionPolicy,
    Request,
    RequestQueue,
    Response,
)
from .replica import Replica  # noqa: F401
from .scheduler import (  # noqa: F401
    ChunkPlan,
    ContinuousBatchingScheduler,
    PageAllocator,
    PagePoolExhausted,
    Slot,
)
