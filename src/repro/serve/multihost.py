"""Multi-host fault domain: real-process workers under a heartbeat supervisor.

Everything before this module *simulated* node loss: a "killed rank" was a
thread told to unwind. Here each host is a **real OS process** — a localhost
subprocess worker owning one replica (``jax.distributed``-initialized when a
coordinator is configured; plain single-process JAX on the CPU backend) —
coordinated by a supervisor over a length-prefixed socket protocol. A
SIGKILL'd worker is a genuinely lost process: no flag, no in-band error word,
just silence. The paper's hard-fault story must therefore run across a real
process boundary, in three acts:

* **Detect** — the supervisor runs a heartbeat/lease failure detector
  (:class:`PhiAccrualDetector`): workers beat every ``heartbeat_interval``;
  the detector keeps per-host inter-arrival statistics and suspects a host
  when the phi-accrual score of its silence crosses the adaptive threshold
  (or the hard ``suspect_timeout`` bound). A suspect that beats again is
  cleared — a SIGSTOP'd (slow-but-alive) host resumed within
  ``suspect_timeout`` is never evicted. A suspect silent past
  ``evict_factor × suspect_timeout`` is evicted; with ``evict_factor ≤ 2``
  the detection-to-evict latency is bounded by ``2 × suspect_timeout``.
* **Map** — eviction latches :class:`~repro.core.errors.ErrorCode.RANK_FAILED`
  into the surviving group: every survivor learns the death through the next
  agreement reply and ORs the bit into its local group error word, exactly as
  the in-band probes latch soft faults.
* **Repair** — the supervisor owns the durable
  :class:`~repro.serve.ledger.GroupLedger` (+ write-ahead log) and drives the
  same ULFM epoch machinery the thread-rank group uses:
  ``ledger.on_death`` proposes the shrunken epoch and deterministically
  re-routes the dead host's unanswered requests (``id % n_survivors``); the
  ``all_reduce([remaining, epoch], emax)`` agreement is re-run over the
  socket transport in star topology — each worker's contribution is folded
  (elementwise max) with the supervisor's ledger view and broadcast back —
  and survivors keep decoding throughout detection: they only ever wait on
  the supervisor, never on a peer, so a dead host can not block anybody.

Protocol (4-byte big-endian length + JSON, one frame per message):

========== =============================================================
worker →   ``hello`` (post-warmup readiness), ``hb`` (heartbeat),
           ``exchange {round, remaining, epoch}`` (agreement contribution),
           ``retire {resp}`` (terminal response), ``trace {events}``,
           ``bye``
supervisor ``work {requests, rerouted}`` (assignment / re-route),
→          ``reduce {round, rem, epoch, members, evicted}`` (agreement
           result), ``retire_ack {id}`` (sent only after the response is
           fsync'd into the WAL — the durability handshake), ``stop``
========== =============================================================

The worker half (:func:`worker_main`) lives in this module too;
``scripts/worker.py`` is the standalone entrypoint. Workers run either the
real :class:`~repro.serve.replica.Replica` engine (``backend="replica"`` —
params rebuilt from the same PRNGKey per process, so re-routed requests
recompute bit-exact token streams) or a deterministic arithmetic simulator
(``backend="sim"`` — :func:`sim_tokens`) for protocol/detector tests and
fuzz lanes that don't need a model.

Trace events (merged across processes — ``time.monotonic`` is
``CLOCK_MONOTONIC``, one clock domain per machine): ``host_suspect`` /
``host_suspect_clear`` / ``host_evict`` / ``host_kill`` / ``host_stop`` /
``host_resume`` instants and one ``heartbeat`` span per host on the
supervisor lane (pid ``SUPERVISOR_PID``), plus the usual ``group`` events
(``replica_kill``, ``ulfm_shrink``, ``reroute``, ``epoch``) so the
post-mortem rules — every evict preceded by a suspect and followed by an
epoch that excludes the dead rank — check the whole causal chain. See
DESIGN.md §3.9 for the host fault-domain contract.
"""
from __future__ import annotations

import json
import math
import os
import queue
import signal
import socket
import struct
import subprocess
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from ..core.errors import ErrorCode
from ..core.faults import FaultSchedule
from ..obs.trace import NULL_TRACER, Tracer
from .config import EngineConfig
from .group import agree_round
from .ledger import (
    GroupLedger,
    WriteAheadLog,
    request_from,
    request_record,
    response_from,
    response_record,
)
from .queue import OK, Request, Response

#: trace pid of the supervisor's lane (workers use their rank as pid).
SUPERVISOR_PID = 1 << 10

#: host fault kinds the supervisor executes on worker processes.
HOST_FAULT_KINDS = frozenset({"host_kill", "host_stop"})

_SIM_VOCAB = 512


# ------------------------------------------------------------------- framing
def send_msg(sock: socket.socket, obj: dict,
             lock: Optional[threading.Lock] = None) -> None:
    """One length-prefixed JSON frame (4-byte big-endian length + body).
    ``lock`` serialises concurrent senders (worker main + heartbeat thread)
    so frames never interleave."""
    data = json.dumps(obj, separators=(",", ":")).encode()
    frame = struct.pack(">I", len(data)) + data
    if lock is not None:
        with lock:
            sock.sendall(frame)
    else:
        sock.sendall(frame)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def recv_msg(sock: socket.socket) -> Optional[dict]:
    """Read one frame; None on a clean/forced EOF (the peer is gone)."""
    hdr = _recv_exact(sock, 4)
    if hdr is None:
        return None
    (n,) = struct.unpack(">I", hdr)
    body = _recv_exact(sock, n)
    if body is None:
        return None
    return json.loads(body.decode())


# ---------------------------------------------------------------- sim tokens
def sim_tokens(prompt: Sequence[int], max_new: int,
               vocab: int = _SIM_VOCAB) -> tuple[int, ...]:
    """The sim backend's deterministic token rule — a pure function of the
    prompt, shared by workers and the supervisor-side bit-exactness oracle
    (the sim analogue of greedy decode's determinism)."""
    base = sum(int(t) for t in prompt) % vocab
    return tuple((base * 31 + 7 * j) % vocab for j in range(int(max_new)))


# ------------------------------------------------------------------ detector
class PhiAccrualDetector:
    """Phi-accrual heartbeat failure detector with a suspect → evict ladder.

    Per-host inter-arrival statistics feed a phi score of the current
    silence (``-log10`` of the one-sided normal tail probability); a host is
    **suspected** when phi crosses ``phi_threshold`` (with a two-interval
    grace so one late beat is never suspicious) *or* when silence reaches the
    hard ``suspect_timeout`` bound — the adaptive path fires earlier for
    hosts with historically tight, regular beats. A beat from a suspect
    clears the suspicion (:meth:`heartbeat` returns True): a SIGSTOP'd
    host resumed within ``suspect_timeout`` is slow-but-alive, not dead.
    A suspect whose silence reaches ``evict_factor × suspect_timeout`` is
    **evictable**; ``1 < evict_factor ≤ 2`` bounds detection-to-evict
    latency by ``2 × suspect_timeout`` while leaving a
    ``(evict_factor − 1) × suspect_timeout`` margin that makes the
    SIGSTOP-no-evict guarantee hold.
    """

    def __init__(self, *, suspect_timeout: float = 1.0,
                 heartbeat_interval: float = 0.05,
                 evict_factor: float = 1.8, phi_threshold: float = 8.0,
                 window: int = 64,
                 clock: Callable[[], float] = time.monotonic):
        if suspect_timeout <= 0:
            raise ValueError(f"suspect_timeout must be > 0, got "
                             f"{suspect_timeout}")
        if not 0 < heartbeat_interval < suspect_timeout:
            raise ValueError(
                f"heartbeat_interval must be in (0, suspect_timeout), got "
                f"{heartbeat_interval} vs {suspect_timeout}")
        if not 1.0 < evict_factor <= 2.0:
            raise ValueError(
                f"evict_factor must be in (1, 2] (≤2 bounds detection-to-"
                f"evict by 2×suspect_timeout; >1 is the SIGSTOP margin), "
                f"got {evict_factor}")
        if phi_threshold <= 0:
            raise ValueError(f"phi_threshold must be > 0, got {phi_threshold}")
        self.suspect_timeout = float(suspect_timeout)
        self.heartbeat_interval = float(heartbeat_interval)
        self.evict_after = float(evict_factor) * float(suspect_timeout)
        self.phi_threshold = float(phi_threshold)
        self.clock = clock
        self._window = int(window)
        self._last: dict[int, float] = {}
        self._intervals: dict[int, deque] = {}
        self._suspect_since: dict[int, float] = {}

    # ------------------------------------------------------------- lifecycle
    def register(self, rank: int, now: Optional[float] = None) -> None:
        now = self.clock() if now is None else now
        self._last[rank] = now
        self._intervals[rank] = deque(maxlen=self._window)

    def remove(self, rank: int) -> None:
        self._last.pop(rank, None)
        self._intervals.pop(rank, None)
        self._suspect_since.pop(rank, None)

    def ranks(self) -> list[int]:
        return sorted(self._last)

    # ------------------------------------------------------------------ beats
    def heartbeat(self, rank: int, now: Optional[float] = None) -> bool:
        """Record a beat; returns True when it cleared a standing suspicion
        (the slow-but-alive discrimination the SIGSTOP guard relies on)."""
        if rank not in self._last:
            return False
        now = self.clock() if now is None else now
        self._intervals[rank].append(max(now - self._last[rank], 0.0))
        self._last[rank] = now
        return self._suspect_since.pop(rank, None) is not None

    # ------------------------------------------------------------------ state
    def silence(self, rank: int, now: Optional[float] = None) -> float:
        now = self.clock() if now is None else now
        return now - self._last[rank]

    def _stats(self, rank: int) -> tuple[float, float]:
        xs = self._intervals.get(rank)
        if not xs:
            return self.heartbeat_interval, 0.1 * self.heartbeat_interval
        mean = sum(xs) / len(xs)
        var = sum((x - mean) ** 2 for x in xs) / len(xs)
        # floor the spread at 10% of the mean: perfectly regular beats must
        # not make a single scheduling hiccup look like a death
        return mean, max(math.sqrt(var), 0.1 * mean, 1e-6)

    def phi(self, rank: int, now: Optional[float] = None) -> float:
        """Phi-accrual score of the current silence: ``-log10`` of the
        one-sided normal tail probability of a gap this long, under the
        host's observed inter-arrival distribution."""
        silence = self.silence(rank, now)
        mean, std = self._stats(rank)
        y = (silence - mean) / std
        p = 0.5 * math.erfc(y / math.sqrt(2.0))
        return -math.log10(max(p, 1e-30))

    def is_suspect(self, rank: int) -> bool:
        return rank in self._suspect_since

    def suspect_since(self, rank: int) -> Optional[float]:
        return self._suspect_since.get(rank)

    # ------------------------------------------------------------------- poll
    def poll(self, now: Optional[float] = None) -> tuple[list[int], list[int]]:
        """One detector tick: ``(newly_suspect, evictable)`` transitions.
        Suspicion is entered at most once per silent stretch (a clearing
        beat re-arms it); eviction is the caller's decision to execute."""
        now = self.clock() if now is None else now
        newly: list[int] = []
        evictable: list[int] = []
        for rank in self._last:
            silence = now - self._last[rank]
            if rank not in self._suspect_since:
                mean, _ = self._stats(rank)
                # grace floor: queue jitter can compress *measured*
                # inter-arrivals well below the configured beat period, and
                # one missed beat must never look suspicious
                grace = max(2.0 * mean, 2.0 * self.heartbeat_interval)
                adaptive = (silence >= grace
                            and self.phi(rank, now) >= self.phi_threshold)
                if silence >= self.suspect_timeout or adaptive:
                    self._suspect_since[rank] = now
                    newly.append(rank)
            if rank in self._suspect_since and silence >= self.evict_after:
                evictable.append(rank)
        return newly, evictable


# -------------------------------------------------------------------- result
@dataclass
class MultiHostResult:
    """Outcome of one multi-host serve: terminal responses plus the fault
    domain's own story (detection timings, evictions, re-routes)."""

    responses: dict[int, Response]
    rerouted: tuple[int, ...] = ()
    evicted: tuple[int, ...] = ()
    suspected: tuple[int, ...] = ()     # ever entered suspicion
    resumed: tuple[int, ...] = ()       # suspicion cleared by a late beat
    stopped: tuple[int, ...] = ()       # SIGSTOP'd by a host_stop fault
    epoch: int = 0
    detection: dict[int, dict] = field(default_factory=dict)
    retires: tuple = ()                 # (ts, rank, id) — survivor liveness
    events: list = field(default_factory=list)   # merged trace events

    @property
    def ok(self) -> dict[int, Response]:
        return {i: r for i, r in self.responses.items() if r.ok}

    def trace(self) -> dict:
        evs = sorted(self.events,
                     key=lambda e: (e.get("ts", 0.0), e.get("pid", 0)))
        return {"traceEvents": evs, "displayTimeUnit": "ms"}


class _Conn:
    """One worker connection: socket + send lock + liveness flag."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.lock = threading.Lock()
        self.alive = True

    def send(self, obj: dict) -> None:
        if not self.alive:
            return
        try:
            send_msg(self.sock, obj, self.lock)
        except OSError:
            self.alive = False

    def close(self) -> None:
        self.alive = False
        try:
            self.sock.close()
        except OSError:
            pass


def _default_worker_cmd() -> list[str]:
    """Locate the worker entrypoint: ``scripts/worker.py`` next to the source
    tree when present (the documented standalone launcher), else run this
    module directly."""
    here = os.path.dirname(os.path.abspath(__file__))     # .../src/repro/serve
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    script = os.path.join(repo, "scripts", "worker.py")
    if os.path.exists(script):
        return [sys.executable, "-u", script]
    return [sys.executable, "-u", "-m", "repro.serve.multihost"]


# ---------------------------------------------------------------- supervisor
class MultiHostSupervisor:
    """A fleet of worker *processes* under heartbeat supervision.

    The supervisor owns the request ledger (and its WAL when
    ``ledger_path`` is set), distributes work, folds each worker's
    ``[remaining, epoch]`` agreement contribution with its own ledger view
    (star-topology emax), runs the failure detector, executes scheduled host
    faults (``host_kill`` → SIGKILL, ``host_stop`` → SIGSTOP/SIGCONT), and
    repairs membership through the same epoch machinery the thread-rank
    :class:`~repro.serve.group.ServeGroup` uses.
    """

    def __init__(self, nranks: int, *,
                 backend: str = "sim",
                 arch: str = "qwen3-1.7b",
                 config: Optional[EngineConfig] = None,
                 seed: int = 0,
                 suspect_timeout: float = 1.0,
                 heartbeat_interval: float = 0.05,
                 evict_factor: float = 1.8,
                 phi_threshold: float = 8.0,
                 ledger_path: Optional[str] = None,
                 trace: bool = False,
                 timeout: float = 120.0,
                 sim_tokens_per_step: int = 4,
                 sim_step_delay_s: float = 0.005,
                 worker_cmd: Optional[Sequence[str]] = None,
                 jax_coordinator: Optional[str] = None):
        if nranks < 2:
            raise ValueError("a multi-host group needs >= 2 workers")
        if backend not in ("sim", "replica"):
            raise ValueError(f"unknown worker backend {backend!r} "
                             "(known: sim, replica)")
        self.nranks = int(nranks)
        self.backend = backend
        self.arch = arch
        self.config = config if config is not None else EngineConfig(
            num_slots=2)
        self.seed = int(seed)
        self.suspect_timeout = float(suspect_timeout)
        self.heartbeat_interval = float(heartbeat_interval)
        self.evict_factor = float(evict_factor)
        self.phi_threshold = float(phi_threshold)
        self.ledger_path = ledger_path
        self.trace = bool(trace)
        self.timeout = float(timeout)
        self.sim_tokens_per_step = int(sim_tokens_per_step)
        self.sim_step_delay_s = float(sim_step_delay_s)
        self.worker_cmd = (list(worker_cmd) if worker_cmd
                           else _default_worker_cmd())
        self.jax_coordinator = jax_coordinator
        # validate the detector parameters now, not mid-serve
        PhiAccrualDetector(suspect_timeout=self.suspect_timeout,
                           heartbeat_interval=self.heartbeat_interval,
                           evict_factor=self.evict_factor,
                           phi_threshold=self.phi_threshold)

    # -------------------------------------------------------------- plumbing
    def _worker_spec(self, rank: int, port: int) -> dict:
        import dataclasses
        return {
            "rank": rank, "port": port, "nranks": self.nranks,
            "backend": self.backend, "arch": self.arch, "seed": self.seed,
            "heartbeat_interval": self.heartbeat_interval,
            "trace": self.trace, "io_timeout": self.timeout,
            "engine": dataclasses.asdict(self.config),
            "sim": {"tokens_per_step": self.sim_tokens_per_step,
                    "step_delay_s": self.sim_step_delay_s,
                    "vocab": _SIM_VOCAB},
            "jax_coordinator": self.jax_coordinator,
        }

    def _spawn(self, rank: int, port: int) -> subprocess.Popen:
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                             if env.get("PYTHONPATH") else src)
        cmd = self.worker_cmd + [
            "--spec", json.dumps(self._worker_spec(rank, port))]
        return subprocess.Popen(cmd, env=env, stdout=subprocess.DEVNULL)

    # ------------------------------------------------------------------ serve
    def serve(self, requests: Sequence[Request], *,
              faults: FaultSchedule | None = None) -> MultiHostResult:
        """Serve ``requests`` to completion across the worker processes.

        ``faults`` accepts host-level specs only: ``kind="host_kill"``
        SIGKILLs worker ``rank`` once ``step`` responses have been retired
        fleet-wide (so the kill lands mid-decode), ``kind="host_stop"``
        SIGSTOPs it for ``magnitude`` seconds then SIGCONTs. Device-word
        kinds belong to the engines, not the host domain, and are rejected.
        """
        requests = list(requests)
        faults = (faults or FaultSchedule()).resolve(range(self.nranks))
        pending_faults = []
        for spec in faults.specs:
            if spec.kind not in HOST_FAULT_KINDS:
                raise ValueError(
                    f"multihost supervisor only executes host faults "
                    f"{sorted(HOST_FAULT_KINDS)}, got kind={spec.kind!r} "
                    "(in-band words are the engines' injection surface)")
            pending_faults.append(spec)
        pending_faults.sort(key=lambda s: s.step)

        wal = WriteAheadLog(self.ledger_path) if self.ledger_path else None
        ledger = GroupLedger(requests, range(self.nranks), wal=wal)
        tracer = Tracer(pid=SUPERVISOR_PID) if self.trace else NULL_TRACER
        detector = PhiAccrualDetector(
            suspect_timeout=self.suspect_timeout,
            heartbeat_interval=self.heartbeat_interval,
            evict_factor=self.evict_factor,
            phi_threshold=self.phi_threshold)

        listener = socket.create_server(("127.0.0.1", 0))
        port = listener.getsockname()[1]
        listener.settimeout(0.2)
        inbox: queue.Queue = queue.Queue()
        stop_accept = threading.Event()
        conns: dict[int, _Conn] = {}

        def reader(sock: socket.socket) -> None:
            """Per-connection reader: the first frame must be ``hello`` (it
            names the rank); afterwards every frame lands in the inbox."""
            try:
                first = recv_msg(sock)
            except OSError:
                first = None
            if not first or first.get("type") != "hello":
                try:
                    sock.close()
                except OSError:
                    pass
                return
            rank = int(first["rank"])
            conns[rank] = _Conn(sock)
            inbox.put((rank, first))
            while True:
                try:
                    msg = recv_msg(sock)
                except OSError:
                    msg = None
                if msg is None:
                    inbox.put((rank, {"type": "_eof"}))
                    return
                inbox.put((rank, msg))

        def acceptor() -> None:
            while not stop_accept.is_set():
                try:
                    sock, _ = listener.accept()
                except socket.timeout:
                    continue
                except OSError:
                    return
                threading.Thread(target=reader, args=(sock,),
                                 daemon=True).start()

        threading.Thread(target=acceptor, daemon=True).start()

        procs = {r: self._spawn(r, port) for r in range(self.nranks)}
        timers: list[threading.Timer] = []

        live = set(range(self.nranks))      # not yet evicted
        ready: set[int] = set()             # said hello
        done: set[int] = set()              # said bye
        evict_notices: dict[int, set] = {r: set() for r in range(self.nranks)}
        beats: dict[int, list] = {}         # rank -> [first, last, count]
        worker_events: list[dict] = []
        retires: list[tuple] = []
        detection: dict[int, dict] = {}
        suspected: set[int] = set()
        resumed: set[int] = set()
        stopped: set[int] = set()
        retired_total = 0

        def note(rank: int) -> dict:
            return detection.setdefault(rank, {})

        def ship_rerouted(moved) -> None:
            for owner in sorted({new for _, _, new in moved}):
                if owner not in ready or owner not in live:
                    continue    # its hello-time take will scoop these up
                reqs = ledger.take(owner)
                if reqs:
                    conns[owner].send({
                        "type": "work", "rerouted": True,
                        "requests": [request_record(q) for q in reqs]})

        def evict(rank: int, now: float) -> None:
            live.discard(rank)
            silence = detector.silence(rank, now)
            phi = detector.phi(rank, now)
            detector.remove(rank)
            proc = procs.get(rank)
            if proc is not None and proc.poll() is None:
                try:                       # a stopped process can't die
                    proc.send_signal(signal.SIGCONT)
                except (OSError, ProcessLookupError):
                    pass
                proc.kill()
            if rank in conns:
                conns[rank].close()
            note(rank)["evict_ts"] = now
            if tracer.enabled:
                tracer.instant("host_evict", "host", ts=now, rank=rank,
                               silence_s=silence, phi=phi)
            moved = ledger.on_death({rank})
            survivors = sorted(ledger.members)
            if tracer.enabled:
                tracer.instant("ulfm_shrink", "group", ts=now, rank=rank,
                               survivors=survivors)
                tracer.instant("epoch", "group", ts=now, epoch=ledger.epoch,
                               members=survivors, reason="shrink")
                for rid, old, new in moved:
                    tracer.instant("reroute", "group", ts=now, request=rid,
                                   trace_id=ledger.requests[rid].trace_id,
                                   from_rank=old, to_rank=new)
            ship_rerouted(moved)
            for r in live:
                evict_notices[r].add(rank)

        def fire_faults(now: float) -> None:
            while pending_faults and retired_total >= pending_faults[0].step:
                spec = pending_faults.pop(0)
                rank = int(spec.rank)
                proc = procs.get(rank)
                if rank not in live or rank in done or proc is None \
                        or proc.poll() is not None:
                    continue               # target already gone: a no-op
                if spec.kind == "host_kill":
                    note(rank)["kill_ts"] = now
                    if tracer.enabled:
                        tracer.instant("host_kill", "host", ts=now, rank=rank,
                                       retired=retired_total)
                        tracer.instant("replica_kill", "group", ts=now,
                                       rank=rank)
                    proc.kill()            # SIGKILL: a genuinely lost process
                else:                      # host_stop: slow-but-alive
                    stopped.add(rank)
                    note(rank)["stop_ts"] = now
                    if tracer.enabled:
                        tracer.instant("host_stop", "host", ts=now, rank=rank,
                                       duration_s=spec.magnitude)
                    try:
                        proc.send_signal(signal.SIGSTOP)
                    except (OSError, ProcessLookupError):
                        continue

                    def resume(r=rank, p=proc):
                        try:
                            p.send_signal(signal.SIGCONT)
                        except (OSError, ProcessLookupError):
                            return
                        if tracer.enabled:
                            tracer.instant("host_resume", "host", rank=r)

                    t = threading.Timer(float(spec.magnitude), resume)
                    t.daemon = True
                    t.start()
                    timers.append(t)

        def handle(rank: int, msg: dict, now: float) -> None:
            nonlocal retired_total
            kind = msg.get("type")
            if kind == "hello":
                ready.add(rank)
                detector.register(rank, now)
                reqs = ledger.take(rank)
                conns[rank].send({
                    "type": "work", "rerouted": False,
                    "requests": [request_record(q) for q in reqs]})
                fire_faults(now)       # step-0 specs fire once targets exist
            elif kind == "hb":
                if rank not in live:
                    return
                b = beats.setdefault(rank, [now, now, 0])
                b[1] = now
                b[2] += 1
                if detector.heartbeat(rank, now):
                    resumed.add(rank)
                    if tracer.enabled:
                        tracer.instant("host_suspect_clear", "host", ts=now,
                                       rank=rank)
            elif kind == "exchange":
                if rank not in live:
                    return
                # star-topology emax: fold the worker's [remaining, epoch]
                # contribution with the supervisor's authoritative ledger view
                rem = max(ledger.remaining(), int(msg.get("remaining", 0)))
                agreed = max(ledger.epoch, int(msg.get("epoch", 0)))
                notices = sorted(evict_notices[rank])
                evict_notices[rank].clear()
                conns[rank].send({
                    "type": "reduce", "round": msg.get("round"),
                    "rem": rem, "epoch": agreed,
                    "members": sorted(ledger.members), "evicted": notices})
            elif kind == "retire":
                resp = response_from(msg["resp"])
                if ledger.complete(resp):
                    retired_total += 1
                    retires.append((now, rank, resp.id))
                    fire_faults(now)
                if rank in live:
                    conns[rank].send({"type": "retire_ack", "id": resp.id})
            elif kind == "trace":
                worker_events.extend(msg.get("events", ()))
            elif kind == "bye":
                done.add(rank)
                detector.remove(rank)
            elif kind == "_eof":
                # the socket died (SIGKILL closes it instantly on localhost);
                # death is only ever *declared* by the heartbeat detector —
                # real networks don't deliver EOFs — so just stop sending
                if rank in conns:
                    conns[rank].alive = False

        deadline = time.monotonic() + self.timeout
        failure: Optional[str] = None
        try:
            while True:
                now = time.monotonic()
                if now > deadline:
                    failure = (f"multihost serve timed out after "
                               f"{self.timeout}s: remaining="
                               f"{ledger.remaining()} live={sorted(live)} "
                               f"ready={sorted(ready)} done={sorted(done)}")
                    break
                if ready and (live & ready) <= done \
                        and ledger.remaining() == 0:
                    break
                if live <= done and ledger.remaining() > 0 and ready:
                    failure = (f"all workers finished but "
                               f"{ledger.remaining()} requests unanswered")
                    break
                try:
                    rank, msg = inbox.get(timeout=0.01)
                except queue.Empty:
                    rank, msg = -1, None
                now = time.monotonic()
                if msg is not None:
                    handle(rank, msg, now)
                newly, evictable = detector.poll(now)
                for r in newly:
                    if r in live:
                        suspected.add(r)
                        note(r)["suspect_ts"] = now
                        if tracer.enabled:
                            tracer.instant(
                                "host_suspect", "host", ts=now, rank=r,
                                silence_s=detector.silence(r, now),
                                phi=detector.phi(r, now))
                for r in evictable:
                    if r in live:
                        evict(r, now)
        finally:
            ledger.close()
            stop_accept.set()
            for t in timers:
                t.cancel()
            # drain stragglers (late byes / trace batches) briefly, then stop
            drain_until = time.monotonic() + 2.0
            while time.monotonic() < drain_until:
                try:
                    rank, msg = inbox.get(timeout=0.05)
                except queue.Empty:
                    if all(p.poll() is not None for p in procs.values()):
                        break
                    continue
                if msg.get("type") in ("trace", "bye", "retire"):
                    handle(rank, msg, time.monotonic())
            for r, c in conns.items():
                c.send({"type": "stop"})
            for r, p in procs.items():
                if p.poll() is None:
                    try:
                        p.send_signal(signal.SIGCONT)
                    except (OSError, ProcessLookupError):
                        pass
                try:
                    p.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    p.kill()
                    p.wait(timeout=5.0)
            for c in conns.values():
                c.close()
            try:
                listener.close()
            except OSError:
                pass
            if wal is not None:
                wal.close()

        if tracer.enabled:
            for r, (first, last, count) in sorted(beats.items()):
                mean = (last - first) / max(count - 1, 1)
                tracer.span("heartbeat", "host", first, last, rank=r,
                            beats=count, mean_interval_s=mean)

        if failure is not None:
            raise RuntimeError(failure)

        events = list(worker_events)
        events.extend(tracer.events())
        return MultiHostResult(
            responses=dict(ledger.responses),
            rerouted=tuple(ledger.rerouted),
            evicted=tuple(r for r in range(self.nranks) if r not in live),
            suspected=tuple(sorted(suspected)),
            resumed=tuple(sorted(resumed)),
            stopped=tuple(sorted(stopped)),
            epoch=ledger.epoch, detection=detection,
            retires=tuple(retires), events=events)


# -------------------------------------------------------------------- worker
class _SimBackend:
    """Deterministic arithmetic decode (no model, no jit): emits
    ``tokens_per_step`` tokens of :func:`sim_tokens` per step. Used by
    protocol/detector tests and the fuzzer's host-fault lanes."""

    def __init__(self, rank: int, *, tokens_per_step: int = 4,
                 step_delay_s: float = 0.0, vocab: int = _SIM_VOCAB,
                 tracer: Tracer = NULL_TRACER,
                 clock: Callable[[], float] = time.monotonic):
        self.rank = rank
        self.tokens_per_step = max(int(tokens_per_step), 1)
        self.step_delay_s = float(step_delay_s)
        self.vocab = int(vocab)
        self.tracer = tracer
        self.clock = clock
        self._inflight: dict[int, dict] = {}

    def submit(self, req: Request) -> Optional[Response]:
        now = self.clock()
        req.arrival_t = now
        if self.tracer.enabled and req.trace_id is None:
            req.trace_id = self.tracer.start_request(req, now)
        self._inflight[req.id] = {
            "req": req,
            "tokens": sim_tokens(req.prompt, req.max_new_tokens, self.vocab),
            "emitted": 0, "ttft": None}
        return None

    def step(self) -> list[Response]:
        if self.step_delay_s:
            time.sleep(self.step_delay_s)
        out: list[Response] = []
        for rid in list(self._inflight):
            st = self._inflight[rid]
            if st["emitted"] == 0 and st["tokens"]:
                st["ttft"] = self.clock() - st["req"].arrival_t
            st["emitted"] = min(st["emitted"] + self.tokens_per_step,
                                len(st["tokens"]))
            if st["emitted"] >= len(st["tokens"]):
                now = self.clock()
                req = st["req"]
                resp = Response(
                    id=rid, status=OK, tokens=tuple(st["tokens"]),
                    latency_s=now - req.arrival_t, ttft_s=st["ttft"],
                    replica=self.rank, trace_id=req.trace_id)
                if self.tracer.enabled:
                    self.tracer.end_request(resp, now)
                out.append(resp)
                del self._inflight[rid]
        return out

    def load(self) -> int:
        return len(self._inflight)


class _ReplicaBackend:
    """The real engine: one :class:`~repro.serve.replica.Replica` per worker
    process, params rebuilt from the shared PRNGKey so token streams are
    bit-exact across process boundaries."""

    def __init__(self, spec: dict, tracer: Tracer):
        import jax

        from ..configs import smoke_config
        from ..models import build_model
        from .replica import Replica
        cfg = smoke_config(spec["arch"])
        params = build_model(cfg).init(
            jax.random.PRNGKey(int(spec.get("seed", 0))))
        engine = dict(spec.get("engine") or {})
        engine.pop("trace", None)          # workers trace via the tracer obj
        engine.pop("trace_sample", None)
        self.replica = Replica(cfg, params=params,
                               config=EngineConfig(**engine),
                               rank=int(spec["rank"]), tracer=tracer)
        self.replica.warmup()

    def submit(self, req: Request) -> Optional[Response]:
        return self.replica.submit(req)

    def step(self) -> list[Response]:
        return self.replica.step()

    def load(self) -> int:
        return self.replica.load() + len(self.replica.queue)


class _Stop(Exception):
    pass


def worker_main(argv: Optional[Sequence[str]] = None) -> int:
    """One worker process: connect, warm up, say hello, heartbeat, serve.

    The ``hello`` is sent only *after* the backend finished warming up (jit
    compiles included), so compile pauses can never read as missed
    heartbeats — the lease only starts once the worker is actually able to
    honour it.
    """
    import argparse
    parser = argparse.ArgumentParser(prog="worker")
    parser.add_argument("--worker", action="store_true",
                        help="compatibility no-op (python -m launch path)")
    parser.add_argument("--spec", required=True,
                        help="JSON worker spec from the supervisor")
    args = parser.parse_args(argv)
    spec = json.loads(args.spec)
    rank = int(spec["rank"])
    io_timeout = float(spec.get("io_timeout", 120.0))

    # cross-host runtime, gated: localhost CPU workers run standalone
    coord = spec.get("jax_coordinator")
    if coord:
        try:
            import jax
            jax.distributed.initialize(coordinator_address=coord,
                                       num_processes=int(spec["nranks"]),
                                       process_id=rank)
        except Exception:
            pass

    tracer = Tracer(pid=rank) if spec.get("trace") else NULL_TRACER
    if spec.get("backend") == "replica":
        backend = _ReplicaBackend(spec, tracer)
    else:
        sim = spec.get("sim") or {}
        backend = _SimBackend(
            rank, tokens_per_step=int(sim.get("tokens_per_step", 4)),
            step_delay_s=float(sim.get("step_delay_s", 0.0)),
            vocab=int(sim.get("vocab", _SIM_VOCAB)), tracer=tracer)

    sock = socket.create_connection(("127.0.0.1", int(spec["port"])),
                                    timeout=io_timeout)
    send_lock = threading.Lock()
    send_msg(sock, {"type": "hello", "rank": rank}, send_lock)

    stop_hb = threading.Event()
    hb_interval = float(spec.get("heartbeat_interval", 0.05))

    def hb_loop() -> None:
        while not stop_hb.wait(hb_interval):
            try:
                send_msg(sock, {"type": "hb", "rank": rank}, send_lock)
            except OSError:
                return

    threading.Thread(target=hb_loop, daemon=True).start()

    inq: queue.Queue = queue.Queue()

    def read_loop() -> None:
        while True:
            try:
                msg = recv_msg(sock)
            except OSError:
                msg = None
            inq.put(msg)
            if msg is None:
                return

    threading.Thread(target=read_loop, daemon=True).start()

    def retire(resp: Response) -> None:
        send_msg(sock, {"type": "retire", "rank": rank,
                        "resp": response_record(resp)}, send_lock)

    def dispatch(msg: Optional[dict]) -> Optional[dict]:
        """Apply a pushed message; returns it when it is a ``reduce`` the
        round loop is waiting for."""
        if msg is None:
            raise _Stop("supervisor connection lost")
        kind = msg.get("type")
        if kind == "work":
            for rec in msg.get("requests", ()):
                rej = backend.submit(request_from(rec))
                if rej is not None:
                    retire(rej)
            return None
        if kind == "stop":
            raise _Stop("stop requested")
        if kind == "reduce":
            return msg
        return None          # retire_ack and anything future-compatible

    my_epoch = 0
    group_word = 0
    round_i = 0
    rc = 0
    try:
        while True:
            try:
                while True:
                    dispatch(inq.get_nowait())
            except queue.Empty:
                pass
            for resp in backend.step():
                retire(resp)
            send_msg(sock, {"type": "exchange", "rank": rank,
                            "round": round_i, "remaining": backend.load(),
                            "epoch": my_epoch}, send_lock)
            reduce_msg = None
            wait_until = time.monotonic() + io_timeout
            while reduce_msg is None:
                left = wait_until - time.monotonic()
                if left <= 0:
                    raise RuntimeError(
                        f"worker {rank}: no reduce for round {round_i} "
                        f"within {io_timeout}s")
                try:
                    reduce_msg = dispatch(inq.get(timeout=min(left, 1.0)))
                except queue.Empty:
                    continue
            for dead in reduce_msg.get("evicted", ()):
                # the supervisor declared a peer dead: latch RANK_FAILED into
                # this worker's group error word — the remote fault mapped to
                # the same local code an in-band probe would latch
                group_word |= int(ErrorCode.RANK_FAILED)
                if tracer.enabled:
                    tracer.instant("rank_failed", "group", rank=rank,
                                   dead=int(dead),
                                   code=int(ErrorCode.RANK_FAILED))
            decision = agree_round(int(reduce_msg["rem"]),
                                   int(reduce_msg["epoch"]), my_epoch)
            if decision.action == "reconfigure":
                my_epoch = decision.epoch
            elif decision.action == "close":
                break
            elif backend.load() == 0:
                time.sleep(0.002)      # idle but the fleet isn't done yet
            round_i += 1
    except _Stop:
        pass
    except (OSError, RuntimeError):
        rc = 1
    finally:
        stop_hb.set()
        try:
            if tracer.enabled:
                send_msg(sock, {"type": "trace", "rank": rank,
                                "events": tracer.events()}, send_lock)
            send_msg(sock, {"type": "bye", "rank": rank,
                            "word": group_word}, send_lock)
        except OSError:
            rc = 1
        try:
            sock.close()
        except OSError:
            pass
    return rc


if __name__ == "__main__":
    raise SystemExit(worker_main())
