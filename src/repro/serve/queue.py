"""Request/response types, admission control and the deadline-aware queue.

The serving analogue of the paper's contract is applied at the *request*
granularity: a request either gets an answer or an explicit terminal status —
never a silent drop, never a hang. Statuses:

* ``OK``       — decoded to completion;
* ``REJECTED`` — refused at admission (queue full / does not fit the cache);
* ``EXPIRED``  — deadline passed before completion;
* ``FAILED``   — unrecoverable after the retry budget (poisoned cache that
  re-faults on every recompute — the serving counterpart of ABORT).

The queue orders by earliest deadline first (EDF) with FIFO tie-break, and is
thread-safe because a :class:`~repro.serve.group.ServeGroup` re-routes a dead
replica's requests into survivor queues from other rank threads.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from ..obs.trace import NULL_TRACER, Tracer

# Terminal request statuses.
OK = "ok"
REJECTED = "rejected"
EXPIRED = "expired"
FAILED = "failed"


@dataclass
class Request:
    """One generation request (mutable: the scheduler tracks retries on it)."""

    id: int
    prompt: tuple[int, ...]
    max_new_tokens: int = 16
    deadline: Optional[float] = None     # absolute, in the queue's clock domain
    arrival_t: Optional[float] = None    # stamped once by RequestQueue.submit
    retries: int = 0                     # LFLR recomputes consumed so far
    trace_id: Optional[int] = None       # stamped once by RequestQueue.submit
                                         # (None = untraced / sampled out);
                                         # survives re-routes and requeues so
                                         # post-mortems see one causal chain

    def __post_init__(self):
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.max_new_tokens


@dataclass(frozen=True)
class Response:
    """Terminal answer for one request."""

    id: int
    status: str                          # OK | REJECTED | EXPIRED | FAILED
    tokens: tuple[int, ...] = ()         # generated tokens (no prompt)
    latency_s: float = 0.0               # submit → terminal
    ttft_s: Optional[float] = None       # submit → first generated token
    retries: int = 0                     # faults recovered while serving it
    replica: Optional[int] = None        # rank that answered it
    detail: str = ""
    trace_id: Optional[int] = None       # the request's trace id, if traced

    @property
    def ok(self) -> bool:
        return self.status == OK


@dataclass(frozen=True)
class AdmissionPolicy:
    """Static admission checks, applied before a request ever holds a slot."""

    max_queue: int = 256
    max_total_len: int = 4096            # prompt + max_new must fit the cache

    def reject_reason(self, req: Request, queue_len: int) -> Optional[str]:
        if queue_len >= self.max_queue:
            return f"queue full ({queue_len}/{self.max_queue})"
        if req.total_len > self.max_total_len:
            return (f"request needs {req.total_len} cache positions, "
                    f"capacity is {self.max_total_len}")
        return None


class RequestQueue:
    """Deadline-aware (EDF) admission queue.

    ``submit`` returns ``None`` on acceptance or a terminal ``REJECTED``
    response; ``pop`` returns the most urgent request that can still meet its
    deadline and reports the ones that cannot via ``drain_expired``.
    """

    def __init__(self, policy: AdmissionPolicy | None = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 tracer: Tracer | None = None):
        self.policy = policy or AdmissionPolicy()
        self.clock = clock
        self.tracer = tracer or NULL_TRACER
        self._lock = threading.Lock()
        self._heap: list[tuple[float, int, Request]] = []
        self._seq = itertools.count()
        self._rseq = itertools.count(-1, -1)   # requeue: ahead of same-deadline
        self._expired: list[Request] = []

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)

    def submit(self, req: Request) -> Optional[Response]:
        now = self.clock()
        with self._lock:
            reason = self.policy.reject_reason(req, len(self._heap))
            if reason is not None:
                if self.tracer.enabled:
                    self.tracer.instant("reject", "request", ts=now,
                                        request_id=req.id, reason=reason)
                return Response(id=req.id, status=REJECTED, detail=reason)
            stamp = req.arrival_t is None
            if stamp:
                # stamp once: a request re-routed after a replica kill keeps
                # its original acceptance time, so latency/TTFT include the
                # whole fault-recovery delay — and its trace id, so the
                # post-mortem stitches both replicas into one causal chain
                req.arrival_t = now
            key = req.deadline if req.deadline is not None else float("inf")
            heapq.heappush(self._heap, (key, next(self._seq), req))
        if stamp and self.tracer.enabled and req.trace_id is None:
            req.trace_id = self.tracer.start_request(req, now)
        elif not stamp and self.tracer.enabled and req.trace_id is not None:
            # re-submission of an already-accepted request (ledger re-route
            # after a kill): a causal hop, not a new request
            self.tracer.instant("resubmit", "request", ts=now,
                                trace_id=req.trace_id)
        return None

    def requeue(self, req: Request) -> None:
        """Put an *already accepted* request back in the queue, ahead of its
        deadline class (negative sequence keys sort before every submitted
        entry with the same deadline, newest requeue first).

        This is the zero-drop re-queue path: admission checks are bypassed —
        the request was admitted once and must eventually get a terminal
        answer — and ``arrival_t`` is preserved, so latency/TTFT span the
        preemption (same contract as the group ledger's re-route). Used when
        a serving slot is preempted, e.g. paged-KV eviction under memory
        pressure.
        """
        assert req.arrival_t is not None, "requeue is for accepted requests"
        if self.tracer.enabled and req.trace_id is not None:
            self.tracer.instant("requeue", "sched", trace_id=req.trace_id)
        with self._lock:
            key = req.deadline if req.deadline is not None else float("inf")
            heapq.heappush(self._heap, (key, next(self._rseq), req))

    def submit_all(self, reqs: Iterable[Request]) -> list[Response]:
        """Submit many; returns the rejections (accepted ones return later)."""
        out = []
        for r in reqs:
            resp = self.submit(r)
            if resp is not None:
                out.append(resp)
        return out

    def pop(self, now: Optional[float] = None) -> Optional[Request]:
        """Earliest-deadline request still able to start; expired ones are set
        aside for ``drain_expired``."""
        now = self.clock() if now is None else now
        with self._lock:
            while self._heap:
                deadline, _, req = heapq.heappop(self._heap)
                if req.deadline is not None and now >= req.deadline:
                    self._expired.append(req)
                    continue
                return req
            return None

    def drain_expired(self, now: Optional[float] = None) -> list[Request]:
        """All queued requests whose deadline has passed (removed from queue)."""
        now = self.clock() if now is None else now
        with self._lock:
            keep: list[tuple[float, int, Request]] = []
            for entry in self._heap:
                req = entry[2]
                if req.deadline is not None and now >= req.deadline:
                    self._expired.append(req)
                else:
                    keep.append(entry)
            heapq.heapify(keep)
            self._heap = keep
            out, self._expired = self._expired, []
            return out
