"""Serving replica: fused slot-decode behind a DeviceFuture, per-sequence LFLR.

One replica owns a fixed-slot continuous batch over
:func:`~repro.launch.steps.make_slot_decode_step`. Every dispatched step is
wrapped in a :class:`~repro.core.device_channel.DeviceFuture`; the per-slot
error words run through the paper's enumeration algorithm so the
``PropagatedError`` raised at the wait carries exact ``(slot, code)`` pairs.

With ``window=K`` the hot path moves to the **zero-sync decode window**
(:func:`~repro.launch.steps.make_decode_window`): K greedy steps run fully on
device, fault detection is deferred to the window boundary (the paper's
asynchrony contract — errors latch in-band, raise at the *wait*), and the
commit loop is **double-buffered**: window N+1 is dispatched from window N's
device-resident outputs (next token + donated caches) *before* window N's
token block is read back, so the device never idles on a host round trip.
Host syncs scale with ``steps / K`` instead of ``steps``. EOS / deadline /
faulted slots are handled at window boundaries: trailing tokens are
discarded, freed lanes are backfilled, and the already-in-flight speculative
window is patched — its stale lanes are marked invalid and simply skipped at
its own retirement.

With ``overlap=True`` (the default in window mode) admission and LFLR
recovery become **background prefill lanes** driven by the scheduler: instead
of a blocking full-length prefill between windows, a joining or recovering
slot's pending sequence is chunked into the *fused* decode+prefill window
(:func:`~repro.launch.steps.make_prefill_decode_window`) — the token stream
of the healthy slots never stalls, and the lane flips to decoding inside the
window whose chunk consumes its last pending token (bit-exact vs the blocking
path, since both compute the first token as the argmax after the last prompt
token through the same decode step). A fault latched during a chunk is
attributed through the same ``(K, slots)`` history and re-queues the lane
(cache reset + chunk from position 0) without a single host sync.

Recovery is the paper's use-case 1 applied to inference:

* ``STATE_FAULT`` (bit-flipped recurrent state) or non-finite logits on slot
  *i* → **LFLR re-prefill**: recompute slot *i*'s cache from its prompt +
  already-generated tokens (greedy decode is deterministic, so this recreates
  the pre-fault trajectory exactly) — the other slots commit their tokens and
  never notice;
* the :class:`~repro.core.recovery.RecoveryPolicy` escalates: repeated faults
  inside its window recompute *every* lane (the rollback analogue), and a
  request that re-faults past ``max_request_retries`` is answered ``FAILED``
  (the serving ABORT — one poisoned request must not wedge the replica).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.detect import ProbeConfig
from ..core.device_channel import WORD_DTYPE, DeviceFuture
from ..core.errors import PropagatedError
from ..core.recovery import Action, RecoveryPolicy
from ..launch.steps import (
    make_cache_prefill,
    make_decode_window,
    make_prefill_decode_window,
    make_slot_decode_step,
)
from ..models import build_model
from .metrics import ServeMetrics
from .queue import EXPIRED, FAILED, AdmissionPolicy, Request, RequestQueue, Response
from .scheduler import ContinuousBatchingScheduler

# CPU/interpret backends fall back to the fused-by-XLA probe oracle anyway;
# forcing it keeps the vmapped step portable (see kernels/fault_probe/ops.py).
SERVE_PROBES = ProbeConfig(use_kernel=False)


@functools.lru_cache(maxsize=None)
def make_enum_fn(num_slots: int):
    """Jitted ``(words, mask) -> (combined, count, table)`` over the slot axis.

    Free slots are masked out (their caches may hold stale values from an
    evicted sequence), then the paper's enumeration attributes each remaining
    word to its slot. ``max_errors=num_slots`` so attribution never truncates.
    Cached per slot count, so a fleet of replicas compiles it once.
    """
    from ..core.device_channel import combine_words, enumerate_errors_ref

    @jax.jit
    def enum(words, mask):
        words = words.astype(WORD_DTYPE) * mask.astype(WORD_DTYPE)
        combined = combine_words(*(words[i] for i in range(num_slots)))
        count, table = enumerate_errors_ref(words, max_errors=num_slots)
        return combined, count, table

    return enum


@functools.lru_cache(maxsize=None)
def make_window_enum_fn(num_slots: int):
    """Jitted ``(history (K, S), mask (S,)) -> (combined, count, table, hist)``.

    The window variant of :func:`make_enum_fn`: free slots are masked out of
    the whole ``(K, slots)`` word history, per-slot words are OR-folded over
    the window (deferred detection — one check per K tokens), and the fold is
    handed to the *same* per-slot enumeration the stepwise engine uses, so
    the two engines cannot diverge in attribution semantics. The masked
    history rides along so :meth:`DeviceFuture.fault_steps` can attribute a
    fault to its exact ``(step, slot)`` on the (rare) fault path only.
    """
    slot_enum = make_enum_fn(num_slots)

    @jax.jit
    def enum(history, mask):
        hist = history.astype(WORD_DTYPE) * mask.astype(WORD_DTYPE)[None, :]
        words = jax.lax.reduce(hist, jnp.uint32(0), jax.lax.bitwise_or, (0,))
        combined, count, table = slot_enum(words, jnp.ones_like(mask))
        return combined, count, table, hist

    return enum


@dataclass
class _WindowInFlight:
    """One dispatched decode window awaiting retirement.

    ``req_ids`` snapshots which request occupied each slot at dispatch (None =
    free lane); a lane's token block only commits if the same request still
    holds the slot at retirement. ``valid`` is cleared for a lane when the
    host patches its device state (LFLR re-prefill / backfill) while this
    window is already in flight — the lane's tokens *and its error words* are
    then stale and are skipped wholesale at retirement. ``start`` is the first
    committable step per lane: 0 for a decoding slot, ``rem - 1`` for a lane
    whose prompt chunk exhausts at step ``rem - 1`` (its argmax there is the
    first real token), K for a lane still mid-prefill (nothing committable).
    """

    fut: DeviceFuture
    req_ids: tuple
    valid: np.ndarray
    start: np.ndarray


class Replica:
    """One continuous-batching serving replica (single host / rank)."""

    def __init__(self, cfg: ModelConfig, params: Any = None, *,
                 num_slots: int = 4, max_len: int = 64,
                 queue: RequestQueue | None = None,
                 policy: RecoveryPolicy | None = None,
                 metrics: ServeMetrics | None = None,
                 probe_cfg: ProbeConfig = SERVE_PROBES,
                 max_request_retries: int = 2,
                 rank: int = 0, seed: int = 0, eos_id: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 decode_fn: Callable | None = None,
                 prefill_fn: Callable | None = None,
                 window: int = 0, donate: bool = True,
                 window_fn: Callable | None = None,
                 overlap: bool = True,
                 prefill_budget: Optional[int] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.max_len = max_len
        self.rank = rank
        self.clock = clock
        self.policy = policy or RecoveryPolicy()
        self.metrics = metrics or ServeMetrics(clock=clock)
        self.max_request_retries = max_request_retries
        # jitted step functions are shareable across replicas (ServeGroup
        # builds them once so N rank threads compile once, not N times)
        self._decode = decode_fn or jax.jit(
            make_slot_decode_step(cfg, probe_cfg))
        self._prefill = prefill_fn or make_cache_prefill(cfg, probe_cfg,
                                                         fused=bool(window))
        self._enum = make_enum_fn(num_slots)
        # fused one-dispatch insertion of a rebuilt per-sequence cache into the
        # slot-stacked caches (the un-jitted tree_map was one dispatch per
        # leaf); the window-mode device token feed rides in the same dispatch
        self._insert = jax.jit(
            lambda full, one, slot, dev_toks, tok: (
                jax.tree_util.tree_map(
                    lambda f, o: f.at[slot].set(o.astype(f.dtype)), full, one),
                dev_toks.at[slot, 0, 0].set(tok)))
        self.queue = queue or RequestQueue(
            AdmissionPolicy(max_total_len=max_len), clock=clock)
        self.sched = ContinuousBatchingScheduler(
            num_slots, self.queue, replica=rank, eos_id=eos_id, clock=clock,
            prefill_budget=prefill_budget)
        # stacked per-sequence (batch=1) caches, leading slot axis
        one = self.model.init_cache(1, max_len)
        self.caches = jax.tree_util.tree_map(
            lambda v: jnp.broadcast_to(v[None], (num_slots, *v.shape)).copy(),
            one)
        self._slot_logits = jnp.zeros((num_slots, 1, 1, cfg.vocab_size),
                                      jnp.float32)
        self._step_count = 0
        # ---- zero-sync decode windows (window=K > 0) ----------------------
        self.window = int(window)
        self.overlap = bool(self.window) and bool(overlap)
        if self.window:
            self._decode_window = window_fn or (
                make_prefill_decode_window(cfg, probe_cfg, window=self.window,
                                           donate=donate)
                if self.overlap else
                make_decode_window(cfg, probe_cfg, window=self.window,
                                   donate=donate))
            self._wenum = make_window_enum_fn(num_slots)
        if self.overlap:
            # fresh per-sequence cache template + fused one-dispatch reset of
            # one lane's slice of the stacked caches — the overlapped
            # admission/LFLR restart point (async, never a host sync)
            self._fresh = one
            self._reset = jax.jit(
                lambda full, fresh, slot: jax.tree_util.tree_map(
                    lambda f, o: f.at[slot].set(o.astype(f.dtype)),
                    full, fresh),
                donate_argnums=(0,))    # in-place slice update, no cache copy
        self._pending: Optional[_WindowInFlight] = None
        # device-resident feed for the next window (token chain never leaves
        # the device) + host-tracked dispatch positions
        self._dev_tokens = jnp.zeros((num_slots, 1, 1), jnp.int32)
        self._dev_pos = np.zeros((num_slots,), np.int32)

    # ---------------------------------------------------------------- warmup
    def warmup(self, *, max_new: int = 8) -> None:
        """Compile every hot-path program before real traffic: one throwaway
        request end-to-end covers prefill (the fused variant compiles once
        for *all* lengths), decode/window and commit. Swaps in fresh metrics
        afterwards so compile time never pollutes reported numbers."""
        assert self.idle(), "warmup must run before traffic is admitted"
        req = Request(id=-1, prompt=(1, 2, 3),
                      max_new_tokens=min(max_new, self.max_len - 4))
        assert self.submit(req) is None
        self.run()
        self.metrics = ServeMetrics(clock=self.clock)

    # ------------------------------------------------------------- submission
    def submit(self, req: Request) -> Optional[Response]:
        """Admit a request; returns a ``REJECTED`` response or None (accepted).
        Every accepted request is eventually answered by ``step``/``run``."""
        resp = self.queue.submit(req)
        if resp is not None:
            self.metrics.record_response(resp)
        return resp

    # ---------------------------------------------------------- fault surface
    def inject_state_fault(self, slot: Optional[int] = None) -> Optional[int]:
        """Simulated SDC (paper §II-A): NaN one element of a slot's recurrent
        state on device. ``slot=None`` picks the first active slot. Returns the
        poisoned slot, or None if there was nothing to poison."""
        if slot is None:
            active = self.sched.active_slots()
            if not active:
                return None
            slot = active[0]
        hit = []

        def poison(path, leaf):
            keys = [getattr(k, "key", None) for k in path]
            if any(k in ("h", "ssm") for k in keys) and leaf.ndim >= 1:
                hit.append(True)
                return leaf.at[(slot,) + (0,) * (leaf.ndim - 1)].set(jnp.nan)
            return leaf

        poisoned = jax.tree_util.tree_map_with_path(poison, self.caches)
        if not hit:
            raise ValueError(
                f"{self.cfg.name}: no recurrent state to poison "
                "(attention-only arch — flip a KV bit instead)")
        self.caches = poisoned
        return slot

    # ------------------------------------------------------------- step cycle
    def step(self) -> list[Response]:
        """One scheduler cycle: expire → backfill/prefill → fused decode →
        commit. Returns every request answered during the cycle."""
        now = self.clock()
        out: list[Response] = []
        for req in self.queue.drain_expired(now):
            out.append(Response(id=req.id, status=EXPIRED,
                                latency_s=now - req.arrival_t,
                                replica=self.rank,
                                detail="deadline passed in queue"))
        out.extend(self.sched.expire_active(now))
        for slot, _req in self.sched.backfill(now):
            if self.overlap:
                # admission is a background lane: the scheduler chunks the
                # prompt into subsequent decode windows — no blocking prefill
                self.sched.begin_prefill(slot)
            else:
                resp = self._prefill_slot(slot)
                if resp is not None:
                    out.append(resp)
        if self.window:
            if self.sched.has_active() or self._pending is not None:
                out.extend(self._window_cycle())
        elif self.sched.has_active():
            out.extend(self._decode_step())
        for resp in out:
            self.metrics.record_response(resp)
        return out

    def run(self, *, max_steps: int = 100_000) -> list[Response]:
        """Serve until the queue and all slots drain; returns all responses.

        Raises instead of returning if ``max_steps`` is exhausted with work
        still pending — an accepted request is never silently dropped.
        """
        out: list[Response] = []
        for _ in range(max_steps):
            if self.idle():
                return out
            out.extend(self.step())
        if not self.idle():
            raise RuntimeError(
                f"replica {self.rank}: {len(self.queue)} queued + "
                f"{self.sched.in_flight()} in-flight requests unanswered "
                f"after {max_steps} steps")
        return out

    def idle(self) -> bool:
        return (not len(self.queue) and not self.sched.has_active()
                and self._pending is None)

    # ------------------------------------------------------------ decode path
    def _decode_step(self) -> list[Response]:
        self._step_count += 1
        tokens, pos = self.sched.step_inputs()
        mask = self.sched.active_mask()
        logits, caches, words = self._decode(
            self.params, self.caches, jnp.asarray(tokens), jnp.asarray(pos))
        combined, count, table = self._enum(words, jnp.asarray(mask))
        fut = DeviceFuture(outputs=(logits, caches), word=combined,
                           count=count, table=table)
        try:
            logits, caches = fut.wait()
            self._slot_logits, self.caches = logits, caches
            return self._commit(skip=frozenset())
        except PropagatedError as exc:
            return self._recover(exc, fut)

    def _commit(self, skip: frozenset[int]) -> list[Response]:
        now = self.clock()
        out = []
        # argmax on device: ship S int32s to the host, not S×V logits
        toks = np.asarray(jax.device_get(
            jnp.argmax(self._slot_logits[:, 0, 0, :], axis=-1)))
        committed = 0
        for slot in self.sched.active_slots():
            if slot in skip:
                continue
            resp = self.sched.commit_token(slot, int(toks[slot]), now)
            committed += 1
            if resp is not None:
                out.append(resp)
        self.metrics.record_step(committed)
        return out

    # --------------------------------------------------------- window engine
    def _window_cycle(self) -> list[Response]:
        """Double-buffered commit loop: dispatch window N+1 from window N's
        device-resident outputs *before* reading back window N's tokens."""
        prev = self._pending
        self._pending = (self._dispatch_window()
                         if self.sched.has_active() else None)
        return self._retire_window(prev) if prev is not None else []

    def _dispatch_window(self) -> _WindowInFlight:
        self._step_count += 1
        sched = self.sched
        K = self.window
        mask = sched.active_mask()
        start = np.zeros(sched.num_slots, np.int64)
        if self.overlap:
            chunk = np.zeros((K, sched.num_slots), np.int32)
            rem = np.zeros((sched.num_slots,), np.int32)
            for slot, cp in sched.plan_prefill(K).items():
                if cp.rem == 0:
                    # deferred fresh lane: no valid state yet — fully masked
                    mask[slot] = 0
                    start[slot] = K
                    continue
                if cp.fresh:
                    # lane (re)start: fresh cache slice + position 0, both
                    # queued on the device chain — never a host sync
                    self.caches = self._reset(self.caches, self._fresh,
                                              jnp.int32(slot))
                    self._dev_pos[slot] = 0
                chunk[:cp.rem, slot] = cp.tokens
                rem[slot] = cp.rem
                start[slot] = cp.rem - 1 if cp.exhausts else K
                self.metrics.record_chunk(cp.rem)
            toks, words, next_tok, caches = self._decode_window(
                self.params, self.caches, self._dev_tokens,
                jnp.asarray(self._dev_pos), jnp.asarray(chunk),
                jnp.asarray(rem))
        else:
            toks, words, next_tok, caches = self._decode_window(
                self.params, self.caches, self._dev_tokens,
                jnp.asarray(self._dev_pos))
        # the device-side chain advances: window N+1 consumes these directly
        self.caches = caches
        self._dev_tokens = next_tok
        self._dev_pos = self._dev_pos + K
        combined, count, table, hist = self._wenum(words, jnp.asarray(mask))
        fut = DeviceFuture(outputs=toks, word=combined, count=count,
                           table=table, history=hist)
        return _WindowInFlight(
            fut=fut,
            req_ids=tuple(s.req.id if s.active else None for s in sched.slots),
            valid=np.ones(sched.num_slots, bool),
            start=start)

    def _retire_window(self, win: _WindowInFlight) -> list[Response]:
        if not win.fut.done():
            # the device is still computing this window at its retirement —
            # the pipeline, not the host, is the bottleneck right now
            self.metrics.record_window_wait()
        try:
            tok_block = win.fut.wait()
        except PropagatedError as exc:
            return self._recover_window(win, exc)
        toks = np.asarray(jax.device_get(tok_block))
        return self._commit_window(win, toks)

    def _commit_window(self, win: _WindowInFlight, toks: np.ndarray,
                       limits: Optional[np.ndarray] = None) -> list[Response]:
        """Commit each lane's token block from its first real step
        (``win.start`` — past any prompt-chunk feed) up to EOS / token budget /
        its fault boundary (``limits``); trailing tokens are discarded. Lanes
        whose request left the slot since dispatch (finished, expired,
        re-routed) or whose state was patched mid-flight (``valid`` cleared)
        are skipped."""
        now = self.clock()
        K = self.window
        out: list[Response] = []
        committed = discarded = 0
        for slot, rid in enumerate(win.req_ids):
            if rid is None:
                continue                         # lane was free at dispatch
            lo = int(win.start[slot])            # prompt-feed steps emit no
            s = self.sched.slots[slot]           # committable tokens
            if not s.active or s.req.id != rid or not win.valid[slot]:
                discarded += K - lo
                continue
            limit = K if limits is None else int(limits[slot])
            k, done = (self.sched.commit_block(slot, toks[lo:limit, slot], now)
                       if limit > lo else (0, None))
            committed += k
            discarded += (K - lo) - k
            if done is not None:
                out.append(done)
        self.metrics.record_window(committed, discarded, K)
        return out

    def _recover_window(self, win: _WindowInFlight,
                        exc: PropagatedError) -> list[Response]:
        """Deferred-detection recovery: the ``(K, slots)`` history attributes
        the fault to its exact ``(step, slot)``; the clean prefix before the
        fault step commits (it is part of the deterministic greedy trajectory)
        and only the faulted suffix is recomputed via LFLR re-prefill."""
        num_slots = self.sched.num_slots
        K = self.window
        faulted = sorted({e.rank for e in exc.errors if 0 <= e.rank < num_slots})
        if not faulted:                      # unattributed word: assume all
            faulted = list(self.sched.active_slots())
        # a lane patched while this window was in flight re-reports its old
        # fault (the window *computed* with the poisoned state even though the
        # state has since been repaired) — stale, already recovered: drop it
        faulted = [s for s in faulted if win.valid[s]]
        toks = np.asarray(jax.device_get(win.fut.outputs))
        if not faulted:
            return self._commit_window(win, toks)
        decision = self.policy.decide(exc, self._step_count)
        self.metrics.record_fault(self._step_count, int(exc.combined_code),
                                  decision.action.value, tuple(faulted))
        steps = win.fut.fault_steps()        # first faulting step per slot
        limits = np.full(num_slots, K, np.int64)
        for slot in faulted:
            limits[slot] = steps[slot] if steps is not None and steps[slot] >= 0 else 0
        if decision.action is Action.ROLLBACK:
            targets, fail_now = list(self.sched.active_slots()), False
        elif decision.action is Action.ABORT:
            targets, fail_now = faulted, True
        else:   # SKIP_BATCH / RESTORE_GOOD / CONTINUE / ... → per-sequence LFLR
            targets, fail_now = faulted, False
        out = self._commit_window(win, toks, limits=limits)
        faulted_set = set(faulted)
        for slot in targets:
            s = self.sched.slots[slot]
            if not s.active or s.req.id != win.req_ids[slot]:
                continue                     # finished/evicted inside its prefix
            if slot in faulted_set:
                retries = self.sched.note_retry(slot)
            else:
                retries = self.sched.request(slot).retries
            if fail_now or retries > self.max_request_retries:
                out.append(self.sched.evict(
                    slot, FAILED,
                    detail=f"{decision.reason} (retries={retries})"))
                if self._pending is not None:
                    # the in-flight speculative window computed with the same
                    # poisoned state; without a prefill patch clearing it, its
                    # lane would re-raise this fault as a new one at retire
                    self._pending.valid[slot] = False
                continue
            resp = self._lflr_slot(slot)     # LFLR: recompute, don't restart
            if resp is not None:
                out.append(resp)
        return out

    def _lflr_slot(self, slot: int) -> Optional[Response]:
        """Window-mode LFLR recompute for one lane.

        Overlapped: re-queue the lane — the scheduler chunks prompt +
        committed tokens back into the cache through subsequent fused windows
        (the cache reset rides the next dispatch), and the in-flight
        speculative window's stale lane is invalidated. The host never blocks.
        Blocking mode: the synchronous re-prefill."""
        if not self.overlap:
            return self._prefill_slot(slot)
        self.sched.begin_prefill(slot)
        if self._pending is not None:
            self._pending.valid[slot] = False
        return None

    # --------------------------------------------------------------- recovery
    def _recover(self, exc: PropagatedError, fut: DeviceFuture) -> list[Response]:
        decision = self.policy.decide(exc, self._step_count)
        num_slots = self.sched.num_slots
        faulted = sorted({e.rank for e in exc.errors if 0 <= e.rank < num_slots})
        if not faulted:                      # unattributed word: assume all
            faulted = list(self.sched.active_slots())
        self.metrics.record_fault(self._step_count, int(exc.combined_code),
                                  decision.action.value, tuple(faulted))
        # Slots are independent under vmap: the dispatched outputs of the
        # non-faulted slots are valid, so salvage them and only recompute the
        # attributed ones — this is what keeps one bad sequence from stalling
        # the whole batch.
        self._slot_logits, self.caches = fut.outputs
        if decision.action is Action.ROLLBACK:
            # escalation: recompute every lane (whole-batch recompute is the
            # serving analogue of restoring the last checkpoint)
            targets, fail_now = list(self.sched.active_slots()), False
        elif decision.action is Action.ABORT:
            targets, fail_now = faulted, True
        else:   # SKIP_BATCH / RESTORE_GOOD / CONTINUE / ... → per-sequence LFLR
            targets, fail_now = faulted, False
        out = self._commit(skip=frozenset(targets))
        faulted_set = set(faulted)
        for slot in targets:
            if not self.sched.slots[slot].active:
                continue                     # already evicted this cycle
            # only the slots the enumeration attributed pay a retry: a healthy
            # lane swept into a ROLLBACK recompute must not burn its budget
            # (FAILED is reserved for requests that re-fault on recompute)
            if slot in faulted_set:
                retries = self.sched.note_retry(slot)
            else:
                retries = self.sched.request(slot).retries
            if fail_now or retries > self.max_request_retries:
                out.append(self.sched.evict(
                    slot, FAILED,
                    detail=f"{decision.reason} (retries={retries})"))
                continue
            resp = self._prefill_slot(slot)  # LFLR: recompute, don't restart
            if resp is not None:
                out.append(resp)
        return out

    # ---------------------------------------------------------------- prefill
    def _prefill_slot(self, slot: int) -> Optional[Response]:
        """*Blocking* (re-)compute of a slot's cache from its full token
        history, committing the next token from the prefill logits. Serves
        admission and the LFLR recompute on the stepwise and non-overlapped
        window engines; the overlapped engine replaces it with background
        lanes (``sched.begin_prefill`` + the fused window) and never blocks
        here. The wall time spent inside — the host stall every healthy slot
        pays — is recorded via ``metrics.record_host_stall``.

        In (non-overlapped) window mode this is also the *patch point* of the
        double-buffered pipeline: the rebuilt cache / next-token / position
        overwrite the lane's device state (the in-flight speculative window's
        outputs), and the lane is marked invalid in that window so its stale
        block is skipped at retirement."""
        t0 = self.clock()
        try:
            while True:
                tokens = np.asarray([self.sched.sequence_tokens(slot)],
                                    np.int32)
                logits, cache, word = self._prefill(self.params, tokens,
                                                    self.max_len)
                fut = DeviceFuture(outputs=(logits, cache), word=word)
                try:
                    logits, cache = fut.wait()
                    break
                except PropagatedError as exc:
                    retries = self.sched.note_retry(slot)
                    self.metrics.record_fault(self._step_count,
                                              int(exc.combined_code),
                                              "prefill_retry", (slot,))
                    if retries > self.max_request_retries:
                        return self.sched.evict(
                            slot, FAILED,
                            detail=f"prefill faulted {retries} times: {exc}")
            tok = int(jax.device_get(jnp.argmax(logits[0, -1])))
            self.caches, self._dev_tokens = self._insert(
                self.caches, cache, jnp.int32(slot), self._dev_tokens,
                jnp.int32(tok))
            if not self.window:
                # only the stepwise commit path reads logits back per slot
                self._slot_logits = self._slot_logits.at[slot].set(
                    logits.astype(jnp.float32))
            resp = self.sched.commit_token(slot, tok, self.clock())
            self.metrics.record_prefill(1)
            if self.window:
                s = self.sched.slots[slot]
                self._dev_pos[slot] = s.seq_len - 1 if s.active else 0
                if self._pending is not None:
                    self._pending.valid[slot] = False
            return resp
        finally:
            self.metrics.record_host_stall(self.clock() - t0)
