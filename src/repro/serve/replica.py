"""Serving replica: fused slot-decode behind a DeviceFuture, per-sequence LFLR.

One replica owns a fixed-slot continuous batch over
:func:`~repro.launch.steps.make_slot_decode_step`. Every dispatched step is
wrapped in a :class:`~repro.core.device_channel.DeviceFuture`; the per-slot
error words run through the paper's enumeration algorithm so the
``PropagatedError`` raised at the wait carries exact ``(slot, code)`` pairs.

Recovery is the paper's use-case 1 applied to inference:

* ``STATE_FAULT`` (bit-flipped recurrent state) or non-finite logits on slot
  *i* → **LFLR re-prefill**: recompute slot *i*'s cache from its prompt +
  already-generated tokens (greedy decode is deterministic, so this recreates
  the pre-fault trajectory exactly) — the other slots commit their tokens and
  never notice;
* the :class:`~repro.core.recovery.RecoveryPolicy` escalates: repeated faults
  inside its window recompute *every* lane (the rollback analogue), and a
  request that re-faults past ``max_request_retries`` is answered ``FAILED``
  (the serving ABORT — one poisoned request must not wedge the replica).
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.detect import ProbeConfig
from ..core.device_channel import WORD_DTYPE, DeviceFuture
from ..core.errors import PropagatedError
from ..core.recovery import Action, RecoveryPolicy
from ..launch.steps import make_cache_prefill, make_slot_decode_step
from ..models import build_model
from .metrics import ServeMetrics
from .queue import EXPIRED, FAILED, AdmissionPolicy, Request, RequestQueue, Response
from .scheduler import ContinuousBatchingScheduler

# CPU/interpret backends fall back to the fused-by-XLA probe oracle anyway;
# forcing it keeps the vmapped step portable (see kernels/fault_probe/ops.py).
SERVE_PROBES = ProbeConfig(use_kernel=False)


@functools.lru_cache(maxsize=None)
def make_enum_fn(num_slots: int):
    """Jitted ``(words, mask) -> (combined, count, table)`` over the slot axis.

    Free slots are masked out (their caches may hold stale values from an
    evicted sequence), then the paper's enumeration attributes each remaining
    word to its slot. ``max_errors=num_slots`` so attribution never truncates.
    Cached per slot count, so a fleet of replicas compiles it once.
    """
    from ..core.device_channel import combine_words, enumerate_errors_ref

    @jax.jit
    def enum(words, mask):
        words = words.astype(WORD_DTYPE) * mask.astype(WORD_DTYPE)
        combined = combine_words(*(words[i] for i in range(num_slots)))
        count, table = enumerate_errors_ref(words, max_errors=num_slots)
        return combined, count, table

    return enum


class Replica:
    """One continuous-batching serving replica (single host / rank)."""

    def __init__(self, cfg: ModelConfig, params: Any = None, *,
                 num_slots: int = 4, max_len: int = 64,
                 queue: RequestQueue | None = None,
                 policy: RecoveryPolicy | None = None,
                 metrics: ServeMetrics | None = None,
                 probe_cfg: ProbeConfig = SERVE_PROBES,
                 max_request_retries: int = 2,
                 rank: int = 0, seed: int = 0, eos_id: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 decode_fn: Callable | None = None,
                 prefill_fn: Callable | None = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.max_len = max_len
        self.rank = rank
        self.clock = clock
        self.policy = policy or RecoveryPolicy()
        self.metrics = metrics or ServeMetrics(clock=clock)
        self.max_request_retries = max_request_retries
        # jitted step functions are shareable across replicas (ServeGroup
        # builds them once so N rank threads compile once, not N times)
        self._decode = decode_fn or jax.jit(
            make_slot_decode_step(cfg, probe_cfg))
        self._prefill = prefill_fn or make_cache_prefill(cfg, probe_cfg)
        self._enum = make_enum_fn(num_slots)
        self.queue = queue or RequestQueue(
            AdmissionPolicy(max_total_len=max_len), clock=clock)
        self.sched = ContinuousBatchingScheduler(
            num_slots, self.queue, replica=rank, eos_id=eos_id, clock=clock)
        # stacked per-sequence (batch=1) caches, leading slot axis
        one = self.model.init_cache(1, max_len)
        self.caches = jax.tree_util.tree_map(
            lambda v: jnp.broadcast_to(v[None], (num_slots, *v.shape)).copy(),
            one)
        self._slot_logits = jnp.zeros((num_slots, 1, 1, cfg.vocab_size),
                                      jnp.float32)
        self._step_count = 0

    # ------------------------------------------------------------- submission
    def submit(self, req: Request) -> Optional[Response]:
        """Admit a request; returns a ``REJECTED`` response or None (accepted).
        Every accepted request is eventually answered by ``step``/``run``."""
        resp = self.queue.submit(req)
        if resp is not None:
            self.metrics.record_response(resp)
        return resp

    # ---------------------------------------------------------- fault surface
    def inject_state_fault(self, slot: Optional[int] = None) -> Optional[int]:
        """Simulated SDC (paper §II-A): NaN one element of a slot's recurrent
        state on device. ``slot=None`` picks the first active slot. Returns the
        poisoned slot, or None if there was nothing to poison."""
        if slot is None:
            active = self.sched.active_slots()
            if not active:
                return None
            slot = active[0]
        hit = []

        def poison(path, leaf):
            keys = [getattr(k, "key", None) for k in path]
            if any(k in ("h", "ssm") for k in keys) and leaf.ndim >= 1:
                hit.append(True)
                return leaf.at[(slot,) + (0,) * (leaf.ndim - 1)].set(jnp.nan)
            return leaf

        poisoned = jax.tree_util.tree_map_with_path(poison, self.caches)
        if not hit:
            raise ValueError(
                f"{self.cfg.name}: no recurrent state to poison "
                "(attention-only arch — flip a KV bit instead)")
        self.caches = poisoned
        return slot

    # ------------------------------------------------------------- step cycle
    def step(self) -> list[Response]:
        """One scheduler cycle: expire → backfill/prefill → fused decode →
        commit. Returns every request answered during the cycle."""
        now = self.clock()
        out: list[Response] = []
        for req in self.queue.drain_expired(now):
            out.append(Response(id=req.id, status=EXPIRED,
                                latency_s=now - req.arrival_t,
                                replica=self.rank,
                                detail="deadline passed in queue"))
        out.extend(self.sched.expire_active(now))
        for slot, _req in self.sched.backfill(now):
            resp = self._prefill_slot(slot)
            if resp is not None:
                out.append(resp)
        if self.sched.has_active():
            out.extend(self._decode_step())
        for resp in out:
            self.metrics.record_response(resp)
        return out

    def run(self, *, max_steps: int = 100_000) -> list[Response]:
        """Serve until the queue and all slots drain; returns all responses.

        Raises instead of returning if ``max_steps`` is exhausted with work
        still pending — an accepted request is never silently dropped.
        """
        out: list[Response] = []
        for _ in range(max_steps):
            if self.idle():
                return out
            out.extend(self.step())
        if not self.idle():
            raise RuntimeError(
                f"replica {self.rank}: {len(self.queue)} queued + "
                f"{self.sched.in_flight()} in-flight requests unanswered "
                f"after {max_steps} steps")
        return out

    def idle(self) -> bool:
        return not len(self.queue) and not self.sched.has_active()

    # ------------------------------------------------------------ decode path
    def _decode_step(self) -> list[Response]:
        self._step_count += 1
        tokens, pos = self.sched.step_inputs()
        mask = self.sched.active_mask()
        logits, caches, words = self._decode(
            self.params, self.caches, jnp.asarray(tokens), jnp.asarray(pos))
        combined, count, table = self._enum(words, jnp.asarray(mask))
        fut = DeviceFuture(outputs=(logits, caches), word=combined,
                           count=count, table=table)
        try:
            logits, caches = fut.wait()
            self._slot_logits, self.caches = logits, caches
            return self._commit(skip=frozenset())
        except PropagatedError as exc:
            return self._recover(exc, fut)

    def _commit(self, skip: frozenset[int]) -> list[Response]:
        now = self.clock()
        out = []
        # argmax on device: ship S int32s to the host, not S×V logits
        toks = np.asarray(jnp.argmax(self._slot_logits[:, 0, 0, :], axis=-1))
        committed = 0
        for slot in self.sched.active_slots():
            if slot in skip:
                continue
            resp = self.sched.commit_token(slot, int(toks[slot]), now)
            committed += 1
            if resp is not None:
                out.append(resp)
        self.metrics.record_step(committed)
        return out

    # --------------------------------------------------------------- recovery
    def _recover(self, exc: PropagatedError, fut: DeviceFuture) -> list[Response]:
        decision = self.policy.decide(exc, self._step_count)
        num_slots = self.sched.num_slots
        faulted = sorted({e.rank for e in exc.errors if 0 <= e.rank < num_slots})
        if not faulted:                      # unattributed word: assume all
            faulted = list(self.sched.active_slots())
        self.metrics.record_fault(self._step_count, int(exc.combined_code),
                                  decision.action.value, tuple(faulted))
        # Slots are independent under vmap: the dispatched outputs of the
        # non-faulted slots are valid, so salvage them and only recompute the
        # attributed ones — this is what keeps one bad sequence from stalling
        # the whole batch.
        self._slot_logits, self.caches = fut.outputs
        if decision.action is Action.ROLLBACK:
            # escalation: recompute every lane (whole-batch recompute is the
            # serving analogue of restoring the last checkpoint)
            targets, fail_now = list(self.sched.active_slots()), False
        elif decision.action is Action.ABORT:
            targets, fail_now = faulted, True
        else:   # SKIP_BATCH / RESTORE_GOOD / CONTINUE / ... → per-sequence LFLR
            targets, fail_now = faulted, False
        out = self._commit(skip=frozenset(targets))
        faulted_set = set(faulted)
        for slot in targets:
            if not self.sched.slots[slot].active:
                continue                     # already evicted this cycle
            # only the slots the enumeration attributed pay a retry: a healthy
            # lane swept into a ROLLBACK recompute must not burn its budget
            # (FAILED is reserved for requests that re-fault on recompute)
            if slot in faulted_set:
                retries = self.sched.note_retry(slot)
            else:
                retries = self.sched.request(slot).retries
            if fail_now or retries > self.max_request_retries:
                out.append(self.sched.evict(
                    slot, FAILED,
                    detail=f"{decision.reason} (retries={retries})"))
                continue
            resp = self._prefill_slot(slot)  # LFLR: recompute, don't restart
            if resp is not None:
                out.append(resp)
        return out

    # ---------------------------------------------------------------- prefill
    def _prefill_slot(self, slot: int) -> Optional[Response]:
        """(Re-)compute a slot's cache from its full token history and commit
        the next token from the prefill logits. Serves both admission and the
        LFLR recompute — they are literally the same operation."""
        tokens = np.asarray([self.sched.sequence_tokens(slot)], np.int32)
        logits, cache, word = self._prefill(self.params, tokens, self.max_len)
        fut = DeviceFuture(outputs=(logits, cache), word=word)
        try:
            logits, cache = fut.wait()
        except PropagatedError as exc:
            retries = self.sched.note_retry(slot)
            self.metrics.record_fault(self._step_count,
                                      int(exc.combined_code),
                                      "prefill_retry", (slot,))
            if retries > self.max_request_retries:
                return self.sched.evict(
                    slot, FAILED,
                    detail=f"prefill faulted {retries} times: {exc}")
            return self._prefill_slot(slot)
        self.caches = jax.tree_util.tree_map(
            lambda full, one: full.at[slot].set(one.astype(full.dtype)),
            self.caches, cache)
        self._slot_logits = self._slot_logits.at[slot].set(
            logits.astype(jnp.float32))
        tok = int(jnp.argmax(logits[0, -1]))
        resp = self.sched.commit_token(slot, tok, self.clock())
        self.metrics.record_prefill(1)
        return resp
