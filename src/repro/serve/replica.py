"""Serving replica: fused slot-decode behind a DeviceFuture, per-sequence LFLR.

One replica owns a fixed-slot continuous batch over
:func:`~repro.launch.steps.make_slot_decode_step`. Every dispatched step is
wrapped in a :class:`~repro.core.device_channel.DeviceFuture`; the per-slot
error words run through the paper's enumeration algorithm so the
``PropagatedError`` raised at the wait carries exact ``(slot, code)`` pairs.

With ``window=K`` the hot path moves to the **zero-sync decode window**
(:func:`~repro.launch.steps.make_decode_window`): K greedy steps run fully on
device, fault detection is deferred to the window boundary (the paper's
asynchrony contract — errors latch in-band, raise at the *wait*), and the
commit loop is **double-buffered**: window N+1 is dispatched from window N's
device-resident outputs (next token + donated caches) *before* window N's
token block is read back, so the device never idles on a host round trip.
Host syncs scale with ``steps / K`` instead of ``steps``. EOS / deadline /
faulted slots are handled at window boundaries: trailing tokens are
discarded, freed lanes are backfilled, and the already-in-flight speculative
window is patched — its stale lanes are marked invalid and simply skipped at
its own retirement.

With ``overlap=True`` (the default in window mode) admission and LFLR
recovery become **background prefill lanes** driven by the scheduler: instead
of a blocking full-length prefill between windows, a joining or recovering
slot's pending sequence is chunked into the *fused* decode+prefill window
(:func:`~repro.launch.steps.make_prefill_decode_window`) — the token stream
of the healthy slots never stalls, and the lane flips to decoding inside the
window whose chunk consumes its last pending token (bit-exact vs the blocking
path, since both compute the first token as the argmax after the last prompt
token through the same decode step). A fault latched during a chunk is
attributed through the same ``(K, slots)`` history and re-queues the lane
(cache reset + chunk from position 0) without a single host sync.

With ``speculate=True`` (window + overlap mode, full-attention archs) the
window becomes a **speculative decode window**
(:func:`~repro.launch.steps.make_speculative_decode_window`): every window
step drafts ``draft_len`` tokens with a shallow-exit self-draft and verifies
them in one batched full-model forward, emitting 1..D+1 tokens per step —
token-bit-exact vs the plain engine, since every emitted token is a
full-model argmax. The commit loop consumes a per-(step, slot) accepted-count
readback instead of assuming K tokens (EOS / deadline / fault boundaries cut
the flattened accepted stream), the position chain moves on device (advance
is data-dependent), and rejected drafts ride the same ``(K, slots)`` error
history as the attribution-only ``DRAFT_REJECT`` lane — visible to
``fault_codes()``, masked out of the fault-raising word, never recovered.

Recovery is the paper's use-case 1 applied to inference:

* ``STATE_FAULT`` (bit-flipped recurrent state) or non-finite logits on slot
  *i* → **LFLR re-prefill**: recompute slot *i*'s cache from its prompt +
  already-generated tokens (greedy decode is deterministic, so this recreates
  the pre-fault trajectory exactly) — the other slots commit their tokens and
  never notice;
* the :class:`~repro.core.recovery.RecoveryPolicy` escalates: repeated faults
  inside its window recompute *every* lane (the rollback analogue), and a
  request that re-faults past ``max_request_retries`` is answered ``FAILED``
  (the serving ABORT — one poisoned request must not wedge the replica).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.detect import ProbeConfig
from ..core.device_channel import WORD_DTYPE, DeviceFuture
from ..core.errors import ErrorCode, PropagatedError
from ..core.faults import INJECTABLE_CODE_MASK as _INJECTABLE_MASK
from ..core.recovery import Action, RecoveryPolicy
from ..launch.paging import PagedLayout
from ..launch.steps import (
    TPContext,
    make_cache_prefill,
    make_decode_window,
    make_prefill_decode_window,
    make_slot_decode_step,
    make_speculative_decode_window,
)
from ..models import build_model
from ..obs.trace import NULL_TRACER, SHARD_TID, Tracer
from .config import EngineConfig
from .metrics import ServeMetrics
from .queue import EXPIRED, FAILED, AdmissionPolicy, Request, RequestQueue, Response
from .scheduler import ContinuousBatchingScheduler, PageAllocator, PagePoolExhausted

# CPU/interpret backends fall back to the fused-by-XLA probe oracle anyway;
# forcing it keeps the vmapped step portable (see kernels/fault_probe/ops.py).
SERVE_PROBES = ProbeConfig(use_kernel=False)


@functools.lru_cache(maxsize=None)
def make_enum_fn(num_slots: int):
    """Jitted ``(words, mask) -> (combined, count, table)`` over the slot axis.

    Free slots are masked out (their caches may hold stale values from an
    evicted sequence), then the paper's enumeration attributes each remaining
    word to its slot. ``max_errors=num_slots`` so attribution never truncates.
    Cached per slot count, so a fleet of replicas compiles it once.
    """
    from ..core.device_channel import combine_words, enumerate_errors_ref

    @jax.jit
    def enum(words, mask):
        words = words.astype(WORD_DTYPE) * mask.astype(WORD_DTYPE)
        combined = combine_words(*(words[i] for i in range(num_slots)))
        count, table = enumerate_errors_ref(words, max_errors=num_slots)
        return combined, count, table

    return enum


@functools.lru_cache(maxsize=None)
def make_window_enum_fn(num_slots: int, ignore: int = 0):
    """Jitted ``(history (K, S), mask (S,)) -> (combined, count, table, hist)``.

    The window variant of :func:`make_enum_fn`: free slots are masked out of
    the whole ``(K, slots)`` word history, per-slot words are OR-folded over
    the window (deferred detection — one check per K tokens), and the fold is
    handed to the *same* per-slot enumeration the stepwise engine uses, so
    the two engines cannot diverge in attribution semantics. The masked
    history rides along so :meth:`DeviceFuture.fault_steps` can attribute a
    fault to its exact ``(step, slot)`` on the (rare) fault path only.

    ``ignore`` strips attribution-only code bits (``DRAFT_REJECT``) from the
    fold that feeds the combined word and the enumeration table — those lanes
    stay in the returned history for exact (step, slot) attribution, but a
    window whose only events are speculation misses must wait() clean, never
    raise.
    """
    from ..core.errors import strip_codes

    slot_enum = make_enum_fn(num_slots)

    @jax.jit
    def enum(history, mask):
        hist = history.astype(WORD_DTYPE) * mask.astype(WORD_DTYPE)[None, :]
        words = jax.lax.reduce(strip_codes(hist, ignore), jnp.uint32(0),
                               jax.lax.bitwise_or, (0,))
        combined, count, table = slot_enum(words, jnp.ones_like(mask))
        return combined, count, table, hist

    return enum


@dataclass
class _WindowInFlight:
    """One dispatched decode window awaiting retirement.

    ``req_ids`` snapshots which request occupied each slot at dispatch (None =
    free lane); a lane's token block only commits if the same request still
    holds the slot at retirement. ``valid`` is cleared for a lane when the
    host patches its device state (LFLR re-prefill / backfill) while this
    window is already in flight — the lane's tokens *and its error words* are
    then stale and are skipped wholesale at retirement. ``start`` is the first
    committable step per lane: 0 for a decoding slot, ``rem - 1`` for a lane
    whose prompt chunk exhausts at step ``rem - 1`` (its argmax there is the
    first real token), K for a lane still mid-prefill (nothing committable).
    """

    fut: DeviceFuture
    req_ids: tuple
    valid: np.ndarray
    start: np.ndarray
    # speculative windows only. ``start_row``: first committable verify row
    # within the flip step ``start`` (prompt rows before it emit
    # non-committable prompt-position argmaxes). ``rem0``: prompt tokens fed
    # this window per lane (0 for decode lanes) — with the counts readback
    # this yields exact drafted/accepted counters. ``deferred``: lanes masked
    # out at dispatch (no valid state; their counts are garbage).
    start_row: Optional[np.ndarray] = None
    rem0: Optional[np.ndarray] = None
    deferred: Optional[np.ndarray] = None
    # tracing only: dispatch wall time + the window's index (_step_count at
    # dispatch), so the retire-side span covers the window's whole in-flight
    # life and fault events name the exact window they latched in.
    # ``trace_ids`` snapshots the lane owners' trace ids at dispatch (empty
    # when tracing is off): a fault must be attributed to the request whose
    # state the window actually computed with, even if that request finished
    # and left the slot before the deferred detection surfaced it.
    t_dispatch: float = 0.0
    index: int = 0
    trace_ids: tuple = ()


class Replica:
    """One continuous-batching serving replica (single host / rank)."""

    def __init__(self, cfg: ModelConfig, params: Any = None, *,
                 config: Optional[EngineConfig] = None,
                 queue: RequestQueue | None = None,
                 policy: RecoveryPolicy | None = None,
                 metrics: ServeMetrics | None = None,
                 probe_cfg: ProbeConfig = SERVE_PROBES,
                 rank: int = 0, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 decode_fn: Callable | None = None,
                 prefill_fn: Callable | None = None,
                 window_fn: Callable | None = None,
                 paged_layout: Optional[PagedLayout] = None,
                 tracer: Optional[Tracer] = None,
                 fault_injector: Optional[Callable] = None,
                 page_debug: Optional[bool] = None):
        # engine *shape* lives in one validated EngineConfig; runtime wiring
        # (queue, policy, shared jitted fns, tracer, injector, clock) stays as
        # real keywords.
        config = config if config is not None else EngineConfig()
        self.config = config
        num_slots, max_len = config.num_slots, config.max_len
        window, donate, overlap = config.window, config.donate, config.overlap
        prefill_budget, eos_id = config.prefill_budget, config.eos_id
        paged, page_size = config.paged, config.page_size
        page_budget, page_watermark = config.page_budget, config.page_watermark
        speculate = config.speculate
        draft_len, draft_layers = config.draft_len, config.draft_layers
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self.max_len = max_len
        self.rank = rank
        self.clock = clock
        self.policy = policy or RecoveryPolicy()
        self.metrics = metrics or ServeMetrics(clock=clock)
        # fault-causality tracing: explicit tracer > the provided queue's
        # tracer (a ServeGroup threads one per rank through both) > the free
        # NullTracer. Hot-path call sites guard on ``self.trace.enabled`` so
        # the disabled path never builds an event.
        if tracer is not None:
            self.trace = tracer
        elif queue is not None and queue.tracer.enabled:
            self.trace = queue.tracer
        else:
            self.trace = NULL_TRACER
        # slot -> open recovery lane (trace_id, t0, code, action, window):
        # begun at the recovery decision, closed by the first post-recovery
        # committed token (or swept as abandoned when the request leaves the
        # slot without one — its terminal response resolves the fault)
        self._recovering: dict[int, dict] = {}
        self.max_request_retries = config.max_request_retries
        # deterministic in-band fault-word injection (the fuzzer's device
        # mutation surface): called once per dispatch with the dispatch index
        # and the words shape — (slots,) stepwise, (K, slots) windowed — and
        # may return a uint32 array OR'd into the device error words *before*
        # enumeration, so injected codes ride the exact deferred-detection /
        # attribution path a probe-latched fault would. None = no injection.
        self._injector = fault_injector
        # debug-guarded page-ledger verification (fuzzing/tests): check the
        # allocator invariant at every preempt/requeue and LFLR page-reclaim
        # site so ledger corruption surfaces at the mutation site instead of
        # steps later. Defaults to __debug__ (off under python -O).
        self._page_debug = bool(__debug__ if page_debug is None else page_debug)
        self.window = int(window)
        self.overlap = bool(self.window) and bool(overlap)
        # ---- speculative decode windows (speculate=True) ------------------
        # draft-and-verify inside the fused window: up to draft_len+1 tokens
        # per full-model step, token-bit-exact vs the plain engine; the
        # commit loop consumes a per-(step, slot) accepted-count readback
        # instead of assuming K tokens per window (DESIGN.md §3.4)
        self.speculate = bool(speculate)
        self.draft_len = int(draft_len)
        self.draft_layers = int(draft_layers)
        if self.speculate:
            if not self.window:
                raise ValueError("speculate=True requires window mode "
                                 "(window=K)")
            if not self.overlap:
                raise ValueError("speculate=True requires overlap=True "
                                 "(admission/LFLR must ride the window: the "
                                 "blocking-prefill patch path assumes a "
                                 "host-predictable position chain)")
            if not self.model.supports_speculation():
                raise ValueError(
                    f"{cfg.name}: speculation requires a pure full-attention"
                    ", non-MoE architecture")
        # ---- paged KV/state pool (paged=True, window mode only) -----------
        # full-attention caches become one shared page pool addressed through
        # a (slots, max_pages) table; the allocator owns the free list and
        # the per-slot ownership ledger (DESIGN.md §3.3)
        self.paged = bool(paged)
        one = self.model.init_cache(1, max_len)
        if self.paged:
            if not self.window:
                raise ValueError("paged=True requires window mode (window=K)")
            num_pages = (int(page_budget) if page_budget is not None
                         else num_slots * (max_len // page_size))
            self.layout = paged_layout or PagedLayout(
                one, max_len, page_size=page_size, num_pages=num_pages)
            self.alloc = PageAllocator(self.layout.num_pages,
                                       self.layout.page_size,
                                       watermark=page_watermark)
            self.page_table = self.layout.empty_table(num_slots)
            self._scrub = jax.jit(self.layout.scrub, donate_argnums=(0,))
        else:
            self.layout = None
            self.alloc = None
        # jitted step functions are shareable across replicas (ServeGroup
        # builds them once so N rank threads compile once, not N times)
        self._decode = decode_fn or jax.jit(
            make_slot_decode_step(cfg, probe_cfg))
        self._prefill = prefill_fn or make_cache_prefill(
            cfg, probe_cfg, fused=bool(window),
            paged=self.layout if self.paged else None,
            donate=bool(self.paged and donate))
        self._enum = make_enum_fn(num_slots)
        # fused one-dispatch insertion of a rebuilt per-sequence cache into the
        # slot-stacked caches (the un-jitted tree_map was one dispatch per
        # leaf); the window-mode device token feed rides in the same dispatch
        self._insert = jax.jit(
            lambda full, one, slot, dev_toks, tok: (
                jax.tree_util.tree_map(
                    lambda f, o: f.at[slot].set(o.astype(f.dtype)), full, one),
                dev_toks.at[slot, 0, 0].set(tok)))
        self._set_tok = jax.jit(
            lambda dev_toks, slot, tok: dev_toks.at[slot, 0, 0].set(tok))
        if self.paged and self.layout.has_paged_leaves:
            # a request that could never fit in the pool must be REJECTED at
            # submit, not deferred forever by the watermark gate
            pool_cap = min(max_len,
                           self.layout.num_pages * self.layout.page_size)
        else:
            pool_cap = max_len
        self.queue = queue or RequestQueue(
            AdmissionPolicy(max_total_len=pool_cap), clock=clock,
            tracer=self.trace)
        self.sched = ContinuousBatchingScheduler(
            num_slots, self.queue, replica=rank, eos_id=eos_id, clock=clock,
            prefill_budget=prefill_budget,
            can_admit=(self._can_admit if self.paged else None),
            on_release=(self._release_pages if self.paged else None))
        # stacked per-sequence (batch=1) caches, leading slot axis — or, when
        # paged, the hybrid tree (page pools + dense per-slot stacks)
        if self.paged:
            self.caches = self.layout.init_hybrid(one, num_slots)
        else:
            self.caches = jax.tree_util.tree_map(
                lambda v: jnp.broadcast_to(v[None],
                                           (num_slots, *v.shape)).copy(),
                one)
        # ---- tensor parallelism (tp > 1, window + overlap mode) -----------
        # one replica = tp shards of a "model" mesh: params and cache leaves
        # are STORED sharded (rules.param_specs / tp_storage_specs), compute
        # stays replicated inside the shard_mapped window, and per-shard
        # error words are OR-folded across the axis so a fault on any shard
        # latches identically on all shards (DESIGN §3.8)
        self.tp = int(config.tp)
        self._tp_ctx: Optional[TPContext] = None
        if self.tp > 1:
            ndev = len(jax.devices())
            if ndev < self.tp:
                raise ValueError(
                    f"tp={self.tp} requires {self.tp} devices, found {ndev} "
                    "(on CPU, force host devices with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={self.tp})")
            from jax.sharding import NamedSharding
            from ..sharding.rules import param_specs, tp_storage_specs
            mesh = jax.make_mesh((self.tp,), ("model",))
            pspecs = param_specs(self.params, mesh)
            cspecs = (self.layout.tp_storage_specs(self.caches, mesh)
                      if self.paged else
                      tp_storage_specs(self.caches, mesh))
            self._tp_ctx = TPContext(mesh=mesh, param_specs=pspecs,
                                     cache_specs=cspecs)
            ns = lambda s: NamedSharding(mesh, s)  # noqa: E731
            self.params = jax.device_put(
                self.params, jax.tree_util.tree_map(ns, pspecs))
            self.caches = jax.device_put(
                self.caches, jax.tree_util.tree_map(ns, cspecs))
        self._slot_logits = jnp.zeros((num_slots, 1, 1, cfg.vocab_size),
                                      jnp.float32)
        self._step_count = 0
        # ---- zero-sync decode windows (window=K > 0) ----------------------
        if self.window:
            if window_fn is not None:
                self._decode_window = window_fn
            elif self.speculate:
                self._decode_window = make_speculative_decode_window(
                    cfg, probe_cfg, window=self.window,
                    draft_len=self.draft_len, draft_layers=self.draft_layers,
                    donate=donate, paged=self.layout if self.paged else None,
                    tp=self._tp_ctx)
            elif self.overlap:
                self._decode_window = make_prefill_decode_window(
                    cfg, probe_cfg, window=self.window, donate=donate,
                    paged=self.layout if self.paged else None,
                    tp=self._tp_ctx)
            else:
                self._decode_window = make_decode_window(
                    cfg, probe_cfg, window=self.window, donate=donate,
                    paged=self.layout if self.paged else None,
                    tp=self._tp_ctx)
            # speculation misses (DRAFT_REJECT) are attribution-only: strip
            # them from the fault-raising fold so they never reach wait()
            self._ignore_codes = (int(ErrorCode.DRAFT_REJECT)
                                  if self.speculate else 0)
            self._wenum = make_window_enum_fn(num_slots, self._ignore_codes)
        if self.overlap or self.paged:
            # fresh per-sequence cache template + fused one-dispatch reset of
            # one lane's slice of the stacked caches — the overlapped
            # admission/LFLR restart point (async, never a host sync). In
            # paged mode the reset covers the dense leaves only; the paged
            # half of the restart is the page scrub at re-allocation.
            self._fresh = one
            reset = (self.layout.reset_slot if self.paged else
                     lambda full, fresh, slot: jax.tree_util.tree_map(
                         lambda f, o: f.at[slot].set(o.astype(f.dtype)),
                         full, fresh))
            self._reset = jax.jit(reset, donate_argnums=(0,))
        self._pending: Optional[_WindowInFlight] = None
        # device-resident feed for the next window (token chain never leaves
        # the device) + host-tracked dispatch positions. With speculation the
        # per-window advance is data-dependent (1..K*(D+1) tokens), so the
        # position chain ALSO lives on device (`_dev_pos_dev`, fed from window
        # N's outputs into window N+1 without a host sync); `_dev_pos` then
        # tracks the *retired* truth — updated from each window's accepted-
        # count readback — and is only used for host planning (page growth).
        self._dev_tokens = jnp.zeros((num_slots, 1, 1), jnp.int32)
        self._dev_pos = np.zeros((num_slots,), np.int32)
        self._dev_pos_dev = jnp.zeros((num_slots,), jnp.int32)
        self._set_pos = jax.jit(lambda arr, slot, v: arr.at[slot].set(v))

    # ------------------------------------------------------------- page ledger
    def _check_pages(self) -> None:
        """Debug-guarded ledger invariant: every pool page free or owned
        exactly once, right now. Called at the mutation sites (preempt,
        requeue, LFLR reclaim) so a corrupted ledger fails at the op that
        corrupted it, not at whatever later step happens to trip over it."""
        if self._page_debug and self.alloc is not None:
            self.alloc.check()

    def _can_admit(self, req: Request) -> bool:
        """Watermark admission: a fresh sequence joins only if its prompt's
        pages (plus the first generated position) fit with the configured
        headroom left free for in-flight lanes to grow into."""
        if not self.layout.has_paged_leaves:
            return True
        return self.alloc.can_admit(len(req.prompt) + 1)

    def _release_pages(self, slot: int) -> None:
        """Free a slot's pages and unmap its table row. Host bookkeeping only
        — the device chain still orders every dispatched read of these pages
        before the scrub that their next owner's allocation queues, so
        reclamation never stalls or races the in-flight window."""
        if self.alloc.owns(slot):
            freed = self.alloc.free_slot(slot)
            self.page_table[slot, :] = self.layout.sentinel
            self.metrics.record_pages(freed=len(freed),
                                      in_use=self.alloc.pages_in_use)
            if self.trace.enabled:
                self.trace.instant("page_free", "page", tid=slot, slot=slot,
                                   pages=len(freed),
                                   in_use=self.alloc.pages_in_use)

    def _oldest_active(self, exclude: frozenset[int]) -> Optional[int]:
        """Eviction victim: the oldest-arrival active lane that owns pages."""
        best = None
        for s in self.sched.slots:
            if not s.active or s.idx in exclude or not self.alloc.owns(s.idx):
                continue
            key = (s.req.arrival_t if s.req.arrival_t is not None
                   else float("inf"), s.idx)
            if best is None or key < best[0]:
                best = (key, s.idx)
        return None if best is None else best[1]

    def _evict_for_pages(self, victim: int) -> None:
        """Memory-pressure preemption: pull the victim's request out of its
        slot and put it back in the queue (progress discarded — it recomputes
        from the prompt on its next slot, exactly the ledger re-route
        contract: zero dropped requests). The in-flight speculative window's
        lane is invalidated so its stale block is skipped at retirement."""
        req = self.sched.preempt(victim)          # on_release frees the pages
        if self.trace.enabled:
            self.trace.instant("page_evict", "page", tid=victim, slot=victim,
                               trace_id=req.trace_id)
        self.queue.requeue(req)
        self.metrics.record_page_eviction()
        if self._pending is not None:
            self._pending.valid[victim] = False
        self._check_pages()

    def _grow_slot(self, slot: int, target_tokens: int, *,
                   exclude_self: bool = False) -> Optional[list[int]]:
        """Ensure ``slot`` owns pages covering ``target_tokens`` positions,
        evicting oldest lanes under pressure. Returns the newly allocated
        (unscrubbed) page ids, or None if ``slot`` itself was evicted.

        The target is clamped to the pool's token capacity, not just
        ``max_len``: window over-decode can push ``pos + K`` past what any
        lane may hold, and demanding pages that cannot exist would evict the
        whole fleet and livelock (positions past the clamp drop their writes
        and are discarded at retirement anyway)."""
        target = min(int(target_tokens), self.layout.capacity_tokens)
        while True:
            need = (self.alloc.pages_for(target)
                    - len(self.alloc.owned(slot)))
            if need <= 0:
                return []
            try:
                got = self.alloc.alloc(slot, need)
                break
            except PagePoolExhausted:
                victim = self._oldest_active(
                    frozenset((slot,)) if exclude_self else frozenset())
                if victim is None:
                    raise      # unreachable under the admission-policy clamp
                self._evict_for_pages(victim)
                if victim == slot:
                    return None
        # append-only: write just the new tail entries, never rewrite the
        # whole row — the device table is the mapping of record, and a silent
        # full-row rewrite would paper over exactly the ledger/table
        # divergence the in-band PAGE_FAULT probe exists to surface
        n_owned = len(self.alloc.owned(slot))
        self.page_table[slot, n_owned - len(got):n_owned] = got
        self.metrics.record_pages(allocated=len(got),
                                  in_use=self.alloc.pages_in_use)
        if self.trace.enabled:
            self.trace.instant("page_alloc", "page", tid=slot, slot=slot,
                               pages=len(got), in_use=self.alloc.pages_in_use)
        return got

    def _paged_prepare(self, plan: dict) -> None:
        """Pre-dispatch page maintenance for one window.

        1. **Lane (re)starts** (fresh chunk plans — admission or LFLR): free
           the lane's old pages (the LFLR page *reclaim*, a pure host ledger
           op) and reset its dense state on the device chain; its new pages
           are (re-)acquired in step 2 — this is the non-blocking
           free-and-reacquire lane of DESIGN.md §3.3.
        2. **Growth**: every lane that writes during this window must have
           the pages holding positions ``[pos, pos+K)`` mapped; exhaustion
           preempts oldest lanes into the queue (never a drop).
        3. **Scrub**: newly allocated pages are zeroed in one fused dispatch
           riding the device chain before the window, so recycled pages can
           never leak a previous owner's (possibly poisoned) state.
        """
        sched, K = self.sched, self.window
        for slot, cp in plan.items():
            if cp.rem == 0 or not cp.fresh:
                continue
            self._release_pages(slot)
            self._check_pages()
            self.caches = self._reset(self.caches, self._fresh,
                                      jnp.int32(slot))
            self._set_dev_pos(slot, 0)
        if not self.layout.has_paged_leaves:
            return
        deferred = {slot for slot, cp in plan.items() if cp.rem == 0}
        # speculation: a window advances a data-dependent 1..K*(D+1) tokens,
        # and the in-flight window's advance is unknown until its counts come
        # back — grow to the worst case (retired truth + in-flight horizon +
        # this window's horizon). Conservative by design: demanding a page
        # that goes unwritten wastes headroom; missing one latches PAGE_FAULT.
        horizon = K * (self.draft_len + 1) if self.speculate else K
        slack = (horizon if self.speculate and self._pending is not None
                 else 0)
        new_ids: list[int] = []
        for s in list(sched.slots):
            if not s.active or s.idx in deferred:
                continue
            got = self._grow_slot(s.idx,
                                  int(self._dev_pos[s.idx]) + horizon + slack)
            if got:
                new_ids.extend(got)
        if new_ids:
            # dedupe: an eviction inside the growth loop recycles ids, so the
            # same physical page can be granted twice within one prepare —
            # unique ids always fit the fixed-size staging buffer
            new_ids = list(dict.fromkeys(new_ids))
            ids = np.full((self.layout.num_pages,), self.layout.sentinel,
                          np.int32)
            ids[:len(new_ids)] = new_ids
            self.caches = self._scrub(self.caches, jnp.asarray(ids))

    # ------------------------------------------------------------ dev position
    def _set_dev_pos(self, slot: int, val: int) -> None:
        """Patch one lane's dispatch position: the host mirror always; the
        device-resident position chain too when speculating (it is the value
        window N+1 actually consumes — the patch rides the device chain like
        the cache reset it accompanies, never a sync)."""
        self._dev_pos[slot] = val
        if self.speculate:
            self._dev_pos_dev = self._set_pos(self._dev_pos_dev,
                                              jnp.int32(slot), jnp.int32(val))

    # ---------------------------------------------------------------- warmup
    def warmup(self, *, max_new: int = 8) -> None:
        """Compile every hot-path program before real traffic: one throwaway
        request end-to-end covers prefill (the fused variant compiles once
        for *all* lengths), decode/window and commit. Swaps in fresh metrics
        afterwards so compile time never pollutes reported numbers."""
        assert self.idle(), "warmup must run before traffic is admitted"
        req = Request(id=-1, prompt=(1, 2, 3),
                      max_new_tokens=min(max_new, self.max_len - 4))
        assert self.submit(req) is None
        self.run()
        self.metrics = ServeMetrics(clock=self.clock)
        self.trace.clear()       # compile-time spans would pollute the trace

    # ------------------------------------------------------------- submission
    def submit(self, req: Request) -> Optional[Response]:
        """Admit a request; returns a ``REJECTED`` response or None (accepted).
        Every accepted request is eventually answered by ``step``/``run``."""
        resp = self.queue.submit(req)
        if resp is not None:
            self.metrics.record_response(resp)
        return resp

    def readmit(self, req: Request) -> Optional[Response]:
        """Idempotent re-admission after a ledger replay (crash-restart).

        A request the write-ahead log proves was already *accepted* re-enters
        through the negative-sequence requeue lane: admission checks are
        bypassed (it was admitted once, the zero-drop contract owes it a
        terminal answer), it sorts ahead of its deadline class, and its
        original ``arrival_t``/``trace_id`` are preserved so latency spans
        the whole crash-recovery window and the post-mortem sees one causal
        chain across both incarnations of the fleet. Requests the log shows
        as submitted but never accepted go through normal admission."""
        if req.arrival_t is None:
            return self.submit(req)
        self.queue.requeue(req)
        return None

    def load(self) -> int:
        """Queued + in-flight requests — the group take-limit / autoscale
        pressure signal."""
        return len(self.queue) + self.sched.in_flight()

    # ---------------------------------------------------------- fault surface
    def inject_state_fault(self, slot: Optional[int] = None, *,
                           rng: Optional[np.random.Generator] = None
                           ) -> Optional[int]:
        """Simulated SDC (paper §II-A): NaN one element of a slot's recurrent
        state on device — or, for attention-only architectures, of the K
        entry at position 0 of the slot's (paged or contiguous) KV cache,
        which the non-finite-logits probe then latches. ``slot=None`` picks
        the first active slot — or a seeded-random active slot when ``rng``
        is given (``FaultSchedule.rng_for`` hands one out per (rank, step),
        so any randomized injection replays bit-for-bit from the schedule
        seed alone). Returns the poisoned slot, or None if there was nothing
        to poison (e.g. a paged lane holding no mapped page)."""
        if slot is None:
            active = self.sched.active_slots()
            if not active:
                return None
            slot = int(rng.choice(active)) if rng is not None else active[0]
        hit = []

        def poison(path, leaf):
            keys = [getattr(k, "key", None) for k in path]
            if any(k in ("h", "ssm") for k in keys) and leaf.ndim >= 1:
                hit.append(True)
                return leaf.at[(slot,) + (0,) * (leaf.ndim - 1)].set(jnp.nan)
            return leaf

        poisoned = jax.tree_util.tree_map_with_path(poison, self.caches)
        if hit:
            self.caches = poisoned
            return slot
        # attention-only arch: poison K at position 0 (always a written
        # position once the lane holds state, so the NaN reaches the scores)
        if self.paged and self.layout.has_paged_leaves:
            pid = int(self.page_table[slot, 0])
            if pid >= self.layout.num_pages:
                return None              # lane owns no page yet — nothing real

            def poison_pool(path, leaf):
                if hit or not self.layout.is_paged_path(path):
                    return leaf
                hit.append(True)
                return leaf.at[(pid,) + (0,) * (leaf.ndim - 1)].set(jnp.nan)

            poisoned = jax.tree_util.tree_map_with_path(poison_pool,
                                                        self.caches)
        else:

            def poison_kv(path, leaf):
                keys = [getattr(k, "key", None) for k in path]
                if (hit or not keys or keys[-1] != "k" or leaf.ndim < 4
                        or leaf.shape[leaf.ndim - 3] != self.max_len):
                    return leaf          # full-attention K leaves only
                hit.append(True)
                return leaf.at[(slot,) + (0,) * (leaf.ndim - 1)].set(jnp.nan)

            poisoned = jax.tree_util.tree_map_with_path(poison_kv,
                                                        self.caches)
        if not hit:
            raise ValueError(
                f"{self.cfg.name}: no recurrent state or full-attention KV "
                "to poison")
        self.caches = poisoned
        return slot

    def corrupt_page_table(self, slot: int) -> bool:
        """Deterministic ledger-divergence injection (fuzzing/tests): unmap a
        lane's device page-table row behind the allocator's back. The host
        ledger still says the slot owns its pages; the device's mapping of
        record says it owns nothing — exactly the corruption the in-band
        ``PAGE_FAULT`` probe exists to latch at the next write. Returns True
        iff there was a mapped row to corrupt."""
        if not (self.paged and self.layout.has_paged_leaves):
            return False
        if int(self.page_table[slot, 0]) >= self.layout.num_pages:
            return False                  # nothing mapped — nothing to diverge
        self.page_table[slot, :] = self.layout.sentinel
        return True

    def preempt_slot(self, slot: int) -> bool:
        """Deterministic preemption injection (fuzzing / external rebalance):
        pull ``slot``'s request out mid-flight and requeue it ahead of its
        class — the same zero-drop contract as the paged memory-pressure
        eviction, exposed as an explicit hook. The in-flight window's lane is
        invalidated (its block computed with the departed request's state)
        and the page ledger, if any, is verified at the mutation site.
        Returns True iff the slot held a request."""
        s = self.sched.slots[slot]
        if not s.active:
            return False
        req = self.sched.preempt(slot)    # on_release reclaims any pages
        self.queue.requeue(req)
        if self._pending is not None:
            self._pending.valid[slot] = False
        self._check_pages()
        return True

    def _injection_for(self, shape: tuple) -> Optional[np.ndarray]:
        """The injector's validated fault word(s) for this dispatch, or None
        when nothing is scheduled. Shape is the engine's word surface:
        ``(slots,)`` stepwise, ``(K, slots)`` windowed, ``(tp, K, slots)``
        tensor-parallel (shard-targeted injection — the TP kit's device
        mutation surface)."""
        if self._injector is None:
            return None
        inj = self._injector(self._step_count, shape)
        if inj is None:
            return None
        inj = np.asarray(inj, np.uint32)
        if inj.shape != shape:
            raise ValueError(
                f"fault_injector returned shape {inj.shape}, expected {shape}")
        bad = int(np.bitwise_or.reduce(inj, axis=None)) & ~int(
            _INJECTABLE_MASK)
        if bad:
            raise ValueError(
                f"fault_injector word {bad:#x} carries non-injectable bits "
                "(attribution-only / hard / undefined)")
        return inj

    def _inject_words(self, words, shape: tuple):
        """OR the injector's scheduled fault word(s) for this dispatch into
        the device error words, *before* masking/enumeration — an injected
        code is indistinguishable from a probe-latched one from that point
        on (deferred detection, (step, slot) attribution, recovery routing
        all run for real). No-op (and zero extra dispatches) without an
        injector. The TP engine does not use this host-side path: its
        injection rides INTO the shard_mapped window as a per-shard operand
        so it is folded across shards like a probe-latched word."""
        inj = self._injection_for(shape)
        if inj is None:
            return words
        return jnp.bitwise_or(words, jnp.asarray(inj))

    # ------------------------------------------------------------- step cycle
    def step(self) -> list[Response]:
        """One scheduler cycle: expire → backfill/prefill → fused decode →
        commit. Returns every request answered during the cycle."""
        now = self.clock()
        out: list[Response] = []
        for req in self.queue.drain_expired(now):
            out.append(Response(id=req.id, status=EXPIRED,
                                latency_s=now - req.arrival_t,
                                replica=self.rank,
                                detail="deadline passed in queue",
                                trace_id=req.trace_id))
        out.extend(self.sched.expire_active(now))
        for slot, _req in self.sched.backfill(now):
            if self.trace.enabled and _req.trace_id is not None:
                self.trace.instant("slot_assign", "sched", ts=now, tid=slot,
                                   trace_id=_req.trace_id, slot=slot)
            if self.overlap:
                # admission is a background lane: the scheduler chunks the
                # prompt into subsequent decode windows — no blocking prefill
                self.sched.begin_prefill(slot)
            else:
                resp = self._prefill_slot(slot)
                if resp is not None:
                    out.append(resp)
        self.metrics.record_active_slots(self.sched.in_flight())
        if self.window:
            if self.sched.has_active() or self._pending is not None:
                out.extend(self._window_cycle())
        elif self.sched.has_active():
            out.extend(self._decode_step())
        for resp in out:
            self.metrics.record_response(resp)
        if self.trace.enabled:
            t_done = self.clock()
            for resp in out:
                self.trace.end_request(resp, t_done)
            self._sweep_recoveries(t_done)
        return out

    def run(self, *, max_steps: int = 100_000) -> list[Response]:
        """Serve until the queue and all slots drain; returns all responses.

        Raises instead of returning if ``max_steps`` is exhausted with work
        still pending — an accepted request is never silently dropped.
        """
        out: list[Response] = []
        for _ in range(max_steps):
            if self.idle():
                return out
            out.extend(self.step())
        if not self.idle():
            raise RuntimeError(
                f"replica {self.rank}: {len(self.queue)} queued + "
                f"{self.sched.in_flight()} in-flight requests unanswered "
                f"after {max_steps} steps")
        return out

    def idle(self) -> bool:
        return (not len(self.queue) and not self.sched.has_active()
                and self._pending is None)

    # ------------------------------------------------------ recovery lanes (obs)
    def _trace_recovery_begin(self, slot: int, trace_id: Optional[int],
                              code: int, action: str, window: int,
                              now: float) -> None:
        """Open a recovery lane for ``slot`` (closing, as re-faulted, any lane
        the same slot already had open — its recompute never produced a
        healthy token before faulting again)."""
        old = self._recovering.pop(slot, None)
        if old is not None:
            self.trace.span("recovery", "recovery", old["t0"], now, tid=slot,
                            trace_id=old["trace_id"], slot=slot,
                            window=old["window"], action=old["action"],
                            code=old["code"], outcome="refaulted")
        if trace_id is None:
            return
        self._recovering[slot] = {"trace_id": trace_id, "t0": now,
                                  "code": code, "action": action,
                                  "window": window}

    def _trace_recovery_end(self, slot: int, trace_id: Optional[int],
                            now: float, outcome: str) -> None:
        """Close ``slot``'s recovery lane: the span runs from the recovery
        decision to the first healthy post-recovery token (outcome
        ``recovered``)."""
        ctx = self._recovering.get(slot)
        if ctx is None or ctx["trace_id"] != trace_id:
            return
        del self._recovering[slot]
        self.trace.span("recovery", "recovery", ctx["t0"], now, tid=slot,
                        trace_id=trace_id, slot=slot, window=ctx["window"],
                        action=ctx["action"], code=ctx["code"],
                        outcome=outcome)

    def _sweep_recoveries(self, now: float) -> None:
        """Close recovery lanes whose request left the slot without committing
        a post-recovery token (FAILED / EXPIRED / preempted): the request's
        terminal response is what resolves the fault; the abandoned lane span
        records that the recompute never finished."""
        for slot, ctx in list(self._recovering.items()):
            s = self.sched.slots[slot]
            if s.active and s.req.trace_id == ctx["trace_id"]:
                continue
            del self._recovering[slot]
            self.trace.span("recovery", "recovery", ctx["t0"], now, tid=slot,
                            trace_id=ctx["trace_id"], slot=slot,
                            window=ctx["window"], action=ctx["action"],
                            code=ctx["code"], outcome="abandoned")

    # ------------------------------------------------------------ decode path
    def _decode_step(self) -> list[Response]:
        self._step_count += 1
        tokens, pos = self.sched.step_inputs()
        mask = self.sched.active_mask()
        logits, caches, words = self._decode(
            self.params, self.caches, jnp.asarray(tokens), jnp.asarray(pos))
        words = self._inject_words(words, (self.sched.num_slots,))
        combined, count, table = self._enum(words, jnp.asarray(mask))
        fut = DeviceFuture(outputs=(logits, caches), word=combined,
                           count=count, table=table)
        try:
            logits, caches = fut.wait()
            self._slot_logits, self.caches = logits, caches
            return self._commit(skip=frozenset())
        except PropagatedError as exc:
            return self._recover(exc, fut)

    def _commit(self, skip: frozenset[int]) -> list[Response]:
        now = self.clock()
        out = []
        # argmax on device: ship S int32s to the host, not S×V logits
        toks = np.asarray(jax.device_get(
            jnp.argmax(self._slot_logits[:, 0, 0, :], axis=-1)))
        committed = 0
        for slot in self.sched.active_slots():
            if slot in skip:
                continue
            resp = self.sched.commit_token(slot, int(toks[slot]), now)
            committed += 1
            if resp is not None:
                out.append(resp)
        self.metrics.record_step(committed)
        return out

    # --------------------------------------------------------- window engine
    def _window_cycle(self) -> list[Response]:
        """Double-buffered commit loop: dispatch window N+1 from window N's
        device-resident outputs *before* reading back window N's tokens."""
        prev = self._pending
        self._pending = (self._dispatch_window()
                         if self.sched.has_active() else None)
        return self._retire_window(prev) if prev is not None else []

    def _dispatch_window(self) -> _WindowInFlight:
        self._step_count += 1
        sched = self.sched
        K = self.window
        t_disp = self.clock() if self.trace.enabled else 0.0
        # speculation: prompt feed rides the verify width, so one window can
        # consume up to K*(D+1) prompt tokens per lane
        chunk_width = (self.draft_len + 1) if self.speculate else 1
        plan = (sched.plan_prefill(K * chunk_width) if self.overlap else {})
        if self.paged:
            # page maintenance first: lane restarts recycle their pages, every
            # writing lane gets growth pages, eviction preempts under pressure
            # — all of it host bookkeeping + chained device ops, zero syncs
            self._paged_prepare(plan)
        mask = sched.active_mask()
        start = np.zeros(sched.num_slots, np.int64)
        start_row = np.zeros(sched.num_slots, np.int64)
        rem0 = np.zeros(sched.num_slots, np.int64)
        deferred = np.zeros(sched.num_slots, bool)
        extra = ((jnp.asarray(self.page_table),) if self.paged else ())
        if self.tp > 1:
            # per-shard injection rides into the shard_mapped window as its
            # trailing (tp, K, S) operand: each shard ORs its slice into its
            # local words BEFORE the cross-shard fold, so an injected word —
            # like a probe-latched one — latches identically on every shard
            inj = self._injection_for((self.tp, K, sched.num_slots))
            if inj is None:
                inj = np.zeros((self.tp, K, sched.num_slots), np.uint32)
            extra = extra + (jnp.asarray(inj),)
        if self.overlap:
            chunk = np.zeros((K, chunk_width, sched.num_slots), np.int32)
            rem = np.zeros((sched.num_slots,), np.int32)
            for slot, cp in plan.items():
                if not sched.slots[slot].active:
                    continue            # preempted by the page-pressure loop
                if cp.rem == 0:
                    # deferred fresh lane: no valid state yet — fully masked
                    mask[slot] = 0
                    start[slot] = K
                    deferred[slot] = True
                    continue
                if cp.fresh and not self.paged:
                    # lane (re)start: fresh cache slice + position 0, both
                    # queued on the device chain — never a host sync (the
                    # paged engine did this in _paged_prepare, plus the page
                    # free/re-acquire/scrub that replaces the slab reset)
                    self.caches = self._reset(self.caches, self._fresh,
                                              jnp.int32(slot))
                    self._set_dev_pos(slot, 0)
                chunk.reshape(K * chunk_width,
                              sched.num_slots)[:cp.rem, slot] = cp.tokens
                rem[slot] = cp.rem
                rem0[slot] = cp.rem
                if cp.exhausts:
                    # flip point: the argmax after the last prompt token is
                    # the first committable token — step kf, verify row rf
                    kf = (cp.rem - 1) // chunk_width
                    start[slot] = kf
                    start_row[slot] = (cp.rem - 1) - kf * chunk_width
                else:
                    start[slot] = K
                self.metrics.record_chunk(cp.rem)
                if self.trace.enabled:
                    tr = sched.slots[slot].req.trace_id
                    if tr is not None:
                        self.trace.instant(
                            "chunk", "prefill", ts=t_disp, tid=slot,
                            trace_id=tr, slot=slot, tokens=cp.rem,
                            fresh=cp.fresh, exhausts=cp.exhausts,
                            window=self._step_count)
            if not self.speculate:
                chunk = chunk[:, 0, :]          # plain engines feed 1/step
            if self.speculate:
                # device-resident position chain: the per-window advance is
                # data-dependent, so window N+1 reads window N's next_pos
                # without the host ever seeing it
                toks, counts, words, next_tok, next_pos, caches = (
                    self._decode_window(
                        self.params, self.caches, self._dev_tokens,
                        self._dev_pos_dev, jnp.asarray(chunk),
                        jnp.asarray(rem), *extra))
                self._dev_pos_dev = next_pos
                outputs = (toks, counts)
            else:
                toks, words, next_tok, caches = self._decode_window(
                    self.params, self.caches, self._dev_tokens,
                    jnp.asarray(self._dev_pos), jnp.asarray(chunk),
                    jnp.asarray(rem), *extra)
                outputs = toks
        else:
            toks, words, next_tok, caches = self._decode_window(
                self.params, self.caches, self._dev_tokens,
                jnp.asarray(self._dev_pos), *extra)
            outputs = toks
        # the device-side chain advances: window N+1 consumes these directly
        self.caches = caches
        self._dev_tokens = next_tok
        if not self.speculate:
            self._dev_pos = self._dev_pos + K
        if self.tp <= 1:
            # TP injection already rode the window (pre-fold, device-side)
            words = self._inject_words(words, (K, sched.num_slots))
        combined, count, table, hist = self._wenum(words, jnp.asarray(mask))
        fut = DeviceFuture(outputs=outputs, word=combined, count=count,
                           table=table, history=hist)
        return _WindowInFlight(
            fut=fut,
            req_ids=tuple(s.req.id if s.active else None for s in sched.slots),
            valid=np.ones(sched.num_slots, bool),
            start=start,
            start_row=start_row if self.speculate else None,
            rem0=rem0 if self.speculate else None,
            deferred=deferred if self.speculate else None,
            t_dispatch=t_disp, index=self._step_count,
            trace_ids=(tuple(s.req.trace_id if s.active else None
                             for s in sched.slots)
                       if self.trace.enabled else ()))

    def _retire_window(self, win: _WindowInFlight) -> list[Response]:
        if not win.fut.done():
            # the device is still computing this window at its retirement —
            # the pipeline, not the host, is the bottleneck right now
            self.metrics.record_window_wait()
            if self.trace.enabled:
                self.trace.instant("window_wait", "window", window=win.index)
        try:
            block = win.fut.wait()
        except PropagatedError as exc:
            if self.trace.enabled:
                self.trace.span("window", "window", win.t_dispatch,
                                self.clock(), window=win.index, faulted=True)
            return self._recover_window(win, exc)
        if self.trace.enabled:
            self.trace.span("window", "window", win.t_dispatch, self.clock(),
                            window=win.index, faulted=False)
        if self.speculate:
            toks, counts = (np.asarray(x) for x in jax.device_get(block))
            self._note_advance(win, counts)
            return self._commit_window(win, toks, counts=counts)
        toks = np.asarray(jax.device_get(block))
        return self._commit_window(win, toks)

    def _note_advance(self, win: _WindowInFlight, counts: np.ndarray,
                      metric_limits: Optional[np.ndarray] = None) -> None:
        """Fold a retired speculative window's accepted counts into the host
        position mirror — the only place the host learns how far the device
        chain actually advanced. Lanes that were patched mid-flight or have
        changed owner are skipped: their device position was (or will be)
        reset on the chain, and the mirror was reset with it. Also derives
        the drafted/accepted speculation counters from the counts block:
        step k of a lane with ``rem0`` prompt tokens force-feeds
        ``f_k = max(clip(rem0 - k·(D+1), 0, D+1), 1)`` rows, drafts the
        remaining ``D+1 - f_k``, and accepted drafts are whatever the counts
        show beyond the forced rows. ``metric_limits`` (per-slot first
        faulting step, from the fault path) caps the *counters* — steps at
        and past a real fault ran on corrupted state, so their
        accepts/rejects are noise that must not skew acceptance rates — while
        the position mirror always folds the full window (the device chain
        advanced through every step regardless)."""
        D1 = self.draft_len + 1
        K = self.window
        drafted = accepted = 0
        per_slot: dict[int, tuple[int, int]] = {}
        for slot, rid in enumerate(win.req_ids):
            if rid is None or not win.valid[slot] or win.deferred[slot]:
                continue
            s = self.sched.slots[slot]
            if s.active and s.req.id == rid:
                self._dev_pos[slot] += int(counts[:, slot].sum())
            lim = K if metric_limits is None else int(metric_limits[slot])
            rem = int(win.rem0[slot])
            forced = np.maximum(np.clip(rem - np.arange(lim) * D1, 0, D1), 1)
            d = int((D1 - forced).sum())
            a = int(counts[:lim, slot].sum() - forced.sum())
            if d > 0:
                drafted += d
                accepted += a
                per_slot[slot] = (d, a)
        if drafted:
            self.metrics.record_spec(drafted, accepted, per_slot)
            if self.trace.enabled:
                self.trace.instant("speculate", "spec", window=win.index,
                                   drafted=drafted, accepted=accepted)

    def _flat_block(self, win: _WindowInFlight, toks: np.ndarray,
                    counts: np.ndarray, slot: int, lo: int,
                    hi: int) -> list[int]:
        """Flatten a speculative lane's committable tokens: window steps
        ``lo .. hi-1``, each contributing its accepted rows — starting at the
        lane's flip row in its flip step (earlier rows are prompt-position
        argmaxes, fed not generated)."""
        out = []
        for k in range(lo, hi):
            j0 = int(win.start_row[slot]) if k == lo else 0
            out.extend(int(toks[k, slot, j])
                       for j in range(j0, int(counts[k, slot])))
        return out

    def _commit_window(self, win: _WindowInFlight, toks: np.ndarray,
                       limits: Optional[np.ndarray] = None,
                       counts: Optional[np.ndarray] = None) -> list[Response]:
        """Commit each lane's token block from its first real step
        (``win.start`` — past any prompt-chunk feed) up to EOS / token budget /
        its fault boundary (``limits``, in window steps); trailing tokens are
        discarded. Lanes whose request left the slot since dispatch (finished,
        expired, re-routed) or whose state was patched mid-flight (``valid``
        cleared) are skipped. With speculation (``counts`` given) a window
        step contributes its variable accepted prefix instead of one token —
        the variable-commit contract of DESIGN.md §3.4."""
        now = self.clock()
        K = self.window
        out: list[Response] = []
        committed = discarded = 0
        for slot, rid in enumerate(win.req_ids):
            if rid is None:
                continue                         # lane was free at dispatch
            lo = int(win.start[slot])            # prompt-feed steps emit no
            s = self.sched.slots[slot]           # committable tokens
            if counts is None:
                emitted = K - lo
            else:
                # the flip step's leading prompt rows are fed, not generated
                emitted = max(int(counts[lo:, slot].sum())
                              - int(win.start_row[slot]), 0)
            if not s.active or s.req.id != rid or not win.valid[slot]:
                discarded += emitted
                continue
            limit = K if limits is None else int(limits[slot])
            if limit <= lo:
                block = []
            elif counts is None:
                block = toks[lo:limit, slot]
            else:
                block = self._flat_block(win, toks, counts, slot, lo, limit)
            if self.trace.enabled:
                # capture before commit: a finishing lane clears its slot
                tr = s.req.trace_id
                first_before = s.t_first
            k, done = (self.sched.commit_block(slot, block, now)
                       if len(block) else (0, None))
            committed += k
            discarded += emitted - k
            if self.trace.enabled and tr is not None:
                self.trace.span("decode", "window", win.t_dispatch, now,
                                tid=slot, trace_id=tr, window=win.index,
                                committed=k, discarded=emitted - k)
                if k and first_before is None:
                    self.trace.instant("first_token", "request", ts=now,
                                       tid=slot, trace_id=tr)
                if k:
                    self._trace_recovery_end(slot, tr, now, "recovered")
            if done is not None:
                out.append(done)
        self.metrics.record_window(committed, discarded, K)
        return out

    def _recover_window(self, win: _WindowInFlight,
                        exc: PropagatedError) -> list[Response]:
        """Deferred-detection recovery: the ``(K, slots)`` history attributes
        the fault to its exact ``(step, slot)``; the clean prefix before the
        fault step commits (it is part of the deterministic greedy trajectory)
        and only the faulted suffix is recomputed via LFLR re-prefill."""
        num_slots = self.sched.num_slots
        K = self.window
        faulted = sorted({e.rank for e in exc.errors if 0 <= e.rank < num_slots})
        if not faulted:                      # unattributed word: assume all
            faulted = list(self.sched.active_slots())
        # a lane patched while this window was in flight re-reports its old
        # fault (the window *computed* with the poisoned state even though the
        # state has since been repaired) — stale, already recovered: drop it
        faulted = [s for s in faulted if win.valid[s]]
        if self.speculate:
            toks, counts = (np.asarray(x)
                            for x in jax.device_get(win.fut.outputs))
        else:
            toks = np.asarray(jax.device_get(win.fut.outputs))
            counts = None
        if not faulted:
            if self.speculate:
                self._note_advance(win, counts)
            return self._commit_window(win, toks, counts=counts)
        # first *faulting* step per slot: attribution-only lanes (speculation
        # misses) are masked out, so a rejected draft never truncates the
        # clean committable prefix — and a real fault mid-speculation drops
        # every token from its step on (no stale draft tokens commit)
        steps = win.fut.fault_steps(ignore=getattr(self, "_ignore_codes", 0))
        limits = np.full(num_slots, K, np.int64)
        for slot in faulted:
            limits[slot] = steps[slot] if steps is not None and steps[slot] >= 0 else 0
        if self.speculate:
            # counters capped at each lane's fault boundary: post-fault steps
            # ran on corrupted state and must not skew acceptance rates
            self._note_advance(win, counts, metric_limits=limits)
        decision = self.policy.decide(exc, self._step_count)
        self.metrics.record_fault(self._step_count, int(exc.combined_code),
                                  decision.action.value, tuple(faulted))
        # per-slot exact error words from the (K, slots) history OR-fold:
        # unlike the enumeration table it never truncates, so both the paged
        # ledger repair and the fault spans can attribute every slot even
        # under an enumeration-saturating burst
        codes = (win.fut.fault_codes()
                 if (self.paged or self.trace.enabled) else None)
        if self.paged:
            # page-ownership faults get their own ledger record: the LFLR
            # re-queue repairs them too (free + re-acquire rebuilds the
            # mapping), but a PAGE_FAULT means the host ledger and device
            # table diverged — worth counting separately from soft faults
            page_slots = tuple(
                s for s in faulted if codes is not None
                and int(codes[s]) & int(ErrorCode.PAGE_FAULT))
            if page_slots:
                self.metrics.record_fault(self._step_count,
                                          int(ErrorCode.PAGE_FAULT),
                                          "page_reclaim", page_slots)
        if self.trace.enabled:
            # one fault event per attributed slot, carrying the slot's exact
            # error word (bit-for-bit what fault_codes() read back) and the
            # (window, step) the history latched it at — the detection edge
            # of the causal chain
            t_fault = self.clock()
            for slot in faulted:
                tr = win.trace_ids[slot] if win.trace_ids else None
                word = (int(codes[slot]) if codes is not None
                        else int(exc.combined_code))
                step_i = (int(steps[slot])
                          if steps is not None and steps[slot] >= 0 else None)
                self.trace.instant(
                    "fault", "fault", ts=t_fault, tid=slot, trace_id=tr,
                    slot=slot, window=win.index, step=step_i, code=word,
                    code_names=[c.name for c in ErrorCode(word).classes()],
                    action=decision.action.value)
            if self.tp > 1:
                # reconciliation fan-out: the OR-folded word latched on EVERY
                # shard of the model mesh — one instant per shard, so the
                # post-mortem can check that no shard missed (or diverged
                # from) the fault its peers recovered from
                for shard in range(self.tp):
                    self.trace.instant(
                        "shard_fanout", "shard", ts=t_fault,
                        tid=SHARD_TID + shard, shard=shard, tp=self.tp,
                        window=win.index, code=int(exc.combined_code))
        if decision.action is Action.ROLLBACK:
            targets, fail_now = list(self.sched.active_slots()), False
        elif decision.action is Action.ABORT:
            targets, fail_now = faulted, True
        else:   # SKIP_BATCH / RESTORE_GOOD / CONTINUE / ... → per-sequence LFLR
            targets, fail_now = faulted, False
        out = self._commit_window(win, toks, limits=limits, counts=counts)
        faulted_set = set(faulted)
        for slot in targets:
            s = self.sched.slots[slot]
            if not s.active or s.req.id != win.req_ids[slot]:
                continue                     # finished/evicted inside its prefix
            if slot in faulted_set:
                retries = self.sched.note_retry(slot)
            else:
                retries = self.sched.request(slot).retries
            if fail_now or retries > self.max_request_retries:
                out.append(self.sched.evict(
                    slot, FAILED,
                    detail=f"{decision.reason} (retries={retries})"))
                if self._pending is not None:
                    # the in-flight speculative window computed with the same
                    # poisoned state; without a prefill patch clearing it, its
                    # lane would re-raise this fault as a new one at retire
                    self._pending.valid[slot] = False
                continue
            if self.trace.enabled:
                word = (int(codes[slot]) if codes is not None
                        and slot in faulted_set else 0)
                self._trace_recovery_begin(
                    slot, s.req.trace_id, word, decision.action.value,
                    win.index, self.clock())
            resp = self._lflr_slot(slot)     # LFLR: recompute, don't restart
            if resp is not None:
                out.append(resp)
        return out

    def _lflr_slot(self, slot: int) -> Optional[Response]:
        """Window-mode LFLR recompute for one lane.

        Overlapped: re-queue the lane — the scheduler chunks prompt +
        committed tokens back into the cache through subsequent fused windows
        (the cache reset rides the next dispatch), and the in-flight
        speculative window's stale lane is invalidated. The host never blocks.
        Blocking mode: the synchronous re-prefill."""
        if not self.overlap:
            return self._prefill_slot(slot)
        self.sched.begin_prefill(slot)
        if self._pending is not None:
            self._pending.valid[slot] = False
        return None

    # --------------------------------------------------------------- recovery
    def _recover(self, exc: PropagatedError, fut: DeviceFuture) -> list[Response]:
        decision = self.policy.decide(exc, self._step_count)
        num_slots = self.sched.num_slots
        faulted = sorted({e.rank for e in exc.errors if 0 <= e.rank < num_slots})
        if not faulted:                      # unattributed word: assume all
            faulted = list(self.sched.active_slots())
        self.metrics.record_fault(self._step_count, int(exc.combined_code),
                                  decision.action.value, tuple(faulted))
        slot_codes: dict[int, int] = {}
        if self.trace.enabled:
            # stepwise engine: no window history — the enumeration's
            # per-(slot, code) pairs are the exact attribution
            for e in exc.errors:
                if 0 <= e.rank < num_slots:
                    slot_codes[e.rank] = slot_codes.get(e.rank, 0) | int(e.code)
            t_fault = self.clock()
            for slot in faulted:
                s = self.sched.slots[slot]
                tr = s.req.trace_id if s.active else None
                word = slot_codes.get(slot, int(exc.combined_code))
                self.trace.instant(
                    "fault", "fault", ts=t_fault, tid=slot, trace_id=tr,
                    slot=slot, step=self._step_count, code=word,
                    code_names=[c.name for c in ErrorCode(word).classes()],
                    action=decision.action.value)
        # Slots are independent under vmap: the dispatched outputs of the
        # non-faulted slots are valid, so salvage them and only recompute the
        # attributed ones — this is what keeps one bad sequence from stalling
        # the whole batch.
        self._slot_logits, self.caches = fut.outputs
        if decision.action is Action.ROLLBACK:
            # escalation: recompute every lane (whole-batch recompute is the
            # serving analogue of restoring the last checkpoint)
            targets, fail_now = list(self.sched.active_slots()), False
        elif decision.action is Action.ABORT:
            targets, fail_now = faulted, True
        else:   # SKIP_BATCH / RESTORE_GOOD / CONTINUE / ... → per-sequence LFLR
            targets, fail_now = faulted, False
        out = self._commit(skip=frozenset(targets))
        faulted_set = set(faulted)
        for slot in targets:
            if not self.sched.slots[slot].active:
                continue                     # already evicted this cycle
            # only the slots the enumeration attributed pay a retry: a healthy
            # lane swept into a ROLLBACK recompute must not burn its budget
            # (FAILED is reserved for requests that re-fault on recompute)
            if slot in faulted_set:
                retries = self.sched.note_retry(slot)
            else:
                retries = self.sched.request(slot).retries
            if fail_now or retries > self.max_request_retries:
                out.append(self.sched.evict(
                    slot, FAILED,
                    detail=f"{decision.reason} (retries={retries})"))
                continue
            if self.trace.enabled:
                word = (slot_codes.get(slot, int(exc.combined_code))
                        if slot in faulted_set else 0)
                self._trace_recovery_begin(
                    slot, self.sched.request(slot).trace_id, word,
                    decision.action.value, self._step_count, self.clock())
            resp = self._prefill_slot(slot)  # LFLR: recompute, don't restart
            if resp is not None:
                out.append(resp)
        return out

    # ---------------------------------------------------------------- prefill
    def _prefill_slot(self, slot: int) -> Optional[Response]:
        """*Blocking* (re-)compute of a slot's cache from its full token
        history, committing the next token from the prefill logits. Serves
        admission and the LFLR recompute on the stepwise and non-overlapped
        window engines; the overlapped engine replaces it with background
        lanes (``sched.begin_prefill`` + the fused window) and never blocks
        here. The wall time spent inside — the host stall every healthy slot
        pays — is recorded via ``metrics.record_host_stall``.

        In (non-overlapped) window mode this is also the *patch point* of the
        double-buffered pipeline: the rebuilt cache / next-token / position
        overwrite the lane's device state (the in-flight speculative window's
        outputs), and the lane is marked invalid in that window so its stale
        block is skipped at retirement.

        In paged mode the rebuilt cache is written straight into the slot's
        (re-acquired, in-program-scrubbed) pool pages — there is no cache to
        insert afterwards, only the device token feed to update."""
        t0 = self.clock()
        if self.trace.enabled:
            # capture before commit: a finishing lane clears its slot
            tr = self.sched.request(slot).trace_id
            first_before = self.sched.slots[slot].t_first
        try:
            while True:
                tokens = np.asarray([self.sched.sequence_tokens(slot)],
                                    np.int32)
                if self.paged:
                    # recycle + reacquire the lane's pages for the full
                    # sequence plus its first generated write position
                    self._release_pages(slot)
                    self._check_pages()
                    if self._grow_slot(slot, tokens.shape[1] + 1,
                                       exclude_self=True) is None:
                        raise AssertionError("blocking prefill self-evicted")
                    logits, hybrid, word = self._prefill(
                        self.params, self.caches,
                        jnp.asarray(self.page_table[slot]), jnp.int32(slot),
                        tokens)
                    # rebind NOW: the pool was donated to the dispatch, and a
                    # faulted attempt's stray writes are confined to this
                    # slot's row (drop-mode) and scrubbed by the retry's
                    # in-program fresh_slot
                    self.caches = hybrid
                    fut = DeviceFuture(outputs=(logits, hybrid), word=word)
                else:
                    logits, cache, word = self._prefill(self.params, tokens,
                                                        self.max_len)
                    fut = DeviceFuture(outputs=(logits, cache), word=word)
                try:
                    logits, cache = fut.wait()
                    break
                except PropagatedError as exc:
                    retries = self.sched.note_retry(slot)
                    self.metrics.record_fault(self._step_count,
                                              int(exc.combined_code),
                                              "prefill_retry", (slot,))
                    if self.trace.enabled:
                        word = int(exc.combined_code)
                        self.trace.instant(
                            "fault", "fault", tid=slot, trace_id=tr,
                            slot=slot, step=self._step_count, code=word,
                            code_names=[c.name
                                        for c in ErrorCode(word).classes()],
                            action="prefill_retry")
                        self._trace_recovery_begin(
                            slot, tr, word, "prefill_retry",
                            self._step_count, self.clock())
                    if retries > self.max_request_retries:
                        return self.sched.evict(
                            slot, FAILED,
                            detail=f"prefill faulted {retries} times: {exc}")
            tok = int(jax.device_get(jnp.argmax(logits[0, -1])))
            if self.paged:
                # `cache` is the updated hybrid tree: pool writes landed
                # through the page table, dense leaves at the slot slice
                self.caches = cache
                self._dev_tokens = self._set_tok(self._dev_tokens,
                                                 jnp.int32(slot),
                                                 jnp.int32(tok))
            else:
                self.caches, self._dev_tokens = self._insert(
                    self.caches, cache, jnp.int32(slot), self._dev_tokens,
                    jnp.int32(tok))
            if not self.window:
                # only the stepwise commit path reads logits back per slot
                self._slot_logits = self._slot_logits.at[slot].set(
                    logits.astype(jnp.float32))
            t_commit = self.clock()
            resp = self.sched.commit_token(slot, tok, t_commit)
            self.metrics.record_prefill(1)
            if self.trace.enabled and tr is not None:
                self.trace.span("prefill", "prefill", t0, t_commit, tid=slot,
                                trace_id=tr, slot=slot,
                                tokens=int(tokens.shape[1]))
                if first_before is None:
                    self.trace.instant("first_token", "request", ts=t_commit,
                                       tid=slot, trace_id=tr)
                self._trace_recovery_end(slot, tr, t_commit, "recovered")
            if self.window:
                s = self.sched.slots[slot]
                self._dev_pos[slot] = s.seq_len - 1 if s.active else 0
                if self._pending is not None:
                    self._pending.valid[slot] = False
            return resp
        finally:
            self.metrics.record_host_stall(self.clock() - t0)
