"""Continuous-batching scheduler: fixed decode slots, evict + backfill.

The scheduler is the host-side brain of a replica. It never touches JAX: it
tracks which request occupies which decode slot, hands the replica the
``(tokens, pos)`` arrays for the next fused step, consumes the sampled token
per slot, evicts finished/expired/faulted sequences and backfills freed slots
from the admission queue *every step* — prefill and decode share the same
fixed-shape batch, so a long request never blocks the lane (the serving
counterpart of the paper's "local errors must not block global progress").

:class:`PageAllocator` is the host half of the paged KV pool
(``launch/paging.py`` holds the device half): a free list plus a per-slot
ownership ledger. It is deliberately dumb — pure accounting, no JAX — so its
invariants (no page owned twice, double frees rejected, exact free-count
arithmetic under arbitrary alloc/free interleavings) are unit-testable
without a device in sight.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .queue import EXPIRED, OK, Request, RequestQueue, Response


class PagePoolExhausted(RuntimeError):
    """Not enough free pages — the caller must evict or defer (never drop)."""


class PageAllocator:
    """Free list + per-slot page-ownership ledger for the paged KV pool.

    * **allocation order is irrelevant by design** — the device addresses
      pages through the table, so fragmentation of the physical id space
      never degrades anything (there is no "contiguity" to lose);
    * **watermark-driven admission**: :meth:`can_admit` says whether a new
      sequence's first pages fit while keeping ``watermark`` pages free as
      headroom for in-flight lanes to grow into (one page per active lane is
      a sensible default at call sites);
    * **strict frees**: freeing a slot that owns nothing, or a page that is
      not owned by that slot, raises — a double free means the host ledger
      and the device table have diverged, which is exactly the corruption
      the in-band ``PAGE_FAULT`` probe exists to catch, so it must never be
      papered over.
    """

    def __init__(self, num_pages: int, page_size: int, *, watermark: int = 0):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if watermark < 0:
            raise ValueError(f"watermark must be >= 0, got {watermark}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.watermark = int(watermark)
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        self._owned: dict[int, list[int]] = {}

    # ---------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.page_size)

    def owns(self, slot: int) -> bool:
        return bool(self._owned.get(slot))

    def owned(self, slot: int) -> tuple[int, ...]:
        """Slot's pages in logical-page order (index i holds positions
        ``[i*page_size, (i+1)*page_size)``)."""
        return tuple(self._owned.get(slot, ()))

    def can_admit(self, n_tokens: int) -> bool:
        """True iff ``n_tokens`` worth of pages fit with the watermark spare.

        The headroom is waived for a request so large that ``need +
        watermark`` exceeds the whole pool: such a request could *never*
        pass the gated check even with every page free, and an accepted
        request must eventually be admitted, not deferred forever — it is
        admitted whenever it plainly fits instead."""
        need = self.pages_for(n_tokens)
        headroom = (self.watermark
                    if need + self.watermark <= self.num_pages else 0)
        return need <= self.free_pages - headroom

    # ------------------------------------------------------------- alloc/free
    def alloc(self, slot: int, n: int) -> list[int]:
        """Grow ``slot`` by ``n`` pages; returns the new physical ids (the
        caller appends them to the device table *and scrubs them* before any
        step reads them). Raises :class:`PagePoolExhausted` without partial
        effect when the pool cannot cover the request."""
        if n < 0:
            raise ValueError(f"cannot alloc {n} pages")
        if n > len(self._free):
            raise PagePoolExhausted(
                f"slot {slot} needs {n} pages, {len(self._free)} free "
                f"of {self.num_pages}")
        got = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(slot, []).extend(got)
        return got

    def free_slot(self, slot: int) -> list[int]:
        """Return all of ``slot``'s pages to the free list; returns the freed
        ids. Freeing a slot that owns nothing is a double free — rejected."""
        pages = self._owned.pop(slot, None)
        if not pages:
            raise ValueError(f"double free: slot {slot} owns no pages")
        # cross-ownership corruption is asserted by check() (tests/debug);
        # scanning every owner here would put an O(pages²) walk on the hot
        # finish/evict path
        self._free.extend(pages)
        return pages

    # -------------------------------------------------------------- invariant
    def check(self) -> None:
        """Assert ledger consistency (tests / debugging / the fuzzer oracle):
        every page is free or owned exactly once. Raises ``AssertionError``
        explicitly (not via ``assert``) so the invariant still fires under
        ``python -O`` — a fuzz oracle that silently evaporates is worse than
        none."""
        seen: dict[int, str] = {}
        for p in self._free:
            if p in seen:
                raise AssertionError(f"page {p} double-listed as free")
            seen[p] = "free"
        for slot, pages in self._owned.items():
            for p in pages:
                if p in seen:
                    raise AssertionError(
                        f"page {p} owned by slot {slot} and {seen[p]}")
                seen[p] = f"slot {slot}"
        if len(seen) != self.num_pages:
            raise AssertionError(
                f"{self.num_pages - len(seen)} pages leaked")


@dataclass
class Slot:
    """One decode lane. ``req is None`` ⇔ the lane is free.

    ``pending`` is the overlapped-prefill lane state: the token sequence being
    chunked into the cache through decode windows (the prompt at admission,
    prompt + generated at an LFLR recompute). ``pending is None`` ⇔ the slot
    is decoding; ``prefill_pos`` counts pending tokens already dispatched to
    the device chain.
    """

    idx: int
    req: Optional[Request] = None
    generated: list[int] = field(default_factory=list)
    t_first: Optional[float] = None      # wall time of the first generated token
    pending: Optional[list[int]] = None  # tokens being chunk-prefilled, or None
    prefill_pos: int = 0                 # pending tokens already fed on device

    @property
    def active(self) -> bool:
        return self.req is not None

    @property
    def prefilling(self) -> bool:
        return self.req is not None and self.pending is not None

    @property
    def seq_len(self) -> int:
        """Tokens whose state is already in the cache (prompt + generated)."""
        return len(self.req.prompt) + len(self.generated) if self.req else 0

    def clear(self) -> None:
        self.req = None
        self.generated = []
        self.t_first = None
        self.pending = None
        self.prefill_pos = 0


@dataclass(frozen=True)
class ChunkPlan:
    """One lane's share of a decode window's prefill budget.

    ``rem`` steps of the window feed ``tokens`` (prompt chunk) instead of
    greedy feedback; ``rem == 0`` means the lane is deferred this window (it
    must be masked out — its cache holds no valid state yet). ``exhausts``
    marks the flip window: the lane's last pending token lands at step
    ``rem - 1``, whose argmax is its first real generated token. ``fresh``
    marks a lane's first chunk — the replica must reset the slot's cache (and
    position) on device before dispatching this window.
    """

    tokens: tuple[int, ...]
    rem: int
    exhausts: bool
    fresh: bool


class ContinuousBatchingScheduler:
    """Slot bookkeeping for one replica.

    The replica drives it in a strict step cycle::

        expire_active → backfill (replica prefills the admitted slots)
        → step_inputs → [fused decode on device] → commit_token per slot

    In window mode (``Replica(window=K)``) the cycle retires one K-token
    decode window per step instead: ``commit_block`` consumes each lane's
    token block up to EOS / budget / fault boundary and discards the trailing
    tokens the deferred-detection window over-decoded.

    On a fault, ``sequence_tokens``/``note_retry`` feed the LFLR recompute.
    """

    def __init__(self, num_slots: int, queue: RequestQueue, *,
                 replica: Optional[int] = None, eos_id: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 prefill_budget: Optional[int] = None,
                 can_admit: Optional[Callable[[Request], bool]] = None,
                 on_release: Optional[Callable[[int], None]] = None):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1 (or None)")
        self.queue = queue
        self.slots = [Slot(i) for i in range(num_slots)]
        self.replica = replica
        self.eos_id = eos_id
        self.clock = clock
        self.prefill_budget = prefill_budget
        # paged-KV hooks: `can_admit` gates backfill on pool headroom
        # (watermark admission); `on_release` fires whenever a slot stops
        # owning its request (finish, expiry, failure, preemption) so the
        # page ledger can reclaim without the replica chasing every exit path
        self.can_admit = can_admit
        self.on_release = on_release

    # ---------------------------------------------------------------- queries
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    def active_slots(self) -> list[int]:
        return [s.idx for s in self.slots if s.active]

    def free_slots(self) -> list[int]:
        return [s.idx for s in self.slots if not s.active]

    def has_active(self) -> bool:
        return any(s.active for s in self.slots)

    def in_flight(self) -> int:
        return len(self.active_slots())

    def pressure(self) -> dict:
        """Occupancy snapshot for the group autoscaler: queued requests,
        busy slots, total slots. Pure bookkeeping — no device sync."""
        return {"queued": len(self.queue), "active": self.in_flight(),
                "slots": self.num_slots}

    def request(self, slot: int) -> Request:
        req = self.slots[slot].req
        assert req is not None, f"slot {slot} is free"
        return req

    def sequence_tokens(self, slot: int) -> list[int]:
        """Prompt + generated so far — the LFLR recompute input."""
        s = self.slots[slot]
        assert s.req is not None
        return list(s.req.prompt) + s.generated

    def prefilling_slots(self) -> list[int]:
        return [s.idx for s in self.slots if s.prefilling]

    # ------------------------------------------------- overlapped prefill lanes
    def begin_prefill(self, slot: int) -> None:
        """Turn a slot into a background prefill lane.

        Admission and LFLR recovery are literally the same lane: the pending
        sequence is prompt + generated-so-far (empty at admission), chunked
        into the cache by subsequent decode windows via :meth:`plan_prefill`.
        Re-calling on an already-prefilling lane restarts it from position 0
        (the LFLR restart after a fault mid-chunk — the recurrent state is
        poisoned, so the whole sequence recomputes; committed tokens are kept
        and replayed, which is what makes the recovery bit-exact)."""
        s = self.slots[slot]
        assert s.req is not None, f"begin_prefill on free slot {slot}"
        s.pending = self.sequence_tokens(slot)
        s.prefill_pos = 0

    def plan_prefill(self, window: int,
                     budget: Optional[int] = None) -> dict[int, ChunkPlan]:
        """Split the next window's token budget between decode and prefill.

        Returns a :class:`ChunkPlan` per prefilling lane and advances each
        planned lane's ``prefill_pos`` (the device chain consumes the chunk at
        dispatch; a fault later rewinds via :meth:`begin_prefill`). Budgeting
        (Sarathi-style, per window):

        * an in-progress lane (``prefill_pos > 0``) always gets
          ``min(window, remaining)`` — a half-built cache must keep advancing
          every window it participates in, because a parked lane would decode
          garbage into its own state (the no-park invariant);
        * a fresh lane starts only if the remaining budget covers its first
          chunk *whole* (a partial non-exhausting chunk would break the
          no-park invariant); fresh lanes start oldest-arrival-first, so
          under load the budget prioritises the TTFT of the longest-waiting
          request;
        * a deferred fresh lane gets ``ChunkPlan(rem=0)`` — the replica masks
          it out of the window entirely;
        * the effective budget is clamped to ≥ ``window``: a first chunk is
          at most one window, so a smaller budget could never admit it and a
          fresh lane would starve for as long as any slot keeps decoding.

        ``budget=None`` means unthrottled (every lane chunks every window).
        When a lane's chunk exhausts its pending sequence the lane flips to
        decoding (``pending = None``) — from step ``rem - 1`` of that window
        onwards its token block is real output.
        """
        budget = self.prefill_budget if budget is None else budget
        left = float("inf") if budget is None else max(int(budget),
                                                       int(window))
        lanes = [s for s in self.slots if s.prefilling]
        # in-progress first (correctness), then fresh by arrival (TTFT)
        lanes.sort(key=lambda s: (s.prefill_pos == 0,
                                  s.req.arrival_t if s.req.arrival_t is not None
                                  else float("inf"), s.idx))
        # liveness: deferring is only legal while something else makes progress
        work = any(s.active and not s.prefilling for s in self.slots)
        plan: dict[int, ChunkPlan] = {}
        for s in lanes:
            remaining = len(s.pending) - s.prefill_pos
            n = min(window, remaining)
            fresh = s.prefill_pos == 0
            if fresh and n > left and work:
                plan[s.idx] = ChunkPlan(tokens=(), rem=0, exhausts=False,
                                        fresh=True)
                continue
            toks = tuple(s.pending[s.prefill_pos:s.prefill_pos + n])
            exhausts = s.prefill_pos + n == len(s.pending)
            plan[s.idx] = ChunkPlan(tokens=toks, rem=n, exhausts=exhausts,
                                    fresh=fresh)
            s.prefill_pos += n
            left -= n
            work = True
            if exhausts:
                s.pending = None
                s.prefill_pos = 0
        return plan

    # ------------------------------------------------------------- admission
    def backfill(self, now: Optional[float] = None) -> list[tuple[int, Request]]:
        """Fill free slots from the queue; returns (slot, request) pairs the
        replica must prefill before the next decode step."""
        now = self.clock() if now is None else now
        admitted = []
        for s in self.slots:
            if s.active:
                continue
            req = self.queue.pop(now)
            if req is None:
                break
            if self.can_admit is not None and not self.can_admit(req):
                # pool headroom exhausted: put it back (ahead of its class)
                # and stop admitting this cycle — deferred, never dropped
                self.queue.requeue(req)
                break
            s.req = req
            s.generated = []
            s.t_first = None
            admitted.append((s.idx, req))
        return admitted

    # ------------------------------------------------------------ step cycle
    def step_inputs(self) -> tuple[np.ndarray, np.ndarray]:
        """(tokens (S,1,1) int32, pos (S,) int32) for the fused decode step.

        An active slot feeds its last token at its own absolute position; free
        slots decode a dummy token at position 0 (their word is masked out and
        their cache is overwritten at admission, so the work is dead weight the
        fixed-shape batch pays for simplicity).
        """
        S = self.num_slots
        tokens = np.zeros((S, 1, 1), np.int32)
        pos = np.zeros((S,), np.int32)
        for s in self.slots:
            if not s.active:
                continue
            # The cache holds states for positions [0, seq_len-1): prefill
            # consumed the prompt, decode consumed every generated token but
            # the newest. The input is that newest token (the first one comes
            # from the prefill logits, committed in Replica._prefill_slot, so
            # active slots always have generated ≥ 1), at position seq_len-1.
            last = s.generated[-1] if s.generated else s.req.prompt[-1]
            tokens[s.idx, 0, 0] = last
            pos[s.idx] = s.seq_len - 1
        return tokens, pos

    def active_mask(self) -> np.ndarray:
        return np.asarray([1 if s.active else 0 for s in self.slots], np.uint32)

    def commit_token(self, slot: int, token: int,
                     now: Optional[float] = None) -> Optional[Response]:
        """Record one sampled token; returns a Response iff the slot finished."""
        now = self.clock() if now is None else now
        s = self.slots[slot]
        assert s.req is not None, f"commit on free slot {slot}"
        if s.t_first is None:
            s.t_first = now
        s.generated.append(int(token))
        done = (len(s.generated) >= s.req.max_new_tokens
                or (self.eos_id is not None and int(token) == self.eos_id))
        if not done:
            return None
        return self._finish(s, OK, now)

    def commit_block(self, slot: int, tokens, now: Optional[float] = None,
                     limit: Optional[int] = None
                     ) -> tuple[int, Optional[Response]]:
        """Commit a window's token block for one lane.

        Feeds ``tokens[:limit]`` through :meth:`commit_token` until the
        request finishes (EOS / token budget); returns ``(consumed, response)``
        where ``response`` is non-None iff the lane finished mid-block —
        everything after that boundary is discarded by the caller.

        ``tokens`` is a *variable-length* sequence by contract: the plain
        window engine hands K tokens, the speculative engine hands each
        lane's flattened accepted prefixes (1 to K·(D+1) tokens, pre-cut at
        the lane's fault boundary) — EOS and the token budget are checked
        token-by-token either way, so a request that ends *inside* an
        accepted draft run finishes at exactly the same token as the plain
        engine and the trailing accepts are discarded.
        """
        now = self.clock() if now is None else now
        limit = len(tokens) if limit is None else min(limit, len(tokens))
        consumed = 0
        for k in range(limit):
            resp = self.commit_token(slot, int(tokens[k]), now)
            consumed += 1
            if resp is not None:
                return consumed, resp
        return consumed, None

    def note_retry(self, slot: int) -> int:
        """Count one LFLR recompute against the slot's request; returns total."""
        req = self.request(slot)
        req.retries += 1
        return req.retries

    # -------------------------------------------------------------- eviction
    def evict(self, slot: int, status: str, now: Optional[float] = None,
              detail: str = "") -> Response:
        """Terminal eviction (EXPIRED / FAILED); frees the slot."""
        now = self.clock() if now is None else now
        return self._finish(self.slots[slot], status, now, detail=detail)

    def expire_active(self, now: Optional[float] = None) -> list[Response]:
        """Evict active sequences whose deadline passed mid-decode."""
        now = self.clock() if now is None else now
        out = []
        for s in self.slots:
            if s.active and s.req.deadline is not None and now >= s.req.deadline:
                out.append(self._finish(s, EXPIRED, now,
                                        detail="deadline passed mid-decode"))
        return out

    def _finish(self, s: Slot, status: str, now: float,
                detail: str = "") -> Response:
        req = s.req
        resp = Response(
            id=req.id, status=status, tokens=tuple(s.generated),
            latency_s=now - req.arrival_t,
            ttft_s=(s.t_first - req.arrival_t) if s.t_first is not None else None,
            retries=req.retries, replica=self.replica, detail=detail,
            trace_id=req.trace_id)
        s.clear()
        if self.on_release is not None:
            self.on_release(s.idx)
        return resp

    def preempt(self, slot: int) -> Request:
        """Non-terminal eviction: pull the request out of its slot with its
        progress discarded (the next owner recomputes from the prompt — the
        single-replica analogue of ``drain_in_flight``, used by the paged
        engine's memory-pressure path). The caller MUST requeue the returned
        request: an accepted request is never dropped. Fault retries already
        consumed are *preserved* (unlike the group ledger's cross-replica
        re-route, the same replica keeps serving it): a persistently
        faulting request must still converge to FAILED instead of laundering
        its retry budget through evictions."""
        s = self.slots[slot]
        req = s.req
        assert req is not None, f"preempt on free slot {slot}"
        s.clear()
        if self.on_release is not None:
            self.on_release(slot)
        return req

    # ------------------------------------------------------------- re-route
    def drain_in_flight(self) -> list[Request]:
        """Pull every in-flight request out of its slot (progress discarded —
        the receiving replica recomputes from the prompt). API for external
        drivers that rebalance work off a *live* replica; note a ServeGroup
        kill is re-routed through the group ledger instead, since a dead
        replica's scheduler can no longer be drained."""
        out = []
        for s in self.slots:
            if s.active:
                out.append(s.req)
                s.clear()
                if self.on_release is not None:
                    self.on_release(s.idx)
        return out
