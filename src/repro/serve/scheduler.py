"""Continuous-batching scheduler: fixed decode slots, evict + backfill.

The scheduler is the host-side brain of a replica. It never touches JAX: it
tracks which request occupies which decode slot, hands the replica the
``(tokens, pos)`` arrays for the next fused step, consumes the sampled token
per slot, evicts finished/expired/faulted sequences and backfills freed slots
from the admission queue *every step* — prefill and decode share the same
fixed-shape batch, so a long request never blocks the lane (the serving
counterpart of the paper's "local errors must not block global progress").
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .queue import EXPIRED, OK, Request, RequestQueue, Response


@dataclass
class Slot:
    """One decode lane. ``req is None`` ⇔ the lane is free."""

    idx: int
    req: Optional[Request] = None
    generated: list[int] = field(default_factory=list)
    t_first: Optional[float] = None      # wall time of the first generated token

    @property
    def active(self) -> bool:
        return self.req is not None

    @property
    def seq_len(self) -> int:
        """Tokens whose state is already in the cache (prompt + generated)."""
        return len(self.req.prompt) + len(self.generated) if self.req else 0

    def clear(self) -> None:
        self.req = None
        self.generated = []
        self.t_first = None


class ContinuousBatchingScheduler:
    """Slot bookkeeping for one replica.

    The replica drives it in a strict step cycle::

        expire_active → backfill (replica prefills the admitted slots)
        → step_inputs → [fused decode on device] → commit_token per slot

    In window mode (``Replica(window=K)``) the cycle retires one K-token
    decode window per step instead: ``commit_block`` consumes each lane's
    token block up to EOS / budget / fault boundary and discards the trailing
    tokens the deferred-detection window over-decoded.

    On a fault, ``sequence_tokens``/``note_retry`` feed the LFLR recompute.
    """

    def __init__(self, num_slots: int, queue: RequestQueue, *,
                 replica: Optional[int] = None, eos_id: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.queue = queue
        self.slots = [Slot(i) for i in range(num_slots)]
        self.replica = replica
        self.eos_id = eos_id
        self.clock = clock

    # ---------------------------------------------------------------- queries
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    def active_slots(self) -> list[int]:
        return [s.idx for s in self.slots if s.active]

    def free_slots(self) -> list[int]:
        return [s.idx for s in self.slots if not s.active]

    def has_active(self) -> bool:
        return any(s.active for s in self.slots)

    def in_flight(self) -> int:
        return len(self.active_slots())

    def request(self, slot: int) -> Request:
        req = self.slots[slot].req
        assert req is not None, f"slot {slot} is free"
        return req

    def sequence_tokens(self, slot: int) -> list[int]:
        """Prompt + generated so far — the LFLR recompute input."""
        s = self.slots[slot]
        assert s.req is not None
        return list(s.req.prompt) + s.generated

    # ------------------------------------------------------------- admission
    def backfill(self, now: Optional[float] = None) -> list[tuple[int, Request]]:
        """Fill free slots from the queue; returns (slot, request) pairs the
        replica must prefill before the next decode step."""
        now = self.clock() if now is None else now
        admitted = []
        for s in self.slots:
            if s.active:
                continue
            req = self.queue.pop(now)
            if req is None:
                break
            s.req = req
            s.generated = []
            s.t_first = None
            admitted.append((s.idx, req))
        return admitted

    # ------------------------------------------------------------ step cycle
    def step_inputs(self) -> tuple[np.ndarray, np.ndarray]:
        """(tokens (S,1,1) int32, pos (S,) int32) for the fused decode step.

        An active slot feeds its last token at its own absolute position; free
        slots decode a dummy token at position 0 (their word is masked out and
        their cache is overwritten at admission, so the work is dead weight the
        fixed-shape batch pays for simplicity).
        """
        S = self.num_slots
        tokens = np.zeros((S, 1, 1), np.int32)
        pos = np.zeros((S,), np.int32)
        for s in self.slots:
            if not s.active:
                continue
            # The cache holds states for positions [0, seq_len-1): prefill
            # consumed the prompt, decode consumed every generated token but
            # the newest. The input is that newest token (the first one comes
            # from the prefill logits, committed in Replica._prefill_slot, so
            # active slots always have generated ≥ 1), at position seq_len-1.
            last = s.generated[-1] if s.generated else s.req.prompt[-1]
            tokens[s.idx, 0, 0] = last
            pos[s.idx] = s.seq_len - 1
        return tokens, pos

    def active_mask(self) -> np.ndarray:
        return np.asarray([1 if s.active else 0 for s in self.slots], np.uint32)

    def commit_token(self, slot: int, token: int,
                     now: Optional[float] = None) -> Optional[Response]:
        """Record one sampled token; returns a Response iff the slot finished."""
        now = self.clock() if now is None else now
        s = self.slots[slot]
        assert s.req is not None, f"commit on free slot {slot}"
        if s.t_first is None:
            s.t_first = now
        s.generated.append(int(token))
        done = (len(s.generated) >= s.req.max_new_tokens
                or (self.eos_id is not None and int(token) == self.eos_id))
        if not done:
            return None
        return self._finish(s, OK, now)

    def commit_block(self, slot: int, tokens, now: Optional[float] = None,
                     limit: Optional[int] = None
                     ) -> tuple[int, Optional[Response]]:
        """Commit a window's token block for one lane.

        Feeds ``tokens[:limit]`` through :meth:`commit_token` until the
        request finishes (EOS / token budget); returns ``(consumed, response)``
        where ``response`` is non-None iff the lane finished mid-block —
        everything after that boundary is discarded by the caller.
        """
        now = self.clock() if now is None else now
        limit = len(tokens) if limit is None else min(limit, len(tokens))
        consumed = 0
        for k in range(limit):
            resp = self.commit_token(slot, int(tokens[k]), now)
            consumed += 1
            if resp is not None:
                return consumed, resp
        return consumed, None

    def note_retry(self, slot: int) -> int:
        """Count one LFLR recompute against the slot's request; returns total."""
        req = self.request(slot)
        req.retries += 1
        return req.retries

    # -------------------------------------------------------------- eviction
    def evict(self, slot: int, status: str, now: Optional[float] = None,
              detail: str = "") -> Response:
        """Terminal eviction (EXPIRED / FAILED); frees the slot."""
        now = self.clock() if now is None else now
        return self._finish(self.slots[slot], status, now, detail=detail)

    def expire_active(self, now: Optional[float] = None) -> list[Response]:
        """Evict active sequences whose deadline passed mid-decode."""
        now = self.clock() if now is None else now
        out = []
        for s in self.slots:
            if s.active and s.req.deadline is not None and now >= s.req.deadline:
                out.append(self._finish(s, EXPIRED, now,
                                        detail="deadline passed mid-decode"))
        return out

    def _finish(self, s: Slot, status: str, now: float,
                detail: str = "") -> Response:
        req = s.req
        resp = Response(
            id=req.id, status=status, tokens=tuple(s.generated),
            latency_s=now - req.arrival_t,
            ttft_s=(s.t_first - req.arrival_t) if s.t_first is not None else None,
            retries=req.retries, replica=self.replica, detail=detail)
        s.clear()
        return resp

    # ------------------------------------------------------------- re-route
    def drain_in_flight(self) -> list[Request]:
        """Pull every in-flight request out of its slot (progress discarded —
        the receiving replica recomputes from the prompt). API for external
        drivers that rebalance work off a *live* replica; note a ServeGroup
        kill is re-routed through the group ledger instead, since a dead
        replica's scheduler can no longer be drained."""
        out = []
        for s in self.slots:
            if s.active:
                out.append(s.req)
                s.clear()
        return out
