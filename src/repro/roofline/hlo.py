"""Parse compiled (post-SPMD) HLO text for collective traffic.

``cost_analysis()`` does not expose collective bytes, so we extract them from the
optimized module: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` instruction's *result* shape is summed
(per-device bytes — the module is the per-device SPMD program). Start/done pairs
(``all-gather-start`` etc.) are counted once via the start op.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute")

# one shape token, e.g. f32[16,128]{1,0} or bf16[] — layout suffix optional
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# instruction line: "%name = <shape-or-tuple> <opcode>(" — opcode may have -start
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)]*?\)?)\s+"
    r"(" + "|".join(COLLECTIVE_KINDS) + r")(-start)?\(")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(int))
    count_by_kind: dict = field(default_factory=lambda: defaultdict(int))
    largest: list = field(default_factory=list)      # (bytes, kind, line prefix)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def to_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
            "largest": self.largest[:10],
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_text, kind, started = m.group(1), m.group(2), m.group(3)
        # "-done" ops carry the same result shape; only count starts + sync ops
        if "-done(" in line:
            continue
        b = _shape_bytes(shape_text)
        stats.bytes_by_kind[kind] += b
        stats.count_by_kind[kind] += 1
        stats.largest.append((b, kind, line.strip()[:120]))
    stats.largest.sort(key=lambda t: -t[0])
    return stats


_FUSION_RE = re.compile(r"\bfusion\(")

# ---------------------------------------------------------------- HBM estimator
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_RESULT_NAME = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=")
_WHILE_ATTRS = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPCODE = re.compile(r"=\s*(?:\(?[^)=]*?\)?)\s+([a-z][\w\-]*)\(")

_ZERO_COST_OPS = {"parameter", "bitcast", "get-tuple-element", "tuple",
                  "constant", "iota", "after-all", "partition-id"}


def _split_computations(hlo_text: str):
    """Computation name → body lines. A computation header is a non-indented line
    ending in '{' whose first token (after optional ENTRY) is the name."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if line and not line[0].isspace() and stripped.endswith("{"):
            head = stripped[:-1].strip()
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY"):].strip()
            name = head.split("(")[0].strip().lstrip("%").strip()
            if not name or " " in name:
                cur = None
                continue
            cur = name
            comps[cur] = []
            if is_entry:
                entry = cur
            continue
        if cur is not None and stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def estimate_hbm_bytes(hlo_text: str) -> dict:
    """Fusion-boundary traffic proxy: Σ over *top-level* instructions (ENTRY +
    while bodies × parsed trip count) of (result bytes + operand bytes), where a
    fusion op counts only at its boundary. ``cost_analysis()`` on the CPU backend
    sums ops *inside* fusion computations (register/VMEM traffic on a real TPU),
    wildly over-counting HBM bytes — this estimator is the roofline's memory-term
    numerator instead."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return {"total_bytes": 0, "by_computation": {}}

    # map: computation -> list of (opcode, result_bytes, operand names, line)
    fusion_bodies: set[str] = set()
    while_info: list[tuple[str, str, str]] = []   # (comp_of_while, cond, body)
    parsed: dict[str, list] = {}
    for cname, lines in comps.items():
        rows = []
        for line in lines:
            m = _RESULT_NAME.match(line)
            if not m:
                continue
            opm = _OPCODE.search(line)
            opcode = opm.group(1) if opm else "?"
            shape_part = line.split("=", 1)[1]
            shape_part = shape_part.split(opcode + "(", 1)[0] if opm else shape_part
            rbytes = _shape_bytes(shape_part)
            operands = []
            if opm and opcode + "(" in line:
                inner = line.split(opcode + "(", 1)[1]
                depth, buf = 1, []
                for ch in inner:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    buf.append(ch)
                # an operand token may carry its shape ("f32[256,256]{1,0}
                # %dot.0") or be bare ("%dot.0") — take the %name wherever it
                # sits, else operand bytes silently vanish from the estimate
                for t in "".join(buf).split(","):
                    nm = re.search(r"%([\w\.\-]+)", t)
                    if nm:
                        operands.append(nm.group(1))
            rows.append((m.group(1), opcode, rbytes, operands, line))
            for cm in _CALLS.finditer(line):
                if opcode == "fusion":
                    fusion_bodies.add(cm.group(1))
            wm = _WHILE_ATTRS.search(line)
            if wm and opcode == "while":
                while_info.append((cname, wm.group(1), wm.group(2)))
        parsed[cname] = rows

    # trip counts: max int constant reachable from the while condition
    # computation (the bound constant may live in a called/fused computation)
    name_re = re.compile(r"%([\w\.\-]+)")
    trips: dict[str, int] = {}
    for _, cond, body in while_info:
        text_parts = ["\n".join(comps.get(cond, []))]
        for ref in name_re.findall(text_parts[0]):
            if ref in comps and ref != cond:
                text_parts.append("\n".join(comps[ref]))
        consts = [int(x) for x in _CONST_INT.findall("\n".join(text_parts))]
        trips[body] = max(consts) if consts else 1

    coll_bytes: dict[str, float] = {}

    def comp_bytes(cname: str, mult: float, seen: set) -> float:
        if cname in seen:
            return 0.0
        seen = seen | {cname}
        total = 0.0
        result_bytes = {r[0]: r[2] for r in parsed.get(cname, [])}
        for name, opcode, rbytes, operands, line in parsed.get(cname, []):
            if opcode in _ZERO_COST_OPS:
                continue
            if opcode == "while":
                wm = _WHILE_ATTRS.search(line)
                if wm:
                    total += comp_bytes(wm.group(2), mult * trips.get(wm.group(2), 1),
                                        seen)
                continue
            if opcode in ("conditional", "call"):
                for cm in _CALLS.finditer(line):
                    total += comp_bytes(cm.group(1), mult, seen)
                continue
            for kind in COLLECTIVE_KINDS:
                if opcode == kind or opcode == kind + "-start":
                    coll_bytes[kind] = coll_bytes.get(kind, 0.0) + mult * rbytes
                    break
            # slicing ops touch slice-sized data, not their full operands:
            # dynamic-slice reads+writes the slice (2×result); dynamic-update-
            # slice reads the update and writes it in place (2×update≈2×min-op)
            if opcode in ("dynamic-slice", "slice"):
                total += mult * 2 * rbytes
                continue
            if opcode == "dynamic-update-slice":
                upd = min((result_bytes.get(o, rbytes) for o in operands[1:2]),
                          default=rbytes)
                total += mult * 2 * min(upd, rbytes)
                continue
            ob = sum(result_bytes.get(o, 0) for o in operands)
            total += mult * (rbytes + ob)
        return total

    total = comp_bytes(entry, 1.0, set())
    return {"total_bytes": total, "trip_counts": trips,
            "collective_bytes_by_kind": coll_bytes,
            "collective_total": sum(coll_bytes.values())}


def op_histogram(hlo_text: str) -> dict:
    """Rough opcode histogram (for spotting remat-duplicated ops, reshapes)."""
    hist: dict[str, int] = defaultdict(int)
    opcode_re = re.compile(r"=\s*(?:\(?[^)=]*?\)?)\s+([a-z][\w\-]*)\(")
    for line in hlo_text.splitlines():
        m = opcode_re.search(line)
        if m:
            hist[m.group(1)] += 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1]))
