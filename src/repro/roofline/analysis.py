"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

    compute    = HLO_FLOPs_global / (chips × peak_FLOP/s)
    memory     = HLO_bytes_global / (chips × HBM_bw)
    collective = collective_bytes_per_device / link_bw
                 (the assignment's 'collective_bytes / (chips × link_bw)' with
                  collective_bytes summed over chips — the SPMD module is
                  per-device, so per-device bytes × chips / (chips × link_bw)
                  reduces to this)

``cost_analysis()`` on the SPMD executable reports *per-device* FLOPs/bytes; we
scale by chip count for the global numerators, so the terms are per-device times —
directly comparable to a per-step wall clock.
"""
from __future__ import annotations

from dataclasses import dataclass, field

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_LINK_BW = 50e9              # B/s per link (assignment constant)


@dataclass
class RooflineTerms:
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float = 0.0     # 6·N·D (or 6·N_active·D)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS_global — 'useful compute' fraction; catches
        remat/redundancy waste. >1 means HLO under-counts (fusion estimates)."""
        total = self.hlo_flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term roofline that *useful* model FLOPs
        represent: (MODEL_FLOPS/(chips·peak)) / bound_s. 1.0 = the step is exactly
        as long as the useful math at peak — the hillclimb score."""
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_ratio": self.model_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference (fwd only); MoE uses
    active params. D = tokens processed by the step."""
    n = cfg.active_params_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
