"""Pallas TPU kernels (validated on CPU with interpret=True against ref oracles).

fault_probe - fused non-finite/overflow detection (every-step soft-fault probe)
flash_attention - online-softmax attention fwd (causal/sliding/GQA)
ssd_scan - Mamba-2 SSD intra-chunk kernel + jnp inter-chunk recurrence
rglru_scan - RG-LRU linear recurrence, one-HBM-pass sequential scan
"""
