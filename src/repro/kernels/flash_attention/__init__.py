from .ops import flash_attention  # noqa: F401
from .ref import sdpa_ref  # noqa: F401
