"""Pallas TPU flash attention (fwd): online softmax over KV blocks in VMEM.

Grid: (batch·q_heads, num_q_blocks, num_kv_blocks) — the KV dimension is the
innermost (sequential) grid axis, so the running (acc, m, l) state lives in VMEM
scratch across KV steps and is flushed to the output block on the last step.
Causal/sliding masks are applied with 2D iotas; fully-masked KV blocks are skipped
with ``pl.when`` (predicated-off on TPU, zero compute).

GQA is handled by the k/v BlockSpec index maps: query-head ``h`` reads KV head
``h // group`` — no repeated KV materialisation.

Block shapes: q (1, block_q, head_dim), k/v (1, block_kv, head_dim); head_dim is
expected to be lane-aligned (128/256 for every assigned arch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  block_q: int, block_kv: int, seq_kv: int, num_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    # static-shape positions for this (qi, kj) block pair
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv),
                                                   0) + q_offset
    kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv),
                                                    1)
    # does this block pair intersect the mask at all?
    q_lo = qi * block_q + q_offset
    q_hi = q_lo + block_q - 1
    k_lo = kj * block_kv
    k_hi = k_lo + block_kv - 1
    needed = jnp.bool_(True)
    if causal:
        needed = jnp.logical_and(needed, k_lo <= q_hi)
    if window:
        needed = jnp.logical_and(needed, k_hi > q_lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0].astype(jnp.float32)               # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = kpos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[:, 0] = m_new

    @pl.when(kj == num_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool, window: int = 0,
                        q_offset: int = 0, block_q: int = 512,
                        block_kv: int = 512, seq_kv: int | None = None,
                        interpret: bool = True):
    """q: (BH, S, D) with BH = batch·q_heads; k/v: (BKv, T, D); S and T must be
    block multiples (ops.py pads); ``seq_kv`` is the true (unpadded) KV length
    for masking. Returns (BH, S, D)."""
    BH, S, D = q.shape
    BKv, T, _ = k.shape
    group = BH // BKv
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    assert S % block_q == 0 and T % block_kv == 0, (S, T, block_q, block_kv)
    nq, nk = S // block_q, T // block_kv
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_kv=block_kv,
        seq_kv=seq_kv if seq_kv is not None else T, num_kv=nk)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, D),
                         lambda bh, qi, kj, group=group: (bh // group, kj, 0)),
            pl.BlockSpec((1, block_kv, D),
                         lambda bh, qi, kj, group=group: (bh // group, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
