"""Oracle for the flash kernel: re-export the naive SDPA reference."""
from ...models.attention import sdpa_ref  # noqa: F401
