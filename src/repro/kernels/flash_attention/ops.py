"""Jit wrapper: (B,S,H,D) layout ↔ kernel layout, backend dispatch.

The TPU path uses the Pallas kernel for the forward; the backward falls back to
the custom-VJP jnp flash (``models.attention.sdpa_chunked``), which is already
recompute-based — on-TPU a Pallas backward kernel would slot in here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0, block_q: int = 512,
                    block_kv: int = 512) -> jax.Array:
    """q: (B,S,Hq,D); k/v: (B,T,Hkv,D) → (B,S,Hq,D)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    bq, bkv = min(block_q, S), min(block_kv, T)
    Sp = -(-S // bq) * bq
    Tp = -(-T // bkv) * bkv
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qk = qp.transpose(0, 2, 1, 3).reshape(B * Hq, Sp, D)
    kk = kp.transpose(0, 2, 1, 3).reshape(B * Hkv, Tp, D)
    vk = vp.transpose(0, 2, 1, 3).reshape(B * Hkv, Tp, D)
    out = flash_attention_fwd(qk, kk, vk, causal=causal, window=window,
                              q_offset=q_offset, block_q=bq,
                              block_kv=bkv, seq_kv=T,
                              interpret=_use_interpret())
    return out.reshape(B, Hq, Sp, D)[:, :, :S].transpose(0, 2, 1, 3)
