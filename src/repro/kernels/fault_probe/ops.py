"""Jit-ready wrappers for the fault-probe kernel (with shape normalisation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import probe_rows
from .ref import probe_array_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def probe_array(x: jax.Array, threshold: float, *, nonfinite_code: int,
                overflow_code: int, block_rows: int = 256,
                use_kernel: bool = True) -> jax.Array:
    """Scalar uint32 error word for one array (any shape/float dtype).

    Pads the flattened stream with zeros (finite, below threshold ⇒ no false
    positives) to a ``(k·block_rows, 128)`` tile grid.
    """
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.uint32(0)
    n = x.size
    # Kernel only on real TPU: in interpret mode the grid is traced step-by-step,
    # which would explode trace time for multi-GB grad streams (CPU dry-runs use
    # the fused-by-XLA oracle path; the kernel is validated separately at small
    # shapes with interpret=True).
    if not use_kernel or n < block_rows * 128 or _use_interpret():
        return probe_array_ref(x, threshold, nonfinite_code=nonfinite_code,
                               overflow_code=overflow_code)
    flat = x.reshape(-1)
    tile = block_rows * 128
    pad = (-n) % tile
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    rows = flat.size // 128
    return probe_rows(flat.reshape(rows, 128), jnp.asarray(threshold),
                      nonfinite_code=nonfinite_code, overflow_code=overflow_code,
                      block_rows=block_rows, interpret=_use_interpret())


def probe_tree(tree, threshold: float, *, nonfinite_code: int, overflow_code: int,
               block_rows: int = 256, use_kernel: bool = True) -> jax.Array:
    """OR-fold of per-leaf probe words over a pytree."""
    word = jnp.uint32(0)
    for leaf in jax.tree_util.tree_leaves(tree):
        word = word | probe_array(leaf, threshold, nonfinite_code=nonfinite_code,
                                  overflow_code=overflow_code,
                                  block_rows=block_rows, use_kernel=use_kernel)
    return word
