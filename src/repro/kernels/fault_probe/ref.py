"""Pure-jnp oracle for the fault probe."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def probe_array_ref(x: jax.Array, threshold: float, *, nonfinite_code: int,
                    overflow_code: int) -> jax.Array:
    x = x.astype(jnp.float32)
    nonfinite = jnp.any(jnp.logical_not(jnp.isfinite(x)))
    finite_x = jnp.where(jnp.isfinite(x), x, 0.0)
    over = jnp.any(jnp.abs(finite_x) > threshold)
    return (jnp.where(nonfinite, jnp.uint32(nonfinite_code), jnp.uint32(0))
            | jnp.where(over, jnp.uint32(overflow_code), jnp.uint32(0)))


def probe_tree_ref(tree, threshold: float, *, nonfinite_code: int,
                   overflow_code: int) -> jax.Array:
    word = jnp.uint32(0)
    for leaf in jax.tree_util.tree_leaves(tree):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        word = word | probe_array_ref(leaf, threshold,
                                      nonfinite_code=nonfinite_code,
                                      overflow_code=overflow_code)
    return word
