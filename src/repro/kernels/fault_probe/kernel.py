"""Pallas kernel: fused non-finite / overflow probe over a flat value stream.

Motivation (paper §II-A): soft-fault detection must run on *every* step over the
full gradient/parameter stream to be useful — so it has to ride the memory roofline.
A naive ``jnp.isfinite``+``jnp.abs``+``jnp.any`` chain materialises boolean
intermediates in HBM; this kernel reads each tile of the stream into VMEM once and
reduces it to a single uint32 error word in registers.

Design for TPU:
* the stream is reshaped to ``(rows, 128)`` (lane-aligned) by ``ops.py``;
* the grid walks row-blocks of ``block_rows`` (8-aligned, sublane-friendly);
* each grid step computes ``any(!isfinite)`` and ``any(|x| > threshold)`` on the VPU
  and bitwise-ORs the encoded word into a (1,1) accumulator block that every grid
  step maps to (TPU grid steps execute sequentially on a core, so the accumulation
  is race-free; the same property holds in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Error-code bits are passed in as static ints to keep the kernel independent of the
# errors module (and the lattice usable from any layer).


def _probe_kernel(x_ref, thresh_ref, o_ref, *, nonfinite_code: int,
                  overflow_code: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)
    thresh = thresh_ref[0, 0]
    nonfinite = jnp.any(jnp.logical_not(jnp.isfinite(x)))
    # overflow check must ignore non-finite lanes (inf would always trip it)
    finite_x = jnp.where(jnp.isfinite(x), x, 0.0)
    over = jnp.any(jnp.abs(finite_x) > thresh)
    word = (jnp.where(nonfinite, jnp.uint32(nonfinite_code), jnp.uint32(0))
            | jnp.where(over, jnp.uint32(overflow_code), jnp.uint32(0)))
    prev = jnp.where(i == 0, jnp.uint32(0), o_ref[0, 0])
    o_ref[0, 0] = prev | word


def probe_rows(x: jax.Array, threshold: jax.Array, *, nonfinite_code: int,
               overflow_code: int, block_rows: int = 256,
               interpret: bool = True) -> jax.Array:
    """Probe a ``(rows, 128)`` array; returns a scalar uint32 word."""
    rows, lanes = x.shape
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes, block_rows)
    grid = (rows // block_rows,)
    kernel = functools.partial(_probe_kernel, nonfinite_code=nonfinite_code,
                               overflow_code=overflow_code)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.uint32),
        interpret=interpret,
    )(x, threshold.reshape(1, 1).astype(jnp.float32))
    return out[0, 0]
