from .ops import probe_array, probe_tree  # noqa: F401
from .ref import probe_array_ref, probe_tree_ref  # noqa: F401
