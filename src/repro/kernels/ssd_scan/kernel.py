"""Pallas TPU kernel for the Mamba-2 SSD *intra-chunk* computation.

One grid cell = one (batch, chunk, head): loads the chunk's x·dt (L,P), B/C
(L,N) and per-step log-decay ā (L,) into VMEM and produces

  * ``y_diag``  (L,P): the causal 'attention-like' intra-chunk term
    ``(C Bᵀ ⊙ exp(segsum ā)) · x``  — one L×L decay matrix built in-register,
  * ``state``   (P,N): the chunk's contribution to the inter-chunk recurrence
    ``Σ_j exp(cum_L − cum_j) B_j ⊗ x_j``.

The O(S/L)-length inter-chunk scan and the rank-1 ``y_off`` correction stay in
jnp (``ops.py``) — they are tiny and sequential. Chunk length L and state width N
are 128 by default (MXU-aligned); P = head_dim = 64 for mamba2-2.7b (sublane-
aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)      # (L, P)
    a = a_ref[0, 0, :, 0].astype(jnp.float32)         # (L,)
    b = b_ref[0, 0, :, 0, :].astype(jnp.float32)      # (L, N)
    c = c_ref[0, 0, :, 0, :].astype(jnp.float32)      # (L, N)
    L = x.shape[0]
    cum = jnp.cumsum(a)                               # (L,)
    seg = cum[:, None] - cum[None, :]                 # segsum: i≥j valid
    tril = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    decay = jnp.where(tril, jnp.exp(seg), 0.0)        # (L, L)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # (L, L)
    y = jax.lax.dot_general(scores * decay, x, (((1,), (0,)), ((), ())))
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)
    dstates = jnp.exp(cum[-1] - cum)                  # (L,)
    st = jax.lax.dot_general(x * dstates[:, None], b,
                             (((0,), (0,)), ((), ())))  # (P, N)
    st_ref[0, 0, 0, :, :] = st.astype(st_ref.dtype)


def ssd_intra_chunk(xd, abar, B, C, *, interpret: bool = True):
    """xd: (b,nc,L,h,p); abar: (b,nc,L,h); B,C: (b,nc,L,h,n) (heads already
    broadcast). Returns (y_diag (b,nc,L,h,p), states (b,nc,h,p,n))."""
    b, nc, L, h, p = xd.shape
    n = B.shape[-1]
    grid = (b, nc, h)
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, L, 1, n), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, L, 1, n), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, L, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xd, abar, B, C)
    return y, st
