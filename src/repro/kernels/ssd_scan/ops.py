"""Full SSD scan: Pallas intra-chunk kernel + jnp inter-chunk recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssd_intra_chunk


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd_scan(x, dt, A, B, C, chunk: int = 128):
    """Same contract as ``models.ssm.ssd_chunked``:
    x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,g,n) → y:(b,s,h,p)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    xd = (xf * dtf[..., None]).reshape(b, nc, L, h, p)
    abar = (dtf * A).reshape(b, nc, L, h)
    Bc = jnp.repeat(B, rep, axis=2).astype(jnp.float32).reshape(b, nc, L, h, n)
    Cc = jnp.repeat(C, rep, axis=2).astype(jnp.float32).reshape(b, nc, L, h, n)

    y_diag, states = ssd_intra_chunk(xd, abar, Bc, Cc,
                                     interpret=_use_interpret())

    # inter-chunk recurrence (tiny, sequential)
    cum = jnp.cumsum(abar, axis=2)                       # (b,nc,L,h)
    total = cum[:, :, -1]

    def step(hprev, inp):
        st, tot = inp
        return hprev * jnp.exp(tot)[..., None, None] + st, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, hprevs = jax.lax.scan(step, h0, (states.transpose(1, 0, 2, 3, 4),
                                        total.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)             # (b,nc,h,p,n)

    decay_in = jnp.exp(cum)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cc, hprevs, decay_in)
    return (y_diag + y_off).reshape(b, s, h, p).astype(x.dtype)
