from .ops import ssd_scan  # noqa: F401
from .ref import ssd_chunked, ssd_naive_ref  # noqa: F401
