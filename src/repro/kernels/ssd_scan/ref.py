"""Oracles: naive recurrence and the pure-jnp chunked SSD."""
from ...models.ssm import ssd_chunked, ssd_naive_ref  # noqa: F401
