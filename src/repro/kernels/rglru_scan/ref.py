"""Oracles: sequential scan and log-depth associative scan."""
from ...models.rglru import rglru_scan_assoc, rglru_scan_ref  # noqa: F401
