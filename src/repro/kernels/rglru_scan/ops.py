"""Jit wrapper matching the ``models.rglru`` scan contract."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_blocks


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def rglru_scan(x_in, log_a, *, block_w: int = 128):
    """x_in: (B,S,W) pre-gate input i⊙x; log_a: (B,S,W) ≤ 0.

    Applies the √(1−a²) input normalisation and runs the recurrence kernel.
    """
    a = jnp.exp(log_a.astype(jnp.float32))
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x_in.astype(jnp.float32)
    return rglru_scan_blocks(a, gated, block_w=block_w,
                             interpret=_use_interpret())
