from .ops import rglru_scan  # noqa: F401
from .ref import rglru_scan_assoc, rglru_scan_ref  # noqa: F401
