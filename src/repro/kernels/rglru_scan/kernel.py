"""Pallas TPU kernel for the RG-LRU linear recurrence.

h_t = a_t ⊙ h_{t-1} + x_t  (inputs pre-gated by ops.py: x_t = √(1−a²)·i·x)

Grid: (batch, width-blocks) — channels are independent, so the kernel holds one
(width-block) hidden-state vector in VMEM scratch and walks the sequence with a
``fori_loop``, one fused multiply-add + store per step. This is the TPU-native
shape of the computation: a single HBM pass over (S, blk) with O(blk) state —
the recurrence is memory-bound, so one pass IS the roofline. (A log-depth
Blelloch tree would add passes; the associative-scan jnp path exists as the
XLA fallback.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, x_ref, o_ref, h_ref):
    S = x_ref.shape[1]

    h_ref[...] = jnp.zeros_like(h_ref)

    def body(t, _):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        h = a_t * h_ref[0, :] + x_t
        h_ref[0, :] = h
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, S, body, 0)


def rglru_scan_blocks(a, x, *, block_w: int = 128, interpret: bool = True):
    """a, x: (B, S, W) → h: (B, S, W). a = exp(log_a) decay in [0,1)."""
    B, S, W = a.shape
    block_w = min(block_w, W)
    assert W % block_w == 0, (W, block_w)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        _rglru_kernel,
        grid=(B, W // block_w),
        in_specs=[
            pl.BlockSpec((1, S, block_w), lambda b, w: (b, 0, w)),
            pl.BlockSpec((1, S, block_w), lambda b, w: (b, 0, w)),
        ],
        out_specs=pl.BlockSpec((1, S, block_w), lambda b, w: (b, 0, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, x)
