"""gemma3-1b [hf:google/gemma-3-1b-pt]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144. 5 local (sliding-512) : 1 global layer pattern, 128k-class context,
head_dim=256, qk-norm, GeGLU, logit softcap, embeddings scaled by sqrt(d).

long_500k: runs — local layers use O(window) ring caches; the 1-in-6 global
layers are linear-per-token at decode (see DESIGN.md §Arch-applicability)."""
import math

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    block_pattern=("sliding", "sliding", "sliding", "sliding", "sliding", "attn"),
    sliding_window=512,
    rope_theta=1_000_000.0,
    qk_norm=True,
    mlp_kind="geglu",
    tie_embeddings=True,
    logit_softcap=30.0,
    embed_scale=math.sqrt(1152.0),
)
