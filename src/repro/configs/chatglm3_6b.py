"""chatglm3-6b [arXiv:2406.12793]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024. 2d (partial, interleaved-pair) RoPE on half the head dim."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    block_pattern=("attn",),
    rope_style="partial2d",
    rope_fraction=0.5,
    rope_theta=10000.0,
    mlp_kind="swiglu",
)
