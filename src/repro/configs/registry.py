"""Architecture registry: full configs, reduced smoke configs, cell applicability."""
from __future__ import annotations

import math
from typing import Optional

from .base import SHAPES, ModelConfig, ShapeConfig
from .chatglm3_6b import CONFIG as chatglm3_6b
from .gemma3_1b import CONFIG as gemma3_1b
from .hubert_xlarge import CONFIG as hubert_xlarge
from .llama32_vision_11b import CONFIG as llama32_vision_11b
from .mamba2_2_7b import CONFIG as mamba2_2_7b
from .phi35_moe_42b_a6_6b import CONFIG as phi35_moe
from .qwen3_1_7b import CONFIG as qwen3_1_7b
from .qwen3_moe_30b_a3b import CONFIG as qwen3_moe
from .recurrentgemma_2b import CONFIG as recurrentgemma_2b
from .starcoder2_3b import CONFIG as starcoder2_3b

ARCHS: dict[str, ModelConfig] = {
    "qwen3-moe-30b-a3b": qwen3_moe,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "llama-3.2-vision-11b": llama32_vision_11b,
    "starcoder2-3b": starcoder2_3b,
    "qwen3-1.7b": qwen3_1_7b,
    "chatglm3-6b": chatglm3_6b,
    "gemma3-1b": gemma3_1b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "mamba2-2.7b": mamba2_2_7b,
    "hubert-xlarge": hubert_xlarge,
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


# ------------------------------------------------------------- cell applicability
def cell_skip_reason(arch: str, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; otherwise the documented skip reason."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if cfg.is_encoder and sh.kind == "decode":
        return "encoder-only: no decode step"
    if shape == "long_500k" and cfg.block_pattern == ("attn",):
        return "pure full attention: long_500k needs sub-quadratic attention"
    if shape == "long_500k" and arch == "llama-3.2-vision-11b":
        return "full self-attention backbone: long_500k needs sub-quadratic attention"
    return None


def all_cells() -> list[tuple[str, str, Optional[str]]]:
    return [(a, s, cell_skip_reason(a, s)) for a in ARCHS for s in SHAPES]


# ------------------------------------------------------------------ smoke configs
def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small widths, few experts, tiny vocab — runs a
    full forward/train step on CPU in seconds. Pattern structure (incl. a non-empty
    remainder where the full config has one) is preserved."""
    cfg = get_config(name)
    common = dict(
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, 4 * cfg.num_kv_heads // cfg.num_heads),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        sliding_window=16,
        remat="none",
        dtype="float32",
        embed_scale=math.sqrt(64.0) if cfg.embed_scale != 1.0 else 1.0,
    )
    # keep ≥2 periods plus the same remainder-length so period-scan + rest paths
    # are both exercised
    rem = len(cfg.remainder_layers)
    layers = 2 * cfg.period + rem
    overrides = dict(num_layers=layers, **common)
    if cfg.is_moe:
        overrides.update(num_experts=8, num_experts_per_tok=2)
    if cfg.family == "ssm":
        overrides.update(ssm_state_dim=16, ssm_head_dim=16, ssm_expand=2,
                         ssm_chunk=8)   # d_inner=128, 8 heads
    if cfg.family == "hybrid":
        overrides.update(lru_width=64, lru_heads=4)
    if cfg.family == "vlm":
        overrides.update(img_tokens=8)
    return cfg.replace(**overrides)


SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
