"""Model/config schema shared by all assigned architectures.

One frozen dataclass describes any member of the five families (dense / MoE / VLM /
hybrid / SSM / encoder-audio). Heterogeneous layer stacks (gemma3 local:global,
recurrentgemma RG-LRU:attention, llama-vision cross-attention interleave) are
expressed as a repeating ``block_pattern`` so the model can scan over pattern
*periods* (HLO size ∝ period length, compile time independent of depth).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads

    # --- attention ---------------------------------------------------------
    # per-layer block types, cycled: "attn" | "sliding" | "cross" | "rglru" | "ssd"
    block_pattern: tuple[str, ...] = ("attn",)
    sliding_window: int = 4096
    rope_style: str = "standard"     # standard | partial2d | none
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # chatglm: rotary on half the head dim
    qk_norm: bool = False
    causal: bool = True              # False for encoder-only (hubert)

    # --- mlp / moe ----------------------------------------------------------
    mlp_kind: str = "swiglu"         # swiglu | geglu | gelu
    num_experts: int = 0
    num_experts_per_tok: int = 0
    expert_capacity_factor: float = 1.25

    # --- ssm (mamba2 SSD) ----------------------------------------------------
    ssm_state_dim: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 128             # SSD chunk length

    # --- rglru (griffin) ------------------------------------------------------
    lru_width: int = 0               # 0 → d_model
    lru_heads: int = 0               # block-diagonal gate blocks; 0 → num_heads

    # --- vlm -----------------------------------------------------------------
    img_tokens: int = 0              # stubbed frontend sequence length

    # --- misc ----------------------------------------------------------------
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "nothing_saveable"  # none | nothing_saveable | dots_saveable
    logit_softcap: float = 0.0
    embed_scale: float = 1.0         # gemma: sqrt(d_model)
    scan_layers: bool = True         # lax.scan over periods (False: unrolled)

    # ------------------------------------------------------------------ derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def pattern_layers(self) -> tuple[str, ...]:
        """Full per-layer block-type list (pattern cycled to num_layers)."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def remainder_layers(self) -> tuple[str, ...]:
        return self.pattern_layers[self.num_periods * self.period:]

    # sub-quadratic? (decides long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        return all(b in ("sliding", "rglru", "ssd") or b == "attn" and False
                   for b in self.block_pattern) or not any(
            b in ("attn", "cross") for b in self.block_pattern)

    @property
    def has_global_attention(self) -> bool:
        return any(b in ("attn", "cross") for b in self.block_pattern)

    def params_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline maths)."""
        d, hd = self.d_model, self.resolved_head_dim
        nh, nkv = self.num_heads, self.num_kv_heads
        counts = {"embed": self.vocab_size * d}
        if not self.tie_embeddings:
            counts["unembed"] = self.vocab_size * d
        per = {
            "attn": d * nh * hd + 2 * d * nkv * hd + nh * hd * d,
            "sliding": d * nh * hd + 2 * d * nkv * hd + nh * hd * d,
            "cross": d * nh * hd + 2 * d * nkv * hd + nh * hd * d,
            "ssd": (2 * d * self.d_inner                      # x, z proj
                    + 2 * d * self.ssm_ngroups * self.ssm_state_dim  # B, C
                    + d * self.ssm_nheads                    # dt
                    + self.d_inner * d),                     # out
            "rglru": (2 * d * self.resolved_lru_width
                      + 2 * self.resolved_lru_width ** 2 // max(self.lru_heads or self.num_heads, 1)
                      + self.resolved_lru_width * d),
        }
        total = sum(counts.values())
        for b in self.pattern_layers:
            total += per[b]
            if b in ("attn", "sliding", "cross") or b == "rglru":
                if self.is_moe:
                    gate = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                    total += (d * self.num_experts  # router
                              + self.num_experts * gate * d * self.d_ff)
                elif self.d_ff:
                    gate = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                    total += gate * d * self.d_ff
        return total

    def active_params_count(self) -> int:
        """MoE: params touched per token (6·N_active·D)."""
        if not self.is_moe:
            return self.params_count()
        d = self.d_model
        gate = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        dense_total = self.params_count() - sum(
            self.num_experts * gate * d * self.d_ff
            for b in self.pattern_layers if b in ("attn", "sliding", "cross"))
        active_ff = sum(
            self.num_experts_per_tok * gate * d * self.d_ff
            for b in self.pattern_layers if b in ("attn", "sliding", "cross"))
        return dense_total + active_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
