"""recurrentgemma-2b [arXiv:2402.19427 Griffin]: 26L d_model=2560 10H (GQA kv=1)
d_ff=7680 vocab=256000. Pattern 2×RG-LRU : 1×local-attention (window 2048),
lru_width=2560. Hybrid ⇒ long_500k runs (O(1) recurrent state + ring KV)."""
import math

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "sliding"),
    sliding_window=2048,
    rope_theta=10000.0,
    mlp_kind="geglu",
    lru_width=2560,
    lru_heads=10,                    # block-diagonal gates, 256-wide blocks
    tie_embeddings=True,
    embed_scale=math.sqrt(2560.0),
)
