from .base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401
from .registry import (  # noqa: F401
    ARCHS,
    SMOKE_SHAPE,
    all_cells,
    cell_skip_reason,
    get_config,
    smoke_config,
)
