"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d_model=2048 32H (GQA kv=4)
MoE 128 experts top-8, expert d_ff=768, vocab=151936. qk-norm, head_dim=128
(Qwen3 decouples head_dim from d_model/num_heads)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                       # MoE expert intermediate size
    vocab_size=151936,
    block_pattern=("attn",),
    rope_theta=1_000_000.0,
    qk_norm=True,
    mlp_kind="swiglu",
    num_experts=128,
    num_experts_per_tok=8,
)
