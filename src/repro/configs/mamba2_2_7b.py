"""mamba2-2.7b [arXiv:2405.21060]: 64L d_model=2560 attention-free, SSD
(state-space duality), ssm_state=128, expand=2 (d_inner 5120), head_dim 64
(80 heads), conv width 4. long_500k runs (O(1) state decode)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,                    # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                         # no MLP: the SSD mixer is the block
    vocab_size=50280,
    block_pattern=("ssd",),
    rope_style="none",
    ssm_state_dim=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
)
