"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision]: 40L d_model=4096
32H (GQA kv=8) d_ff=14336 vocab=128256. Cross-attention image layers every 5th
layer (8 of 40). Vision frontend is a STUB: ``input_specs`` provides precomputed
patch embeddings (B, img_tokens, d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    # period of 5: positions 0-3 self-attention, position 4 cross-attention
    block_pattern=("attn", "attn", "attn", "attn", "cross"),
    rope_theta=500_000.0,
    mlp_kind="swiglu",
    img_tokens=1601,                # 1 tile × (40×40 patches + 1 cls)
)
