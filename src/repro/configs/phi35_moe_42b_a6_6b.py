"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]: 32L d_model=4096
32H (GQA kv=8), MoE 16 experts top-2, expert d_ff=6400, vocab=32064."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,                      # MoE expert intermediate size
    vocab_size=32064,
    block_pattern=("attn",),
    rope_theta=10000.0,
    mlp_kind="swiglu",
    num_experts=16,
    num_experts_per_tok=2,
    norm="layernorm",               # phi family uses LayerNorm
)
