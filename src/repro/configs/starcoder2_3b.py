"""starcoder2-3b [arXiv:2402.19173]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152. Sliding-window attention (4096), RoPE, LayerNorm + plain-GeLU MLP.
Sliding window ⇒ sub-quadratic ⇒ long_500k runs (ring KV cache)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    block_pattern=("sliding",),
    sliding_window=4096,
    rope_theta=100_000.0,
    mlp_kind="gelu",
    norm="layernorm",
)
