"""hubert-xlarge [arXiv:2106.07447]: 48L d_model=1280 16H (MHA, kv=16) d_ff=5120
vocab=504 (masked-unit prediction codebook). Encoder-only (bidirectional, no
decode step — decode_32k/long_500k cells are skipped). The audio frontend (conv
feature extractor + conv positional embedding) is a STUB: ``input_specs``
provides precomputed frame embeddings (B, frames, d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    block_pattern=("attn",),
    causal=False,                   # encoder-only, bidirectional
    rope_style="none",              # conv positional embedding is part of the stub
    mlp_kind="gelu",
    norm="layernorm",
)
