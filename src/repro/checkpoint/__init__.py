from .buddy import BuddyStore  # noqa: F401
from .checkpointer import Checkpointer  # noqa: F401
