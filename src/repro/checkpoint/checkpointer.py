"""Asynchronous, atomic, versioned disk checkpointing (global-rollback store).

* ``save`` snapshots device arrays (host transfer) and hands the write to a
  background thread — training never blocks on disk (the paper's premise that
  recovery machinery must not slow the failure-free path).
* Writes are atomic: ``tmp-`` directory + ``os.replace`` rename; a manifest
  records step, pytree structure and per-leaf checksums.
* ``restore_latest`` validates checksums and skips corrupt checkpoints
  (CHECKPOINT_IO soft-fault semantics: a broken rollback target must surface as
  an error, not as silently-wrong weights).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from ..core.errors import ErrorCode


class Checkpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    # ---------------------------------------------------------------- saving
    def save(self, step: int, state, *, blocking: bool = False) -> None:
        """Snapshot to host, then write in the background."""
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        self.wait()          # one in-flight write at a time
        t = threading.Thread(target=self._write, args=(step, host_state),
                             daemon=True)
        t.start()
        self._thread = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state) -> None:
        try:
            tmp = self.dir / f"tmp-{step}"
            final = self.dir / f"step-{step:010d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            leaves, treedef = jax.tree_util.tree_flatten(host_state)
            manifest = {"step": step, "num_leaves": len(leaves),
                        "treedef": str(treedef), "leaves": []}
            for i, leaf in enumerate(leaves):
                arr = np.asarray(leaf)
                path = tmp / f"leaf-{i:05d}.npy"
                np.save(path, arr)
                manifest["leaves"].append({
                    "i": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "crc": zlib.crc32(arr.tobytes()),
                })
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)      # atomic publish
            self._gc()
        except Exception as e:  # noqa: BLE001
            self.last_error = e

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step-{s:010d}", ignore_errors=True)

    # -------------------------------------------------------------- restoring
    def list_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step-*"):
            try:
                out.append(int(p.name.split("-")[1]))
            except ValueError:
                continue
        return sorted(out)

    def restore(self, step: int, like) -> Any:
        """Restore into the structure of ``like`` (device placement preserved
        by jax on use). Raises on checksum mismatch."""
        d = self.dir / f"step-{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree_util.tree_flatten(like)
        if manifest["num_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint step {step}: leaf count mismatch "
                f"({manifest['num_leaves']} vs {len(leaves)})")
        out = []
        for i, _ in enumerate(leaves):
            arr = np.load(d / f"leaf-{i:05d}.npy")
            meta = manifest["leaves"][i]
            if zlib.crc32(arr.tobytes()) != meta["crc"]:
                raise IOError(f"checkpoint step {step} leaf {i}: CRC mismatch "
                              f"(code={ErrorCode.CHECKPOINT_IO!r})")
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like) -> Optional[tuple[int, Any]]:
        """(step, state) from the newest valid checkpoint, skipping corrupt
        ones; None if nothing restorable."""
        for step in reversed(self.list_steps()):
            try:
                return step, self.restore(step, like)
            except Exception:  # noqa: BLE001 - corrupt ckpt: try the previous
                continue
        return None
