"""Buddy (peer-redundant, in-memory) store — the LFLR substrate.

Paper use case 1 (Teranishi & Heroux LFLR; Huber et al. multigrid recovery): a
failed rank's state is recovered from *surviving* memory instead of a global
disk rollback. Each rank pushes a copy of its shard to its buddy
(``(rank + 1) % n``) every ``interval`` steps; after a shrink, survivors
reconstruct the lost rank's shard from the buddy copy.

In the simulated multi-controller runtime the "remote memories" live in one
process, so the store is a thread-safe dict keyed by rank; on a real cluster
the same interface is backed by the transport (send/recv of host buffers) — the
protocol layer is identical, which is the point of the simulation.
"""
from __future__ import annotations

import threading
from typing import Any, Optional

import jax
import numpy as np


class BuddyStore:
    def __init__(self, world_size: int, *, stride: int = 1):
        self.world_size = world_size
        self.stride = stride
        self._lock = threading.Lock()
        # buddy memory: rank -> (step, host pytree of that rank's shard)
        self._mem: dict[int, tuple[int, Any]] = {}

    def buddy_of(self, rank: int) -> int:
        return (rank + self.stride) % self.world_size

    def push(self, rank: int, step: int, shard) -> None:
        """Rank pushes its shard to its buddy's memory."""
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), shard)
        with self._lock:
            self._mem[rank] = (step, host)

    def recover(self, failed_rank: int) -> Optional[tuple[int, Any]]:
        """Survivors fetch the last pushed copy of the failed rank's shard."""
        with self._lock:
            return self._mem.get(failed_rank)

    def drop(self, rank: int) -> None:
        with self._lock:
            self._mem.pop(rank, None)

    def ranks_covered(self) -> list[int]:
        with self._lock:
            return sorted(self._mem)
