"""Future abstractions (paper §III-A: the ``Future`` class).

Two flavours:

* :class:`Future` — wraps a transport :class:`~repro.core.transport.Request` (or a
  thread-backed :class:`AsyncOp` for collectives) plus the error channel of its
  ``Comm``. ``wait()`` is the paper's single choke point: it returns normally only if
  the operation completed *and* no error was signalled; otherwise it raises
  ``PropagatedError`` / ``CommCorruptedError`` / ``RevokedError`` / ``MpiError``.
* :class:`DeviceFuture` — the JAX adaptation: wraps the dispatched (asynchronous)
  outputs of a jitted step together with the in-band error word.  ``wait()`` blocks on
  the error word only (4 bytes), decodes it, and raises exactly the same exception
  types. See ``core/device_channel.py``.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional

from .errors import CancelledError, MpiError
from .transport import ReqState, Request


class AsyncOp:
    """A thread-backed non-blocking operation (used for collectives).

    The paper (§IV-B) notes that non-blocking *collectives* cannot be cancelled
    (``MPI_Cancel`` is erroneous for them) and therefore leak buffers/requests when a
    communicator is abandoned after an error. This class reproduces those semantics
    deliberately: an abandoned ``AsyncOp`` keeps its daemon thread and payload alive
    until the underlying collective completes — which, for an abandoned communicator,
    may be never. ``Transport.leaked_ops`` accounting in tests relies on this.
    """

    def __init__(self, transport, fn: Callable[[], Any]):
        self._t = transport
        self.state = ReqState.PENDING
        self.data: Any = None
        self.error: Optional[Exception] = None
        self.kind = "collective"

        def runner():
            try:
                self.data = fn()
                self.state = ReqState.COMPLETE
            except Exception as e:  # noqa: BLE001
                self.error = e
                self.state = ReqState.FAILED
            with self._t._cv:
                self._t._cv.notify_all()

        self._thread = threading.Thread(target=runner, daemon=True)
        self._thread.start()

    @property
    def done(self) -> bool:
        return self.state is not ReqState.PENDING


class Future:
    """Handle to one non-blocking operation on a ``Comm`` (paper Listing 1)."""

    def __init__(self, comm=None, request: Request | AsyncOp | None = None):
        self._comm = comm
        self._request = request
        self._waited = False

    @property
    def request(self):
        return self._request

    def valid(self) -> bool:
        return self._request is not None

    def test(self) -> bool:
        """Non-blocking completion probe (no error-channel handling)."""
        return self._request is not None and self._request.done

    def wait(self, timeout: float | None = None) -> Any:
        """Block until the operation completes or an error is delivered.

        Returns the received payload for receives, the reduction result for
        collectives, ``None`` for sends. Raises the paper's exception taxonomy.
        """
        if self._request is None:
            return None
        if self._waited:
            return self._payload()
        self._comm._protocol.wait(self._request, timeout=timeout)
        self._waited = True
        return self._payload()

    def _payload(self) -> Any:
        r = self._request
        if r.state is ReqState.CANCELLED:
            raise CancelledError("request was cancelled")
        if r.state is ReqState.FAILED and r.error is not None:
            raise r.error
        if getattr(r, "kind", None) in ("recv", "collective"):
            return r.data
        return None

    def cancel(self) -> bool:
        if isinstance(self._request, Request):
            return self._comm._ctx.cancel(self._request)
        return False  # paper §IV-B: collectives cannot be cancelled
