"""``Comm`` facade (paper Fig. 1): communicator + futures + error signalling.

The user-facing surface mirrors the paper's class diagram:

* ``send`` / ``recv`` / ``all_reduce`` return :class:`~repro.core.future.Future`;
* ``signal_error(code)`` propagates a local error to every rank;
* the object is a context manager: leaving the ``with`` block while an exception is
  unwinding marks the communicator corrupted on *all* ranks (the C++
  ``std::uncaught_exception``-in-destructor idiom, §III-A "Corrupted communicator");
* ``duplicate()`` / ``split()`` create derived communicators (Comm is 1:1 with an MPI
  communicator and therefore non-copyable — here: no ``__copy__``).

The protocol backend is chosen by capability, exactly as in the paper: ULFM if the
transport supports it, otherwise the Black Channel.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

from .blackchannel import BlackChannel
from .errors import CommCorruptedError, ErrorCode, ReproError
from .future import AsyncOp, Future
from .transport import ANY_SOURCE, ANY_TAG, CommContext, RankCtx
from .ulfm import UlfmChannel

DATA_TAG = 0


class Comm:
    """One communicator on one rank."""

    def __init__(self, ctx: RankCtx, base: CommContext | None = None, *,
                 default_timeout: float | None = None):
        self._ctx = ctx
        base = base if base is not None else ctx.world
        self._default_timeout = default_timeout
        if ctx.ulfm:
            self._protocol = UlfmChannel(ctx, base, default_timeout=default_timeout)
        else:
            self._protocol = BlackChannel(ctx, base, default_timeout=default_timeout)

    # --------------------------------------------------------------- introspection
    @property
    def rank(self) -> int:
        return self._protocol.comm.local_rank(self._ctx.rank)

    @property
    def size(self) -> int:
        return self._protocol.comm.size

    @property
    def context(self) -> CommContext:
        return self._protocol.comm

    @property
    def alive(self) -> bool:
        return self._protocol.alive

    @property
    def ulfm(self) -> bool:
        return self._ctx.ulfm

    # ------------------------------------------------------------- communication
    def send(self, data: Any, dst: int, tag: int = DATA_TAG) -> Future:
        req = self._protocol.post(
            lambda c: self._ctx.isend(c, dst, tag, data))
        return Future(self, self._protocol.track(req))

    def ssend(self, data: Any, dst: int, tag: int = DATA_TAG) -> Future:
        req = self._protocol.post(
            lambda c: self._ctx.issend(c, dst, tag, data))
        return Future(self, self._protocol.track(req))

    def recv(self, src: int = ANY_SOURCE, tag: int = ANY_TAG) -> Future:
        req = self._protocol.post(
            lambda c: self._ctx.irecv(c, src, tag))
        return Future(self, self._protocol.track(req))

    def all_reduce(self, value: Any, op: str = "sum") -> Future:
        """Non-blocking collective (paper: 'We exemplarily implemented the all_reduce
        functionality'). Backed by a helper thread; like MPI non-blocking
        collectives it cannot be cancelled — abandoning it leaks (paper §IV-B)."""
        ctx = self._ctx
        op_ = self._protocol.post(
            lambda c: AsyncOp(ctx.t, lambda: ctx.allreduce(c, value, op=op)))
        return Future(self, op_)

    def barrier(self, timeout: float | None = None) -> None:
        self._protocol.post(
            lambda c: self._ctx.barrier(
                c, timeout=timeout or self._default_timeout))

    # ------------------------------------------------------------------- errors
    def signal_error(self, code: int | ErrorCode, *,
                     timeout: float | None = None) -> None:
        """Propagate a local error to all ranks; raises ``PropagatedError`` locally
        (paper: 'The rank itself throws a Propagated_exception within the method
        signal_error')."""
        self._protocol.signal_error(code, timeout=timeout)

    # ------------------------------------------------------------------ derived
    def duplicate(self) -> "Comm":
        return Comm(self._ctx, self._ctx.dup(self._protocol.comm),
                    default_timeout=self._default_timeout)

    def split(self, members: Sequence[int]) -> Optional["Comm"]:
        """Create a sub-communicator from comm-local ranks ``members``; returns None
        on ranks not included (cf. ``MPI_Comm_split``)."""
        base = self._protocol.comm
        global_members = tuple(base.global_rank(m) for m in members)
        new_ctx = self._ctx.t.split(base, global_members, rank=self._ctx.rank)
        if self._ctx.rank not in global_members:
            return None
        return Comm._wrap(self._ctx, new_ctx, self._default_timeout)

    def repair(self, members: Sequence[int], key: object) -> Optional["Comm"]:
        """Fault-aware non-collective creation/reparation (arXiv 2209.01849):
        build a communicator over explicit **global** ranks ``members``
        without a collective over this (possibly corrupted) communicator.
        Unlike :meth:`split`, the member list may exclude dead ranks and may
        include ranks that were never members of this communicator — the one
        primitive that serves both fault-driven shrink *and* grow (rejoin /
        scale-out). All participants calling with the same ``(members, key)``
        share the resulting context. Returns None on excluded ranks."""
        global_members = tuple(int(m) for m in members)
        new_ctx = self._ctx.repair(global_members, key)
        if self._ctx.rank not in global_members:
            return None
        return Comm._wrap(self._ctx, new_ctx, self._default_timeout)

    @classmethod
    def _wrap(cls, ctx: RankCtx, base: CommContext,
              default_timeout: float | None = None) -> "Comm":
        obj = cls.__new__(cls)
        obj._ctx = ctx
        obj._default_timeout = default_timeout
        if ctx.ulfm:
            obj._protocol = UlfmChannel(ctx, base, default_timeout=default_timeout)
        else:
            obj._protocol = BlackChannel(ctx, base, default_timeout=default_timeout)
        return obj

    # ----------------------------------------------------- recovery (ULFM only)
    def shrink_to_survivors(self) -> "Comm":
        """After ``CommCorruptedError`` under ULFM: rebuild from survivors (LFLR)."""
        if not self._ctx.ulfm:
            raise CommCorruptedError(
                msg="black-channel communicator cannot shrink; rebuild required")
        self._protocol.shrink_to_survivors()
        return self

    # -------------------------------------------------------------- RAII analogue
    def __enter__(self) -> "Comm":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        """Paper: 'The Comm object detects in the destructor whether it gets
        destructed during stack unwinding due to a thrown exception ... interpreted
        as an unrecoverable error within the communicator.'

        An exception of the framework's own corrupted/propagated kind that was
        already globally agreed does not need re-signalling.
        """
        if exc_type is None:
            self._protocol.close()
            return False
        already_global = isinstance(exc, CommCorruptedError)
        if not already_global and self._protocol.alive:
            self._protocol.corrupted_teardown()
        return False  # never swallow the user's exception

    def close(self) -> None:
        self._protocol.close()
