"""Recovery policies — the paper's three use cases (§I) as composable strategies.

1. **LFLR** (local failure local recovery): restore only what was lost — from the
   in-memory buddy store for hard faults, or by recomputing/skipping for soft faults.
2. **Hierarchical escalation**: local repair plus a (semi-)global *reset* without a
   rollback — the Krylov-restart analogue for training is re-initialising optimizer
   moments (the "solver state") while keeping the parameters (the "current
   approximation").
3. **Global rollback**: restore the full state from the last checkpoint.

Policies are pure decision objects: they receive the exception + context and return a
:class:`RecoveryAction`; the executor applies it. This keeps them testable and lets
the escalation chain compose (try LFLR, escalate to rollback on repeat).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Optional

from .errors import CommCorruptedError, ErrorCode, PropagatedError, ReproError


class Action(enum.Enum):
    CONTINUE = "continue"              # ignore (log only)
    SKIP_BATCH = "skip_batch"          # drop this step's update, keep state
    RESET_OPTIMIZER = "reset_optimizer"  # use case 2: keep params, reset solver state
    RESTORE_GOOD = "restore_good"      # LFLR: restore last known-good in-memory state
    ROLLBACK = "rollback"              # use case 3: restore from durable checkpoint
    SHRINK = "shrink"                  # hard fault: rebuild communicator/mesh minus dead
    ABORT = "abort"                    # unrecoverable


@dataclass
class RecoveryDecision:
    action: Action
    reason: str = ""
    # optional knobs the executor honours
    lr_scale: float = 1.0


@dataclass
class RecoveryPolicy:
    """Escalating default policy.

    Soft faults: transient (single NaN/overflow batch) → SKIP_BATCH; repeated within
    ``escalate_window`` steps → RESTORE_GOOD; divergence → RESET_OPTIMIZER (+ lr
    decay); persistent → ROLLBACK. Hard faults (corrupted comm / rank loss) →
    SHRINK (ULFM/elastic path) or ROLLBACK (black-channel path, which cannot
    shrink — paper §III-C).
    """

    escalate_window: int = 20
    max_soft_retries: int = 3
    divergence_lr_decay: float = 0.5
    can_shrink: bool = True

    _recent_faults: list = field(default_factory=list)

    def decide(self, exc: ReproError, step: int) -> RecoveryDecision:
        if isinstance(exc, CommCorruptedError):
            if self.can_shrink:
                return RecoveryDecision(Action.SHRINK,
                                        reason="hard fault: shrink + buddy restore")
            return RecoveryDecision(Action.ROLLBACK,
                                    reason="hard fault without ULFM: rollback")
        if not isinstance(exc, PropagatedError):
            return RecoveryDecision(Action.ABORT, reason=f"unhandled: {exc!r}")

        code = exc.combined_code
        self._recent_faults = [s for s in self._recent_faults
                               if step - s < self.escalate_window]
        self._recent_faults.append(step)
        repeats = len(self._recent_faults)

        if code & ErrorCode.RANK_FAILED:
            return (RecoveryDecision(Action.SHRINK, reason="rank failed")
                    if self.can_shrink else
                    RecoveryDecision(Action.ROLLBACK, reason="rank failed"))
        if repeats > self.max_soft_retries:
            return RecoveryDecision(
                Action.ROLLBACK,
                reason=f"{repeats} soft faults in {self.escalate_window} steps")
        if code & ErrorCode.DIVERGENCE:
            # use case 2: local repair + global solver reset, no rollback
            return RecoveryDecision(Action.RESET_OPTIMIZER,
                                    reason="divergence: optimizer reset",
                                    lr_scale=self.divergence_lr_decay)
        if code & (ErrorCode.NONFINITE_LOSS | ErrorCode.NONFINITE_GRAD
                   | ErrorCode.OVERFLOW | ErrorCode.DATA_FAULT):
            if repeats > 1:
                return RecoveryDecision(Action.RESTORE_GOOD,
                                        reason="repeated soft fault: LFLR restore")
            return RecoveryDecision(Action.SKIP_BATCH,
                                    reason="transient soft fault: skip batch")
        if code & ErrorCode.STATE_FAULT:
            return RecoveryDecision(Action.RESTORE_GOOD,
                                    reason="recurrent-state fault: LFLR restore")
        if code & ErrorCode.PAGE_FAULT:
            # paged-KV ownership violation: reclaiming + re-acquiring the
            # sequence's pages (the serving LFLR lane) rebuilds the mapping
            return RecoveryDecision(Action.RESTORE_GOOD,
                                    reason="page-ownership fault: reclaim + LFLR")
        if code & ErrorCode.ROUTER_OVERFLOW:
            return RecoveryDecision(Action.CONTINUE, reason="router overflow: logged")
        if code & ErrorCode.STRAGGLER:
            return RecoveryDecision(Action.CONTINUE, reason="straggler: logged")
        if code & ErrorCode.USER:
            return RecoveryDecision(Action.SKIP_BATCH, reason="user-signalled")
        return RecoveryDecision(Action.SKIP_BATCH, reason=f"default for {code!r}")

    def reset(self) -> None:
        self._recent_faults.clear()
