"""The Black-Channel protocol — faithful implementation of paper §III-B.

Requires only MPI-3.0-level primitives (here: :class:`~repro.core.transport.RankCtx`):

* construction duplicates the user communicator into an *error communicator*
  (``comm_err``) and pre-posts one wildcard non-blocking receive (``err_req``);
* ``signal_error`` posts a matching synchronous-mode send (``MPI_Issend``) to every
  other rank and cancels the local ``err_req``;
* every wait is ``MPI_Waitany({request, err_req})`` so a rank blocked in communication
  is released the moment any peer signals — this *precludes the deadlock* that a local
  exception would otherwise cause;
* the rendezvous is ``barrier → allreduce(BAND)`` (corrupted-communicator vote), then
  the failed-rank enumeration: ``scan(SUM)`` assigns each signaller an index,
  ``bcast`` from the last rank publishes the count, and ``allreduce(MAX)`` over a
  zero-initialised table delivers every ``(rank, code)`` pair to every rank.
"""
from __future__ import annotations

from typing import Optional, Sequence

from .errors import (
    CommCorruptedError,
    ErrorCode,
    MpiError,
    PropagatedError,
    RankError,
)
from .transport import ANY_SOURCE, CommContext, RankCtx, ReqState, Request

ERR_TAG = 999


class _ErrOutcome(Exception):
    """Internal: carries the protocol outcome through the common error path."""

    def __init__(self, exc: Exception):
        self.exc = exc


class BlackChannel:
    """Per-rank protocol state for one communicator (paper Fig. 1 ``Comm`` internals)."""

    def __init__(self, ctx: RankCtx, base: CommContext,
                 default_timeout: float | None = None):
        self.ctx = ctx
        self.comm = base
        # paper: "The constructor of the Comm object duplicates the MPI communicator
        # by calling MPI_Comm_dup. The new communicator is called comm_err."
        self.err_comm = ctx.dup(base)
        self.err_req: Optional[Request] = None
        self.alive = True           # False once the communicator is corrupted
        self.default_timeout = default_timeout
        self._tracked: list[Request] = []   # outstanding user requests on this comm
        self._post_err_recv()

    # ------------------------------------------------------------------ plumbing
    def _post_err_recv(self) -> None:
        # paper: "In comm_err we create a non-blocking receive operation via
        # MPI_Irecv and store the pending request in err_req."
        self.err_req = self.ctx.irecv(self.err_comm, ANY_SOURCE, ERR_TAG)

    @property
    def rank(self) -> int:
        return self.comm.local_rank(self.ctx.rank)

    @property
    def size(self) -> int:
        return self.comm.size

    def _t(self, timeout):
        return timeout if timeout is not None else self.default_timeout

    def track(self, req: Request) -> Request:
        """Register a user request so an error epoch can drain it (a request
        abandoned by an exception must not steal a post-recovery match)."""
        self._tracked = [r for r in self._tracked if not r.done]
        self._tracked.append(req)
        return req

    def _drain_tracked(self) -> None:
        for r in self._tracked:
            if not r.done:
                self.ctx.cancel(r)
        self._tracked.clear()

    def post(self, fn):
        """Issue an operation on the user communicator (no ULFM error surface in
        MPI-3.0 mode; kept symmetric with :class:`UlfmChannel.post`)."""
        if not self.alive:
            raise CommCorruptedError(msg="operation on corrupted communicator")
        return fn(self.comm)

    # ------------------------------------------------------------------- waiting
    def wait(self, request, timeout: float | None = None) -> None:
        """Paper: ``MPI_Waitany`` over {request, err_req}; on completion of the user
        request, additionally ``MPI_Test`` the error request."""
        if not self.alive:
            raise CommCorruptedError(msg="wait on corrupted communicator")
        timeout = self._t(timeout)
        idx, r = self.ctx.waitany([request, self.err_req], timeout=timeout)
        if idx == 0:
            if r.state is ReqState.FAILED:
                raise MpiError(-1, f"request failed: {r.error}") from r.error
            # "if MPI_Waitany completes request, the method uses MPI_Test to check
            # whether an error was signaled"
            if self.ctx.test(self.err_req):
                self._enter_error_state(timeout=timeout)
            return
        # err_req completed: an error was signalled remotely
        self._enter_error_state(timeout=timeout)

    # ------------------------------------------------------------------ signalling
    def signal_error(self, code: int | ErrorCode, *, corrupted: bool = False,
                     timeout: float | None = None, reraise: bool = True) -> None:
        """Paper: propagate a local error to all remote ranks.

        ``corrupted=True`` is the destructor-during-stack-unwinding path: this rank
        votes 0 in the BAND allreduce and every rank throws ``CommCorruptedError``.
        Otherwise every rank (including this one) throws ``PropagatedError`` carrying
        all (rank, code) pairs.
        """
        if not self.alive:
            raise CommCorruptedError(msg="signal_error on corrupted communicator")
        self._enter_error_state(signal=(int(code), corrupted),
                                timeout=self._t(timeout), reraise=reraise)

    # ---------------------------------------------------------------- error state
    def _enter_error_state(self, signal: tuple[int, bool] | None = None,
                           timeout: float | None = None,
                           reraise: bool = True) -> None:
        ctx, err = self.ctx, self.err_comm
        my_rank, size = err.local_rank(ctx.rank), err.size
        am_signaller = signal is not None
        my_code, corrupted = signal if signal is not None else (0, False)

        # Drain abandoned user requests *before* the barrier: every rank drains
        # before any rank can exit the epoch (the allreduce is the fence), so a
        # stale posted receive can never steal a post-recovery message.
        self._drain_tracked()

        send_reqs: list[Request] = []
        if am_signaller:
            # "The function signal_error issues a matching MPI_Issend for err_req to
            # all other ranks and cancels its own err_req. It uses the non-blocking
            # operation since it is possible that two ranks simultaneously propagate
            # errors."
            for dst in range(size):
                if dst != my_rank:
                    send_reqs.append(
                        ctx.issend(err, dst, ERR_TAG, (my_rank, my_code)))
            ctx.cancel(self.err_req)  # may fail if a peer signalled concurrently — fine

        # "Once all error messages have been send or a rank receives an error
        # message, it calls MPI_Barrier to wait for all ranks being in the error
        # state."
        ctx.barrier(err, timeout=timeout)

        # "When all ranks reach the barrier, the propagating ranks cancel the pending
        # send requests, which are the send requests to the ranks that got signaled
        # by another rank."
        for s in send_reqs:
            ctx.cancel(s)

        # "Then all ranks perform an MPI_Allreduce operation with an MPI_BAND operator
        # to determine if the communicator is corrupted, i.e. signal_error was called
        # by the destructor of Comm during stack unwinding."
        ok = ctx.allreduce(err, 0 if corrupted else 1, op="band", timeout=timeout)
        if ok == 0:
            self.alive = False
            exc: Exception = CommCorruptedError()
        else:
            errors = self._enumerate_failed(am_signaller, my_code, timeout)
            # channel survives a recoverable (propagated) error: re-arm for reuse
            self._post_err_recv()
            exc = PropagatedError(errors)
        if reraise:
            raise exc

    def _enumerate_failed(self, am_signaller: bool, my_code: int,
                          timeout: float | None) -> list[RankError]:
        """Paper §III-B, 'Determine failed ranks and codes'."""
        ctx, err = self.ctx, self.err_comm
        my_rank, size = err.local_rank(ctx.rank), err.size
        flag = 1 if am_signaller else 0
        # "we do an MPI_Scan with the operation MPI_SUM, where failed ranks
        # participate with a 1 ... This assigns every failed node an index."
        idx = ctx.scan(err, flag, op="sum", timeout=timeout)
        # "The number of failed nodes is then propagated by an MPI_Bcast of the last
        # rank."
        count = ctx.bcast(err, idx if my_rank == size - 1 else None,
                          root=size - 1, timeout=timeout)
        # "Now all ranks allocate memory for the rank numbers and error codes of the
        # failed ranks and initialise it with zeros. The failed ranks write their rank
        # number and error code ... with respect to their index. Finally an
        # MPI_Allreduce with MPI_MAX is performed to propagate all the information."
        table = [0] * (2 * count)
        if am_signaller:
            k = idx - 1
            table[2 * k] = my_rank
            table[2 * k + 1] = my_code
        table = ctx.allreduce(err, table, op="emax", timeout=timeout)
        return [RankError(rank=table[2 * i], code=table[2 * i + 1])
                for i in range(count)]

    # ------------------------------------------------------------------ teardown
    def corrupted_teardown(self, timeout: float | None = None) -> None:
        """Destructor-during-unwinding path (swallows the resulting exception so the
        original user exception keeps unwinding, like a C++ destructor must)."""
        if not self.alive:
            return
        try:
            self.signal_error(ErrorCode.COMM_CORRUPTED, corrupted=True,
                              timeout=self._t(timeout), reraise=False)
        finally:
            self.alive = False

    def close(self) -> None:
        """Orderly destruction (no unwinding): cancel the pre-posted receive."""
        if self.err_req is not None and not self.err_req.done:
            self.ctx.cancel(self.err_req)
        self.alive = False
