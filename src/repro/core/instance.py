"""``Instance`` singleton (paper §III-A).

The paper wraps ``MPI_Init``/``MPI_Finalize`` in a singleton so that initialisation
happens exactly once and finalisation only if this object performed the init. In the
simulated multi-rank runtime the "process" is a rank thread, so the singleton is
per-(transport, rank).
"""
from __future__ import annotations

import threading
from typing import Optional

from .comm import Comm
from .errors import MpiError
from .transport import RankCtx

_registry: dict[tuple[int, int], "Instance"] = {}
_registry_lock = threading.Lock()


class Instance:
    """Per-rank runtime instance; owns ``comm_world``."""

    def __init__(self, ctx: RankCtx, *, default_timeout: float | None = None):
        self._ctx = ctx
        self._finalized = False
        self._world: Optional[Comm] = None
        self._default_timeout = default_timeout

    def comm_world(self) -> Comm:
        if self._finalized:
            raise MpiError(-1, "instance already finalized")
        if self._world is None:
            self._world = Comm(self._ctx, self._ctx.world,
                               default_timeout=self._default_timeout)
        return self._world

    @property
    def rank(self) -> int:
        return self._ctx.rank

    @property
    def size(self) -> int:
        return self._ctx.world.size

    def finalize(self) -> None:
        if self._world is not None:
            self._world.close()
        self._finalized = True
        with _registry_lock:
            _registry.pop((id(self._ctx.t), self._ctx.rank), None)

    def __enter__(self) -> "Instance":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.finalize()
        return False


def initialize(ctx: RankCtx, *, default_timeout: float | None = None) -> Instance:
    """Idempotent per-rank initialisation (paper: 'The constructor checks if MPI is
    already initialised')."""
    key = (id(ctx.t), ctx.rank)
    with _registry_lock:
        inst = _registry.get(key)
        if inst is None or inst._finalized:
            inst = Instance(ctx, default_timeout=default_timeout)
            _registry[key] = inst
        return inst
