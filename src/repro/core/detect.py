"""Soft-fault probes (paper §II-A 'soft failures') for use *inside* jitted steps.

Each probe returns a uint32 error word (the :class:`~repro.core.errors.ErrorCode`
lattice); words combine with bitwise-or and ride the in-band device channel
(``core/device_channel.py``). The heavy probes (full grad/param stream) use the
``fault_probe`` Pallas kernel so detection stays at the memory roofline.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels.fault_probe import probe_tree
from .device_channel import WORD_DTYPE, combine_words
from .errors import ErrorCode


@dataclass(frozen=True)
class ProbeConfig:
    overflow_threshold: float = 1e4      # pre-NaN early warning on grads
    loss_divergence_threshold: float = 1e3
    router_drop_threshold: float = 0.5   # MoE: fraction of dropped tokens
    use_kernel: bool = True
    probe_params: bool = False           # post-update param check (2x memory traffic)


def _flag(cond: jax.Array, code: ErrorCode) -> jax.Array:
    return jnp.where(cond, jnp.uint32(int(code)), jnp.uint32(0))


def loss_probe(loss: jax.Array, cfg: ProbeConfig = ProbeConfig()) -> jax.Array:
    """NONFINITE_LOSS | DIVERGENCE (paper: 'a solver could diverge')."""
    loss = loss.astype(jnp.float32)
    nonfinite = jnp.logical_not(jnp.isfinite(loss))
    diverged = jnp.logical_and(jnp.isfinite(loss),
                               loss > cfg.loss_divergence_threshold)
    return _flag(nonfinite, ErrorCode.NONFINITE_LOSS) | _flag(
        diverged, ErrorCode.DIVERGENCE)


def grad_probe(grads, cfg: ProbeConfig = ProbeConfig()) -> jax.Array:
    """NONFINITE_GRAD | OVERFLOW over the whole gradient pytree (fused kernel)."""
    return probe_tree(grads, cfg.overflow_threshold,
                      nonfinite_code=int(ErrorCode.NONFINITE_GRAD),
                      overflow_code=int(ErrorCode.OVERFLOW),
                      use_kernel=cfg.use_kernel)


def param_probe(params, cfg: ProbeConfig = ProbeConfig()) -> jax.Array:
    return probe_tree(params, jnp.inf,
                      nonfinite_code=int(ErrorCode.NONFINITE_PARAM),
                      overflow_code=int(ErrorCode.OVERFLOW),
                      use_kernel=cfg.use_kernel)


def state_probe(state, cfg: ProbeConfig = ProbeConfig()) -> jax.Array:
    """Recurrent-state check (SSM/RG-LRU archs): STATE_FAULT."""
    return probe_tree(state, jnp.inf,
                      nonfinite_code=int(ErrorCode.STATE_FAULT),
                      overflow_code=int(ErrorCode.STATE_FAULT),
                      use_kernel=cfg.use_kernel)


def router_probe(dropped_fraction: jax.Array,
                 cfg: ProbeConfig = ProbeConfig()) -> jax.Array:
    """MoE local misbehaviour: excessive token dropping (capacity overflow)."""
    return _flag(dropped_fraction > cfg.router_drop_threshold,
                 ErrorCode.ROUTER_OVERFLOW)


def data_probe(tokens: jax.Array, vocab_size: int) -> jax.Array:
    """Corrupt-batch check: token ids outside [0, vocab)."""
    bad = jnp.logical_or(jnp.any(tokens < 0), jnp.any(tokens >= vocab_size))
    return _flag(bad, ErrorCode.DATA_FAULT)


def step_probe(loss: jax.Array, grads, *, tokens: jax.Array | None = None,
               vocab_size: int | None = None, states=None,
               router_dropped: jax.Array | None = None,
               cfg: ProbeConfig = ProbeConfig()) -> jax.Array:
    """Combined per-step error word: the standard probe set for a train step."""
    words = [loss_probe(loss, cfg), grad_probe(grads, cfg)]
    if tokens is not None and vocab_size is not None:
        words.append(data_probe(tokens, vocab_size))
    if states is not None:
        words.append(state_probe(states, cfg))
    if router_dropped is not None:
        words.append(router_probe(router_dropped, cfg))
    return combine_words(*words)
