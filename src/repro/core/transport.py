"""In-process multi-rank transport: the substrate under the paper's protocols.

The paper's mechanisms (black channel, ULFM adoption) are defined against MPI
point-to-point / collective semantics. JAX has no user-level point-to-point runtime, so
for the *faithful reproduction* we implement the exact request semantics the paper
relies on — non-blocking (synchronous-mode) sends, pre-posted wildcard receives,
``MPI_Cancel``, ``MPI_Waitany``, and fault-aware collectives — over OS threads, one
thread per rank. This is the same role the MPI library plays in the paper; the
protocols in ``blackchannel.py`` / ``ulfm.py`` are written purely against the
:class:`RankCtx` API and do not know they are running on threads.

Failure model:

* ``Transport.kill(rank)`` simulates a *hard fault* (paper §II-A): the rank's thread is
  unwound at its next transport call, it stops participating in all communication.
* In **plain mode** (``ulfm=False``, i.e. MPI-3.0 semantics) operations involving a dead
  peer simply never complete — exactly the deadlock the paper sets out to preclude.
  Tests assert this via wait timeouts.
* In **ULFM mode** (``ulfm=True``) a built-in failure detector makes any operation
  involving a dead peer raise :class:`~repro.core.errors.RankFailedError`
  (``MPI_ERR_PROC_FAILED``), pending wildcard receives fail
  (``MPI_ERR_PROC_FAILED_PENDING``), ``revoke`` poisons a communicator
  (``MPI_ERR_COMM_REVOKED``), ``agree`` is a fault-tolerant AND-allreduce over
  survivors, and ``shrink`` builds a new communicator from survivors.
"""
from __future__ import annotations

import enum
import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from .errors import (
    CancelledError,
    MpiError,
    RankFailedError,
    RevokedError,
    TimeoutError_,
)

ANY_SOURCE = -1
ANY_TAG = -1


class _RankKilled(BaseException):
    """Unwinds a killed rank's thread. BaseException so user ``except Exception``
    blocks (application code) cannot swallow a simulated process death."""


class ReqState(enum.Enum):
    PENDING = "pending"
    COMPLETE = "complete"
    CANCELLED = "cancelled"
    FAILED = "failed"


_req_ids = itertools.count()


class Request:
    """A communication request (``MPI_Request`` analogue)."""

    __slots__ = ("id", "kind", "ctx_id", "owner", "peer", "tag", "data", "state",
                 "error", "source", "synchronous")

    def __init__(self, kind: str, ctx_id: int, owner: int, peer: int, tag: int,
                 data: Any = None, synchronous: bool = False):
        self.id = next(_req_ids)
        self.kind = kind              # "send" | "recv"
        self.ctx_id = ctx_id
        self.owner = owner            # global rank that posted the request
        self.peer = peer              # global rank of the peer (or ANY_SOURCE)
        self.tag = tag
        self.data = data              # payload (send) / received payload (recv)
        self.state = ReqState.PENDING
        self.error: Optional[Exception] = None
        self.source: Optional[int] = None   # actual source for wildcard recvs
        self.synchronous = synchronous      # Issend: complete only on match

    @property
    def done(self) -> bool:
        return self.state is not ReqState.PENDING

    def __repr__(self) -> str:  # pragma: no cover
        return (f"<Request {self.id} {self.kind} owner={self.owner} peer={self.peer} "
                f"tag={self.tag} {self.state.value}>")


@dataclass
class CommContext:
    """A communicator: an ordered member list + collective state + revocation flag."""

    id: int
    members: tuple[int, ...]             # global ranks, ordered; index = comm-local rank
    revoked: bool = False
    # per-global-rank collective sequence counter (keeps slots aligned across ranks)
    coll_seq: dict[int, int] = field(default_factory=dict)
    # per-global-rank derived-communicator sequence counter (dup/split consistency)
    dup_seq: dict[int, int] = field(default_factory=dict)
    # agree has its OWN sequence space: after a revoke, ordinary collective
    # counters are misaligned across ranks (some ops failed before, some after
    # incrementing) — exactly why ULFM specifies agree as a separate
    # fault-tolerant protocol rather than an ordinary collective.
    agree_seq: dict[int, int] = field(default_factory=dict)

    def local_rank(self, global_rank: int) -> int:
        return self.members.index(global_rank)

    def global_rank(self, local: int) -> int:
        return self.members[local]

    @property
    def size(self) -> int:
        return len(self.members)


_COLL_OPS: dict[str, Callable[[Any, Any], Any]] = {
    "sum": lambda a, b: a + b,
    "max": lambda a, b: max(a, b),
    "min": lambda a, b: min(a, b),
    "band": lambda a, b: a & b,
    "bor": lambda a, b: a | b,
    "land": lambda a, b: bool(a) and bool(b),
    "lor": lambda a, b: bool(a) or bool(b),
    # elementwise max over equal-length sequences (paper §III-B enumeration table)
    "emax": lambda a, b: [max(x, y) for x, y in zip(a, b)],
}


class _CollSlot:
    """One in-flight collective operation instance."""

    __slots__ = ("key", "ctx_id", "kind", "op", "required", "arrived", "done",
                 "result", "error", "root")

    def __init__(self, key, ctx_id, kind, op, required, root=None):
        self.key = key
        self.ctx_id = ctx_id
        self.kind = kind              # barrier|allreduce|scan|bcast|gather|agree
        self.op = op
        self.required = set(required)  # global ranks that must arrive
        self.arrived: dict[int, Any] = {}
        self.done = False
        self.result: Any = None
        self.error: Optional[Exception] = None
        self.root = root


class Transport:
    """N simulated ranks over threads. All state guarded by one condition variable."""

    def __init__(self, nranks: int, *, ulfm: bool = False):
        self.nranks = nranks
        self.ulfm = ulfm
        self._cv = threading.Condition()
        self._ctx_ids = itertools.count()
        self.dead: set[int] = set()
        # mailboxes: (ctx_id, dst_global) -> list of unmatched send Requests
        self._mail: dict[tuple[int, int], list[Request]] = {}
        # pending receives: (ctx_id, dst_global) -> list of pending recv Requests
        self._recvs: dict[tuple[int, int], list[Request]] = {}
        self._slots: dict[tuple, _CollSlot] = {}
        self._contexts: dict[int, CommContext] = {}
        self._derived: dict[tuple, CommContext] = {}
        self.world = self._new_context(tuple(range(nranks)))

    # ------------------------------------------------------------------ contexts
    def _new_context(self, members: tuple[int, ...]) -> CommContext:
        ctx = CommContext(id=next(self._ctx_ids), members=members,
                          coll_seq={r: 0 for r in members})
        self._contexts[ctx.id] = ctx
        return ctx

    def dup(self, ctx: CommContext, rank: int | None = None) -> CommContext:
        """``MPI_Comm_dup``: same members, fresh context (fresh tag/collective space).

        Collective-consistent: the k-th dup of a given context yields the *same* new
        context on every rank (keyed by a per-rank dup sequence counter, like the
        collective sequence numbers)."""
        with self._cv:
            if rank is None:
                return self._new_context(ctx.members)
            seq = ctx.dup_seq.get(rank, 0)
            ctx.dup_seq[rank] = seq + 1
            key = (ctx.id, "dup", seq)
            got = self._derived.get(key)
            if got is None:
                got = self._new_context(ctx.members)
                self._derived[key] = got
            return got

    def split(self, ctx: CommContext, members: Sequence[int],
              rank: int | None = None) -> CommContext:
        """Collective-consistent split (all ranks calling with the same member list
        in the same order share the resulting context)."""
        with self._cv:
            members = tuple(members)
            if rank is None:
                return self._new_context(members)
            seq = ctx.dup_seq.get(rank, 0)
            ctx.dup_seq[rank] = seq + 1
            key = (ctx.id, "split", seq, members)
            got = self._derived.get(key)
            if got is None:
                got = self._new_context(members)
                self._derived[key] = got
            return got

    def repair(self, members: Sequence[int], key: object) -> CommContext:
        """Fault-aware **non-collective** communicator creation (the
        reparation primitive of arXiv 2209.01849): build a context from an
        explicit global member list without a collective over any parent —
        so it works when the parent communicator contains dead ranks, and a
        *joining* rank (not a member of any survivor communicator) can reach
        the same context as the survivors.

        Every participant calls independently with the same ``(members,
        key)`` and receives the same context; ``key`` disambiguates repeated
        repairs over the same membership (the serve group keys it by its
        ledger epoch)."""
        with self._cv:
            members = tuple(members)
            cache_key = ("repair", members, key)
            got = self._derived.get(cache_key)
            if got is None:
                got = self._new_context(members)
                self._derived[cache_key] = got
            return got

    # ------------------------------------------------------------------- failure
    def kill(self, rank: int) -> None:
        """Simulate a hard fault of ``rank`` (process/node loss)."""
        with self._cv:
            if rank in self.dead:
                return
            self.dead.add(rank)
            if self.ulfm:
                self._fail_requests_involving(rank)
                self._reeval_slots_after_death()
            self._cv.notify_all()

    def revoke(self, ctx: CommContext) -> None:
        """ULFM ``MPI_Comm_revoke``: poison the context for every rank."""
        with self._cv:
            if ctx.revoked:
                return
            ctx.revoked = True
            err = RevokedError()
            for (cid, _dst), reqs in list(self._mail.items()):
                if cid == ctx.id:
                    for r in reqs:
                        self._finish(r, ReqState.FAILED, error=err)
                    reqs.clear()
            for (cid, _dst), reqs in list(self._recvs.items()):
                if cid == ctx.id:
                    for r in reqs:
                        self._finish(r, ReqState.FAILED, error=err)
                    reqs.clear()
            for slot in self._slots.values():
                if slot.ctx_id == ctx.id and not slot.done and slot.kind != "agree":
                    slot.error = RevokedError()
                    slot.done = True
            self._cv.notify_all()

    def _fail_requests_involving(self, rank: int) -> None:
        """ULFM failure detector: fail pending requests whose peer is dead."""
        err = RankFailedError([rank])
        for reqs in self._mail.values():
            for r in list(reqs):
                if r.peer == rank or r.owner == rank:
                    self._finish(r, ReqState.FAILED, error=err)
                    reqs.remove(r)
        for reqs in self._recvs.values():
            for r in list(reqs):
                # MPI_ERR_PROC_FAILED (named peer) / _PENDING (wildcard)
                if r.peer == rank or r.peer == ANY_SOURCE or r.owner == rank:
                    self._finish(r, ReqState.FAILED, error=err)
                    reqs.remove(r)

    def _reeval_slots_after_death(self) -> None:
        for slot in self._slots.values():
            if slot.done:
                continue
            dead_members = slot.required & self.dead
            if not dead_members:
                continue
            if slot.kind == "agree":
                # fault-tolerant: requirement shrinks to survivors
                slot.required -= self.dead
                self._maybe_complete_slot(slot)
            else:
                slot.error = RankFailedError(sorted(dead_members))
                slot.done = True

    # ------------------------------------------------------------- rank liveness
    def _check_alive(self, rank: int) -> None:
        if rank in self.dead:
            raise _RankKilled()

    def _check_ctx(self, ctx: CommContext, *, allow_revoked: bool = False) -> None:
        if ctx.revoked and not allow_revoked:
            raise RevokedError()

    # ------------------------------------------------------------- point-to-point
    def _post_send(self, ctx: CommContext, src: int, dst_local: int, tag: int,
                   data: Any, synchronous: bool) -> Request:
        with self._cv:
            self._check_alive(src)
            self._check_ctx(ctx)
            dst = ctx.global_rank(dst_local)
            req = Request("send", ctx.id, src, dst, tag, data=data,
                          synchronous=synchronous)
            if self.ulfm and dst in self.dead:
                req.state = ReqState.FAILED
                req.error = RankFailedError([dst])
                return req
            # try to match a pending recv at the destination
            key = (ctx.id, dst)
            for r in self._recvs.get(key, []):
                if self._match(r, src, tag):
                    self._deliver(r, req)
                    self._recvs[key].remove(r)
                    self._cv.notify_all()
                    return req
            self._mail.setdefault(key, []).append(req)
            if not synchronous:
                # buffered send: complete immediately (payload copied by value)
                req.state = ReqState.COMPLETE
            self._cv.notify_all()
            return req

    def isend(self, ctx, src, dst_local, tag, data) -> Request:
        return self._post_send(ctx, src, dst_local, tag, data, synchronous=False)

    def issend(self, ctx, src, dst_local, tag, data) -> Request:
        """Synchronous-mode send: completes only when matched (``MPI_Issend``)."""
        return self._post_send(ctx, src, dst_local, tag, data, synchronous=True)

    def irecv(self, ctx: CommContext, owner: int, src_local: int, tag: int) -> Request:
        with self._cv:
            self._check_alive(owner)
            self._check_ctx(ctx)
            src = ANY_SOURCE if src_local == ANY_SOURCE else ctx.global_rank(src_local)
            req = Request("recv", ctx.id, owner, src, tag)
            if self.ulfm and src != ANY_SOURCE and src in self.dead:
                req.state = ReqState.FAILED
                req.error = RankFailedError([src])
                return req
            key = (ctx.id, owner)
            for s in self._mail.get(key, []):
                if self._match(req, s.owner, s.tag):
                    self._deliver(req, s)
                    self._mail[key].remove(s)
                    self._cv.notify_all()
                    return req
            self._recvs.setdefault(key, []).append(req)
            self._cv.notify_all()
            return req

    @staticmethod
    def _match(recv: Request, src: int, tag: int) -> bool:
        return ((recv.peer == ANY_SOURCE or recv.peer == src)
                and (recv.tag == ANY_TAG or recv.tag == tag))

    def _deliver(self, recv: Request, send: Request) -> None:
        recv.data = send.data
        recv.source = send.owner
        self._finish(recv, ReqState.COMPLETE)
        self._finish(send, ReqState.COMPLETE)

    def _finish(self, req: Request, state: ReqState, error: Exception | None = None) -> None:
        if req.state is ReqState.PENDING:
            req.state = state
            req.error = error

    def cancel(self, req: Request) -> bool:
        """``MPI_Cancel``: succeeds iff the request has not been matched yet."""
        with self._cv:
            if req.state is not ReqState.PENDING:
                return False
            store = self._mail if req.kind == "send" else self._recvs
            for key, lst in store.items():
                if key[0] == req.ctx_id and req in lst:
                    lst.remove(req)
                    break
            self._finish(req, ReqState.CANCELLED, error=CancelledError())
            self._cv.notify_all()
            return True

    # ------------------------------------------------------------------- waiting
    def test(self, rank: int, req: Request) -> bool:
        with self._cv:
            self._check_alive(rank)
            return req.done

    def wait(self, rank: int, req: Request, timeout: float | None = None) -> Request:
        idx, r = self.waitany(rank, [req], timeout=timeout)
        return r

    def waitany(self, rank: int, reqs: Sequence[Request],
                timeout: float | None = None) -> tuple[int, Request]:
        """``MPI_Waitany``: block until any request completes/fails/cancels."""
        with self._cv:
            deadline = None if timeout is None else _now() + timeout
            while True:
                self._check_alive(rank)
                for i, r in enumerate(reqs):
                    if r.done:
                        return i, r
                remaining = None if deadline is None else deadline - _now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError_(f"waitany timed out after {timeout}s")
                self._cv.wait(timeout=remaining if remaining is not None else 0.25)

    def waitall(self, rank: int, reqs: Sequence[Request],
                timeout: float | None = None) -> None:
        with self._cv:
            deadline = None if timeout is None else _now() + timeout
            while True:
                self._check_alive(rank)
                if all(r.done for r in reqs):
                    return
                remaining = None if deadline is None else deadline - _now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError_(f"waitall timed out after {timeout}s")
                self._cv.wait(timeout=remaining if remaining is not None else 0.25)

    # ---------------------------------------------------------------- collectives
    def _collective(self, ctx: CommContext, rank: int, kind: str, value: Any,
                    op: str | None = None, root: int | None = None,
                    timeout: float | None = None) -> Any:
        allow_revoked = kind == "agree"
        with self._cv:
            self._check_alive(rank)
            self._check_ctx(ctx, allow_revoked=allow_revoked)
            counter = ctx.agree_seq if kind == "agree" else ctx.coll_seq
            seq = counter.get(rank, 0)
            counter[rank] = seq + 1
            key = (ctx.id, kind, seq)
            slot = self._slots.get(key)
            if slot is None:
                required = set(ctx.members)
                if kind == "agree":
                    required -= self.dead
                slot = _CollSlot(key, ctx.id, kind,
                                 _COLL_OPS.get(op) if op else None, required, root)
                self._slots[key] = slot
            slot.arrived[rank] = value
            # ULFM failure detector also fires for slots created *after* a death
            if (self.ulfm and not slot.done and kind != "agree"
                    and slot.required & self.dead):
                slot.error = RankFailedError(sorted(slot.required & self.dead))
                slot.done = True
            self._maybe_complete_slot(slot)
            self._cv.notify_all()
            deadline = None if timeout is None else _now() + timeout
            while not slot.done:
                self._check_alive(rank)
                if ctx.revoked and not allow_revoked:
                    raise RevokedError()
                remaining = None if deadline is None else deadline - _now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError_(f"collective {kind} timed out")
                self._cv.wait(timeout=remaining if remaining is not None else 0.25)
            if slot.error is not None:
                raise slot.error
            if kind == "scan":
                # inclusive prefix over comm-local rank order
                local = ctx.local_rank(rank)
                acc = None
                for gr in ctx.members[: local + 1]:
                    if gr in slot.arrived:
                        v = slot.arrived[gr]
                        acc = v if acc is None else slot.op(acc, v)
                return acc
            if kind == "gather":
                return [slot.arrived.get(gr) for gr in ctx.members]
            return slot.result

    def _maybe_complete_slot(self, slot: _CollSlot) -> None:
        if slot.done or not slot.required.issubset(slot.arrived.keys()):
            return
        if slot.kind == "barrier":
            slot.result = None
        elif slot.kind in ("allreduce", "agree"):
            acc = None
            for r in sorted(slot.arrived.keys() & slot.required):
                v = slot.arrived[r]
                acc = v if acc is None else slot.op(acc, v)
            slot.result = acc
        elif slot.kind == "bcast":
            slot.result = slot.arrived.get(slot.root)
        elif slot.kind in ("scan", "gather"):
            slot.result = None  # computed per-rank at return
        slot.done = True

    def barrier(self, ctx, rank, timeout=None) -> None:
        self._collective(ctx, rank, "barrier", None, timeout=timeout)

    def allreduce(self, ctx, rank, value, op="sum", timeout=None) -> Any:
        return self._collective(ctx, rank, "allreduce", value, op=op, timeout=timeout)

    def scan(self, ctx, rank, value, op="sum", timeout=None) -> Any:
        return self._collective(ctx, rank, "scan", value, op=op, timeout=timeout)

    def bcast(self, ctx, rank, value, root=0, timeout=None) -> Any:
        root_global = ctx.global_rank(root)
        return self._collective(ctx, rank, "bcast", value, root=root_global,
                                timeout=timeout)

    def gather_all(self, ctx, rank, value, timeout=None) -> list:
        """Convenience allgather (used by tests/benchmarks, not the paper protocol)."""
        return self._collective(ctx, rank, "gather", value, timeout=timeout)

    def agree(self, ctx, rank, flag: int, timeout=None) -> int:
        """ULFM ``MPI_Comm_agree``: bitwise AND over surviving ranks; tolerant of
        failures and usable on a revoked communicator."""
        if not self.ulfm:
            raise MpiError(-1, "agree requires ULFM support")
        return self._collective(ctx, rank, "agree", int(flag), op="band",
                                timeout=timeout)

    def shrink(self, ctx: CommContext, rank: int, timeout=None) -> CommContext:
        """ULFM ``MPI_Comm_shrink``: new communicator over surviving members.

        Implemented as agree-on-membership: every survivor observes the same dead set
        (consistent under the global lock), then deterministically derives the new
        context. A per-source-context cache makes all survivors share one new context.
        """
        if not self.ulfm:
            raise MpiError(-1, "shrink requires ULFM support")
        # rendezvous among survivors so the dead-set is agreed upon
        self._collective(ctx, rank, "agree", 1, op="band", timeout=timeout)
        with self._cv:
            survivors = tuple(m for m in ctx.members if m not in self.dead)
            cache_key = ("shrink", ctx.id, survivors)
            slot = self._slots.get(cache_key)
            if slot is None:
                new_ctx = self._new_context(survivors)
                slot = _CollSlot(cache_key, ctx.id, "shrinkctx", None, set())
                slot.result = new_ctx
                slot.done = True
                self._slots[cache_key] = slot
            return slot.result


def _now() -> float:
    import time

    return time.monotonic()


# --------------------------------------------------------------------------- RankCtx
class RankCtx:
    """Per-rank handle: the only API the protocol layers see."""

    def __init__(self, transport: Transport, rank: int):
        self.t = transport
        self.rank = rank

    # communicator management
    @property
    def world(self) -> CommContext:
        return self.t.world

    def dup(self, ctx: CommContext) -> CommContext:
        return self.t.dup(ctx, rank=self.rank)

    def repair(self, members: Sequence[int], key: object) -> CommContext:
        return self.t.repair(members, key)

    def local_rank(self, ctx: CommContext) -> int:
        return ctx.local_rank(self.rank)

    def size(self, ctx: CommContext) -> int:
        return ctx.size

    # point-to-point
    def isend(self, ctx, dst, tag, data) -> Request:
        return self.t.isend(ctx, self.rank, dst, tag, data)

    def issend(self, ctx, dst, tag, data) -> Request:
        return self.t.issend(ctx, self.rank, dst, tag, data)

    def irecv(self, ctx, src, tag) -> Request:
        return self.t.irecv(ctx, self.rank, src, tag)

    def cancel(self, req) -> bool:
        return self.t.cancel(req)

    def test(self, req) -> bool:
        return self.t.test(self.rank, req)

    def wait(self, req, timeout=None) -> Request:
        return self.t.wait(self.rank, req, timeout=timeout)

    def waitany(self, reqs, timeout=None):
        return self.t.waitany(self.rank, reqs, timeout=timeout)

    def waitall(self, reqs, timeout=None):
        return self.t.waitall(self.rank, reqs, timeout=timeout)

    # collectives
    def barrier(self, ctx, timeout=None):
        return self.t.barrier(ctx, self.rank, timeout=timeout)

    def allreduce(self, ctx, value, op="sum", timeout=None):
        return self.t.allreduce(ctx, self.rank, value, op=op, timeout=timeout)

    def scan(self, ctx, value, op="sum", timeout=None):
        return self.t.scan(ctx, self.rank, value, op=op, timeout=timeout)

    def bcast(self, ctx, value, root=0, timeout=None):
        return self.t.bcast(ctx, self.rank, value, root=root, timeout=timeout)

    def gather_all(self, ctx, value, timeout=None):
        return self.t.gather_all(ctx, self.rank, value, timeout=timeout)

    # ULFM surface
    def revoke(self, ctx):
        return self.t.revoke(ctx)

    def agree(self, ctx, flag, timeout=None):
        return self.t.agree(ctx, self.rank, flag, timeout=timeout)

    def shrink(self, ctx, timeout=None):
        return self.t.shrink(ctx, self.rank, timeout=timeout)

    @property
    def ulfm(self) -> bool:
        return self.t.ulfm

    def die(self) -> None:
        """Hard-fault *this* rank from inside (used by fault injection)."""
        self.t.kill(self.rank)
        raise _RankKilled()


# ------------------------------------------------------------------------ run harness
@dataclass
class RankResult:
    rank: int
    value: Any = None
    exception: Optional[BaseException] = None
    killed: bool = False


def run_ranks(nranks: int, fn: Callable[[RankCtx], Any], *, ulfm: bool = False,
              join_timeout: float = 60.0,
              transport: Transport | None = None) -> list[RankResult]:
    """Run ``fn(ctx)`` on ``nranks`` simulated ranks; collect results/exceptions.

    The ``transport`` is exposed to ``fn`` via ``ctx.t`` so tests can inject faults
    (e.g. ``ctx.t.kill(3)``).
    """
    t = transport or Transport(nranks, ulfm=ulfm)
    results = [RankResult(r) for r in range(nranks)]

    def runner(rank: int):
        ctx = RankCtx(t, rank)
        try:
            results[rank].value = fn(ctx)
        except _RankKilled:
            results[rank].killed = True
        except BaseException as e:  # noqa: BLE001 - harness must capture everything
            results[rank].exception = e

    threads = [threading.Thread(target=runner, args=(r,), daemon=True)
               for r in range(nranks)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=join_timeout)
    alive = [i for i, th in enumerate(threads) if th.is_alive()]
    if alive:
        # unstick any thread still blocked (test misuse / genuine deadlock): mark dead
        for r in alive:
            t.kill(r)
        for th in threads:
            th.join(timeout=5.0)
        raise TimeoutError_(f"ranks {alive} did not terminate (deadlock?)")
    return results
