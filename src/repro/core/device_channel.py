"""Device-side error channel — the TPU-native adaptation of the black channel.

XLA SPMD programs cannot take per-rank control-flow decisions at runtime, and a
compiled step cannot throw. The paper's contract — *every misbehaviour becomes an
exception at the wait* — is preserved by inverting the mechanism:

1. every jitted step computes a 32-bit **error word** (the
   :class:`~repro.core.errors.ErrorCode` lattice) from cheap probes over loss /
   grads / states (see ``core/detect.py`` and the ``fault_probe`` Pallas kernel);
2. the word is reduced with ``max``/``or`` *inside* the step. Because probes reduce
   over arrays that are already sharded, XLA folds this into the collectives the step
   performs anyway — the channel costs 4 bytes. This is the in-band analogue of the
   pre-posted ``err_req``: it is always armed, and every rank observes any rank's
   error at the step boundary (one step of latency instead of one ``Waitany``);
3. the host wraps the dispatched outputs in a :class:`DeviceFuture`. ``wait()``
   blocks on the error word *only* (JAX async dispatch keeps the rest in flight) and
   raises the paper's exception taxonomy.

For per-rank attribution the paper's enumeration algorithm (§III-B: scan → index,
bcast → count, allreduce(max) → table) is ported 1:1 to a ``shard_map`` program:
``_scan_sum`` is a log-depth Hillis–Steele inclusive scan over ``ppermute`` (the
ICI-torus-native way to run ``MPI_Scan``), the count uses ``psum`` (numerically
identical to the paper's bcast-of-last-scan-entry, but O(log n) on the torus), and
the table reduction is ``pmax`` — exactly the paper's ``MPI_Allreduce(MPI_MAX)``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .errors import (
    CommCorruptedError,
    ErrorCode,
    PropagatedError,
    RankError,
    TimeoutError_,
    strip_codes,
)

# static capacity of the device-side (rank, code) table; errors beyond this are
# still reported through the combined word, only unattributed.
MAX_ERRORS = 8

WORD_DTYPE = jnp.uint32


def combine_words(*words: jax.Array) -> jax.Array:
    """Bitwise-or fold of error words (associative, commutative, idempotent)."""
    out = jnp.asarray(0, WORD_DTYPE)
    for w in words:
        out = out | w.astype(WORD_DTYPE)
    return out


# --------------------------------------------------------------------- enumeration
def _scan_sum(x: jax.Array, axis_name: str, n: int) -> jax.Array:
    """Inclusive prefix-sum over a mesh axis (paper's ``MPI_Scan(MPI_SUM)``).

    Hillis–Steele over ``ppermute``: ceil(log2 n) collective-permute steps, each
    moving 4 bytes per link — the torus-native scan.
    """
    idx = jax.lax.axis_index(axis_name)
    offset = 1
    while offset < n:
        shifted = jax.lax.ppermute(
            x, axis_name, [(i, i + offset) for i in range(n - offset)])
        x = jnp.where(idx >= offset, x + shifted, x)
        offset *= 2
    return x


def enumerate_errors_ref(words: jax.Array, max_errors: int = MAX_ERRORS):
    """Pure-jnp oracle of the enumeration algorithm (single array of per-rank words).

    Returns ``(count, table)`` with ``table[i] = (rank, code)`` for the i-th failed
    rank in rank order; rows beyond ``count`` are zero.
    """
    words = words.astype(WORD_DTYPE)
    n = words.shape[0]
    failed = (words != 0).astype(jnp.int32)
    idx = jnp.cumsum(failed) - 1                      # index per failed rank
    count = jnp.sum(failed)
    table = jnp.zeros((max_errors, 2), WORD_DTYPE)
    ranks = jnp.arange(n, dtype=WORD_DTYPE)

    def body(i, tab):
        write = (failed[i] == 1) & (idx[i] < max_errors)
        row = jnp.stack([ranks[i], words[i]])
        return jnp.where(write, tab.at[idx[i]].set(row), tab)

    table = jax.lax.fori_loop(0, n, body, table)
    return count, table


def enumeration_shard_body(word: jax.Array, *, axis_name: str, n: int,
                           max_errors: int = MAX_ERRORS):
    """Per-shard body of the paper's enumeration, to be called inside ``shard_map``.

    ``word`` is this shard's scalar error word. Returns replicated
    ``(count, table)`` on every shard.
    """
    word = word.astype(WORD_DTYPE)
    failed = (word != 0).astype(jnp.int32)
    # paper: MPI_Scan(MPI_SUM) assigns every failed rank an index
    incl = _scan_sum(failed, axis_name, n)
    my_idx = incl - 1
    # paper: count via bcast of the last rank's scan value; psum(failed) is the same
    # number and O(log n) on the torus instead of a root broadcast.
    count = jax.lax.psum(failed, axis_name)
    rank = jax.lax.axis_index(axis_name).astype(WORD_DTYPE)
    table = jnp.zeros((max_errors, 2), WORD_DTYPE)
    write = (failed == 1) & (my_idx < max_errors)
    row = jnp.stack([rank, word])
    table = jnp.where(write, table.at[jnp.maximum(my_idx, 0)].set(row), table)
    # paper: MPI_Allreduce(MPI_MAX) over the zero-initialised table
    table = jax.lax.pmax(table, axis_name)
    return count, table


def make_enumerate_fn(mesh: jax.sharding.Mesh, axis_name: str,
                      max_errors: int = MAX_ERRORS):
    """Build a jitted ``words -> (count, table)`` over one mesh axis.

    ``words`` must be a length-``mesh.shape[axis_name]`` vector sharded over
    ``axis_name``.
    """
    from jax.sharding import PartitionSpec as P

    try:                                   # jax >= 0.5
        shard_map = jax.shard_map
    except AttributeError:                 # jax 0.4.x
        from jax.experimental.shard_map import shard_map

    n = mesh.shape[axis_name]

    def body(words):
        count, table = enumeration_shard_body(
            words[0], axis_name=axis_name, n=n, max_errors=max_errors)
        return count[None], table[None]

    mapped = shard_map(body, mesh=mesh, in_specs=P(axis_name),
                       out_specs=(P(axis_name), P(axis_name, None, None)))

    @jax.jit
    def run(words):
        counts, tables = mapped(words)
        return counts[0], tables[0]

    return run


def decode_table(count: int, table: np.ndarray) -> list[RankError]:
    out = []
    for i in range(min(int(count), table.shape[0])):
        out.append(RankError(rank=int(table[i, 0]), code=int(table[i, 1])))
    return out


# -------------------------------------------------------------------- DeviceFuture
@dataclass
class DeviceFuture:
    """Future over a dispatched jitted step (the JAX analogue of paper's ``Future``).

    ``outputs`` stay asynchronous; ``wait`` synchronises on the 4-byte error word
    (plus the optional enumeration table) and converts it to the paper's exceptions.

    **Window semantics** (decode windows, ``launch.steps.make_decode_window``):
    a future may cover K deferred steps at once. ``word`` is then the OR over
    the whole window — checked once per K tokens, not per token — and
    ``history`` holds the ``(K, ranks)`` per-step per-rank word matrix so that
    on a fault :meth:`fault_steps` attributes it to its exact ``(step, rank)``:
    everything before the first faulting step is a clean, committable prefix,
    which is what keeps deterministic greedy replay (LFLR) bit-exact from the
    last committed boundary.
    """

    outputs: Any
    word: jax.Array
    count: Optional[jax.Array] = None
    table: Optional[jax.Array] = None
    history: Optional[jax.Array] = None   # (K, ranks) per-step word history
    _waited: bool = False

    def wait(self, timeout: float | None = None) -> Any:
        if self._waited:
            return self.outputs
        word_arr = self.word
        if timeout is not None:
            deadline = time.monotonic() + timeout
            while not _is_ready(word_arr):
                if time.monotonic() > deadline:
                    raise TimeoutError_(f"device step exceeded {timeout}s "
                                        "(straggler watchdog)")
                time.sleep(0.001)
        word = int(jax.device_get(word_arr))
        self._waited = True
        if word == 0:
            return self.outputs
        code = ErrorCode(word)
        if code & ErrorCode.COMM_CORRUPTED:
            raise CommCorruptedError(self._errors(word))
        raise PropagatedError(self._errors(word) or
                              [RankError(rank=-1, code=word)])

    def result(self, timeout: float | None = None) -> Any:
        return self.wait(timeout=timeout)

    def done(self) -> bool:
        """Non-blocking readiness probe on the error word (the paper's
        ``MPI_Test`` analogue): True iff ``wait()`` would return or raise
        without blocking. Lets a serving loop distinguish a device-bound
        pipeline (the window is still computing at retirement) from a
        host-bound one without perturbing async dispatch."""
        return self._waited or _is_ready(self.word)

    def fault_steps(self, *, ignore: int = 0) -> Optional[np.ndarray]:
        """Per-rank index of the first faulting window step, or -1 if clean.

        Requires window ``history``; returns an ``(ranks,)`` int array. Tokens
        produced by steps ``< fault_steps()[r]`` on rank/slot ``r`` are a valid
        prefix (their words were zero), so the host commits them and replays
        only from the fault boundary. ``ignore`` masks code bits out before
        the scan — the speculative window passes its attribution-only
        ``DRAFT_REJECT`` lane here, so a speculation miss is never mistaken
        for the first *faulting* step and the clean prefix stays as long as
        the real fault allows.
        """
        if self.history is None:
            return None
        hist = np.asarray(jax.device_get(self.history)).astype(np.uint32)
        hist = strip_codes(hist, ignore)
        bad = hist != 0
        return np.where(bad.any(axis=0), bad.argmax(axis=0), -1).astype(np.int64)

    def fault_codes(self, *, ignore: int = 0) -> Optional[np.ndarray]:
        """Per-rank OR of the window history — the combined fault class each
        rank/slot latched, or 0 if clean. Unlike the enumeration table (whose
        capacity is ``max_errors``), this never truncates, so a host that must
        pick a per-slot recovery lane (e.g. the paged-KV replica separating
        ``PAGE_FAULT`` ledger repairs from ``STATE_FAULT`` recomputes) can
        attribute every slot even under a burst of simultaneous faults — and,
        with the default ``ignore=0``, distinguish speculation misses
        (``DRAFT_REJECT``) from real faults in the same readback.
        Requires window ``history``; returns a ``(ranks,)`` uint32 array.
        """
        if self.history is None:
            return None
        hist = np.asarray(jax.device_get(self.history)).astype(np.uint32)
        hist = strip_codes(hist, ignore)
        out = np.zeros(hist.shape[1], np.uint32)
        for row in hist:
            out |= row
        return out

    def _errors(self, word: int) -> list[RankError]:
        if self.count is None or self.table is None:
            return []
        cnt = int(jax.device_get(self.count))
        tab = np.asarray(jax.device_get(self.table))
        errs = decode_table(cnt, tab)
        if not errs and word:
            errs = [RankError(rank=-1, code=word)]
        return errs


def _is_ready(arr: jax.Array) -> bool:
    try:
        return arr.is_ready()  # jax >= 0.4.x on most backends
    except AttributeError:  # pragma: no cover - fallback
        jax.block_until_ready(arr)
        return True
