"""Core: the paper's contribution — exception propagation, asynchrony and fault
handling for distributed (JAX) programs.

Host-level (faithful reproduction): ``Instance``/``Comm``/``Future`` over a
multi-rank transport with Black-Channel (MPI-3.0-only) and ULFM protocol backends.

Device-level (TPU-native adaptation): in-band error word + ``DeviceFuture`` +
``ResilientExecutor`` integrating detection/propagation/recovery into training.
"""
from .blackchannel import ERR_TAG, BlackChannel  # noqa: F401
from .comm import Comm  # noqa: F401
from .detect import ProbeConfig, step_probe  # noqa: F401
from .device_channel import (  # noqa: F401
    MAX_ERRORS,
    DeviceFuture,
    combine_words,
    decode_table,
    enumerate_errors_ref,
    make_enumerate_fn,
)
from .errors import (  # noqa: F401
    CancelledError,
    CommCorruptedError,
    ErrorCode,
    LocalError,
    MpiError,
    PropagatedError,
    RankError,
    RankFailedError,
    ReproError,
    RevokedError,
    TimeoutError_,
    strip_codes,
)
from .faults import FaultSchedule, FaultSpec  # noqa: F401
from .future import Future  # noqa: F401
from .instance import Instance, initialize  # noqa: F401
from .recovery import Action, RecoveryDecision, RecoveryPolicy  # noqa: F401
from .resilient import Event, EventLog, ExecutorConfig, ResilientExecutor  # noqa: F401
from .transport import (  # noqa: F401
    ANY_SOURCE,
    ANY_TAG,
    RankCtx,
    Transport,
    run_ranks,
)
from .ulfm import UlfmChannel  # noqa: F401
