"""ResilientExecutor — the paper's technique integrated into the training loop.

One object owns the full detection → propagation → exception → recovery cycle:

* each step is dispatched asynchronously; its in-band error word is wrapped in a
  :class:`~repro.core.device_channel.DeviceFuture` (the paper's ``Future``);
* ``wait()`` converts faults into ``PropagatedError`` / ``CommCorruptedError``;
* a :class:`~repro.core.recovery.RecoveryPolicy` decides skip / LFLR restore /
  optimizer reset / rollback / shrink; the executor applies it;
* a wall-clock watchdog flags stragglers (EMA-based);
* known-good snapshots (cheap, in-memory) refresh every ``good_state_interval``
  steps; durable checkpoints every ``checkpoint_interval`` steps.

The executor is model-agnostic: it only needs a jitted ``step_fn(state, batch,
inject) -> (new_state, metrics, err_word)`` and an optional ``reset_opt_fn``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp

from .device_channel import DeviceFuture
from .errors import CommCorruptedError, ErrorCode, PropagatedError, ReproError
from .faults import FaultSchedule, apply_host_fault
from .recovery import Action, RecoveryDecision, RecoveryPolicy


@dataclass
class ExecutorConfig:
    good_state_interval: int = 10
    checkpoint_interval: int = 100
    straggler_factor: float = 3.0
    straggler_warmup_steps: int = 5
    step_timeout: Optional[float] = None
    max_consecutive_failures: int = 10


@dataclass
class Event:
    step: int
    kind: str                  # ok|fault|straggler|checkpoint|shrink
    detail: str = ""
    code: int = 0
    action: Optional[str] = None
    duration_s: float = 0.0
    t: float = 0.0             # wall clock (monotonic) the event was recorded
                               # at — spans end here and start duration_s
                               # earlier. 0.0 = legacy unstamped event.


@dataclass
class EventLog:
    events: list[Event] = field(default_factory=list)

    def add(self, ev: Event) -> None:
        self.events.append(ev)

    def faults(self) -> list[Event]:
        return [e for e in self.events if e.kind == "fault"]

    def by_action(self, action: Action) -> list[Event]:
        return [e for e in self.events if e.action == action.value]


def snapshot(state):
    """Defensive device copy (safe against donation of the live state)."""
    return jax.tree_util.tree_map(jnp.copy, state)


class ResilientExecutor:
    def __init__(self, step_fn: Callable, *,
                 policy: RecoveryPolicy | None = None,
                 config: ExecutorConfig | None = None,
                 checkpointer=None,
                 reset_opt_fn: Callable | None = None,
                 on_shrink: Callable | None = None,
                 rank: int = 0):
        self.step_fn = step_fn
        self.policy = policy or RecoveryPolicy()
        self.config = config or ExecutorConfig()
        self.checkpointer = checkpointer
        self.reset_opt_fn = reset_opt_fn
        self.on_shrink = on_shrink
        self.rank = rank
        self.log = EventLog()
        self._ema_step_time: Optional[float] = None

    # ------------------------------------------------------------------ dispatch
    def dispatch(self, state, batch, inject: int = 0) -> DeviceFuture:
        new_state, metrics, word = self.step_fn(state, batch,
                                                jnp.uint32(inject))
        return DeviceFuture(outputs=(new_state, metrics), word=word)

    # ------------------------------------------------------------------ main loop
    def run(self, state, data_iter: Iterator, num_steps: int, *,
            faults: FaultSchedule | None = None, start_step: int = 0):
        faults = faults or FaultSchedule()
        good = snapshot(state)
        good_step = start_step
        consecutive_failures = 0
        step = start_step
        while step < start_step + num_steps:
            batch = next(data_iter)
            inject = faults.inject_word(step, self.rank)

            t0 = time.monotonic()
            # host-level faults (straggle/user) count into the step wall time —
            # a straggling host IS a slow step from the watchdog's perspective
            for spec in faults.at(step, self.rank):
                if spec.kind in ("straggle", "user", "kill"):
                    apply_host_fault(spec)
            fut = self.dispatch(state, batch, inject=inject)
            try:
                (new_state, metrics) = fut.wait(timeout=self.config.step_timeout)
                dt = time.monotonic() - t0
                self._watchdog(step, dt)
                state = new_state
                consecutive_failures = 0
                self.log.add(Event(step, "ok", duration_s=dt,
                                   t=time.monotonic()))
                # refresh known-good snapshot / durable checkpoint
                if (step - good_step) >= self.config.good_state_interval:
                    good, good_step = snapshot(state), step
                if (self.checkpointer is not None
                        and step % self.config.checkpoint_interval == 0
                        and step > start_step):
                    self.checkpointer.save(step, state)
                    self.log.add(Event(step, "checkpoint",
                                       t=time.monotonic()))
            except ReproError as exc:
                dt = time.monotonic() - t0
                consecutive_failures += 1
                if consecutive_failures > self.config.max_consecutive_failures:
                    self.log.add(Event(step, "fault", detail="abort: too many",
                                       action=Action.ABORT.value,
                                       duration_s=dt, t=time.monotonic()))
                    raise
                decision = self.policy.decide(exc, step)
                code = int(getattr(exc, "combined_code", ErrorCode.COMM_CORRUPTED))
                self.log.add(Event(step, "fault", detail=decision.reason,
                                   code=code, action=decision.action.value,
                                   duration_s=dt, t=time.monotonic()))
                state, good, good_step = self._apply(
                    decision, exc, state, good, good_step, step)
            step += 1
        return state, self.log

    # ------------------------------------------------------------------ recovery
    def _apply(self, decision: RecoveryDecision, exc: ReproError, state, good,
               good_step: int, step: int):
        act = decision.action
        if act in (Action.CONTINUE, Action.SKIP_BATCH):
            return state, good, good_step            # discard faulty update
        if act is Action.RESET_OPTIMIZER:
            if self.reset_opt_fn is None:
                return state, good, good_step
            state = self.reset_opt_fn(state, decision.lr_scale)
            return state, good, good_step
        if act is Action.RESTORE_GOOD:
            return snapshot(good), good, good_step   # LFLR: in-memory restore
        if act is Action.ROLLBACK:
            if self.checkpointer is None:
                return snapshot(good), good, good_step
            restored = self.checkpointer.restore_latest(like=state)
            if restored is None:
                return snapshot(good), good, good_step
            ck_step, restored_state = restored
            return restored_state, snapshot(restored_state), ck_step
        if act is Action.SHRINK:
            if self.on_shrink is None:
                raise exc
            state = self.on_shrink(exc, state)
            self.log.add(Event(step, "shrink", detail="elastic re-mesh",
                               t=time.monotonic()))
            return state, snapshot(state), step
        raise exc  # ABORT

    # ------------------------------------------------------------------ watchdog
    def _watchdog(self, step: int, dt: float) -> None:
        cfg = self.config
        if step == 0:
            # step 0 is dominated by jit compile: seeding the EMA with it
            # inflates the threshold by orders of magnitude and masks real
            # stragglers for the first ~10 steps of every run
            return
        if self._ema_step_time is None:
            self._ema_step_time = dt
            return
        warmed = step >= cfg.straggler_warmup_steps
        if warmed and dt > cfg.straggler_factor * self._ema_step_time:
            self.log.add(Event(step, "straggler",
                               detail=f"{dt:.3f}s vs ema {self._ema_step_time:.3f}s",
                               code=int(ErrorCode.STRAGGLER),
                               t=time.monotonic()))
        # EMA update after detection, robust to the straggler itself
        self._ema_step_time = 0.9 * self._ema_step_time + 0.1 * min(
            dt, 4.0 * self._ema_step_time)
