"""Exception hierarchy and error-code lattice.

Faithful port of the paper's exception taxonomy (§III-A):

* ``PropagatedError``   <- ``MPICXX::Propagated_exception``: one or more remote ranks
  signalled a *recoverable* error; carries the full set of ``(rank, code)`` pairs.
* ``CommCorruptedError``<- ``MPICXX::Comm_corrupted_exception``: a communicator was torn
  down during stack unwinding (or a hard fault was detected under ULFM); the
  communicator must not be used again.
* ``MpiError``          <- ``MPICXX::MPI_error_exception``: any transport-level error
  that maps to neither of the above; carries the raw status code.
* ``RevokedError``      <- ULFM ``MPI_ERR_COMM_REVOKED``: raised by any operation on a
  communicator after ``revoke()``.
* ``RankFailedError``   <- ULFM ``MPI_ERR_PROC_FAILED``: a peer involved in this
  operation is dead (hard fault).

Beyond the paper, :class:`ErrorCode` defines a *lattice* of device-representable error
codes (uint32 bitmask) so that the in-band device channel can reduce codes with ``max``
/ ``bitwise-or`` and still recover "what went wrong where" (see
``core/device_channel.py``).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np


class ErrorCode(enum.IntFlag):
    """Bitmask of fault classes. Device-representable (fits uint32).

    The low half encodes *soft* faults (paper §II-A: the rank survives and can still
    communicate); the high half encodes *hard*/structural conditions. Codes combine
    with ``|`` and reduce across ranks with ``max``/``or`` without losing classes.
    """

    OK = 0
    # -- soft faults: numerical ---------------------------------------------------
    NONFINITE_LOSS = 1 << 0        # NaN/Inf in the scalar loss
    NONFINITE_GRAD = 1 << 1        # NaN/Inf anywhere in the gradient pytree
    NONFINITE_PARAM = 1 << 2       # NaN/Inf in parameters (post-update check)
    OVERFLOW = 1 << 3              # |value| above overflow threshold (pre-NaN warning)
    DIVERGENCE = 1 << 4            # loss above divergence threshold / rising window
    # -- soft faults: data / algorithm -------------------------------------------
    DATA_FAULT = 1 << 5            # pipeline produced out-of-range / corrupt batch
    ROUTER_OVERFLOW = 1 << 6       # MoE: token dropped-fraction above threshold
    STATE_FAULT = 1 << 7           # SSM / RG-LRU recurrent state non-finite
    USER = 1 << 8                  # user-signalled (paper: user-defined exception)
    # -- structural / runtime -----------------------------------------------------
    STRAGGLER = 1 << 16            # step-time watchdog tripped on this rank
    CHECKPOINT_IO = 1 << 17        # async checkpoint write failed
    PAGE_FAULT = 1 << 18           # paged KV: write landed on an unmapped page
                                   # (ownership-ledger / page-table corruption)
    # -- attribution-only lanes (never trigger recovery) --------------------------
    DRAFT_REJECT = 1 << 19         # speculative decode: a drafted token was
                                   # rejected by the full-model verify this
                                   # window step — expected behaviour recorded
                                   # in-band for exact (step, slot) attribution
                                   # of speculation misses; masked out of the
                                   # fault-raising word at the wait
    # -- hard faults (ULFM territory) ---------------------------------------------
    RANK_FAILED = 1 << 24          # peer process/node lost
    COMM_CORRUPTED = 1 << 25       # communicator destroyed during unwinding

    @property
    def is_hard(self) -> bool:
        return bool(self & (ErrorCode.RANK_FAILED | ErrorCode.COMM_CORRUPTED))

    @property
    def is_soft(self) -> bool:
        return bool(self) and not self.is_hard

    def classes(self) -> list["ErrorCode"]:
        """Decompose a combined code into its constituent single-bit classes."""
        return [c for c in ErrorCode if c != ErrorCode.OK and c & self and c.value & (c.value - 1) == 0]


# Encoded "no error" word for device-side channels.
OK_WORD = 0

# Codes that attribute expected in-band events (speculation misses) rather than
# faults: carried in the per-(step, slot) word history for exact attribution,
# but masked out of the combined word before the wait converts it to an
# exception — they must never trigger recovery.
ATTRIBUTION_ONLY = ErrorCode.DRAFT_REJECT


@dataclass(frozen=True)
class RankError:
    """One signalled error: which rank, which code (paper: rank number + error code)."""

    rank: int
    code: int

    @property
    def error_code(self) -> ErrorCode:
        return ErrorCode(self.code)

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return f"rank {self.rank}: {ErrorCode(self.code)!r}"


class ReproError(Exception):
    """Base class for all errors raised by this framework."""


class LocalError(ReproError):
    """A purely local failure detected before any propagation happened.

    Carries the code so the catch-site can decide to ``signal_error`` it (the paper's
    Listing 1 inner try/catch).
    """

    def __init__(self, code: int | ErrorCode, msg: str = ""):
        self.code = int(code)
        super().__init__(msg or f"local error: {ErrorCode(self.code)!r}")


class PropagatedError(ReproError):
    """Remote rank(s) signalled an error (paper: ``Propagated_exception``).

    Contains *all* ``(rank, code)`` pairs, as produced by the enumeration algorithm
    (§III-B "Determine failed ranks and codes"). Recoverable: the communicator stays
    valid; no revoke/shrink required.
    """

    def __init__(self, errors: Iterable[RankError]):
        self.errors: tuple[RankError, ...] = tuple(errors)
        super().__init__(
            "propagated error(s): " + "; ".join(str(e) for e in self.errors)
        )

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(e.rank for e in self.errors)

    @property
    def combined_code(self) -> ErrorCode:
        out = 0
        for e in self.errors:
            out |= e.code
        return ErrorCode(out)


class CommCorruptedError(ReproError):
    """The communicator is unusable (paper: ``Comm_corrupted_exception``).

    Raised when (a) a ``Comm`` was destroyed during stack unwinding on some rank, or
    (b) a hard fault was detected (ULFM path). Must be caught *outside* the scope of
    the ``Comm`` object; recovery requires rebuilding the communicator (shrink or
    re-spawn) and typically a rollback or LFLR restore.
    """

    def __init__(self, errors: Iterable[RankError] = (), msg: str = ""):
        self.errors: tuple[RankError, ...] = tuple(errors)
        super().__init__(msg or ("communicator corrupted: " + "; ".join(str(e) for e in self.errors) if self.errors else "communicator corrupted"))


class RevokedError(ReproError):
    """Operation on a revoked communicator (ULFM ``MPI_ERR_COMM_REVOKED``)."""

    def __init__(self, msg: str = "communicator revoked"):
        super().__init__(msg)


class RankFailedError(ReproError):
    """A peer involved in this operation is dead (ULFM ``MPI_ERR_PROC_FAILED``)."""

    def __init__(self, failed_ranks: Sequence[int] = (), msg: str = ""):
        self.failed_ranks = tuple(failed_ranks)
        super().__init__(msg or f"rank(s) failed: {list(self.failed_ranks)}")


class MpiError(ReproError):
    """Any other transport error (paper: ``MPI_error_exception``)."""

    def __init__(self, status: int, msg: str = ""):
        self.status = status
        super().__init__(msg or f"transport error, status={status}")


class CancelledError(ReproError):
    """A request was cancelled (``MPI_Cancel`` analogue)."""


class TimeoutError_(ReproError):
    """A wait exceeded its deadline (used by the straggler watchdog)."""


def combine_codes(codes: Iterable[int]) -> int:
    out = 0
    for c in codes:
        out |= int(c)
    return out


def strip_codes(words, ignore: int = 0):
    """Mask ``ignore`` code bits out of an error word / word array.

    The single source of truth for every ``ignore=`` lane in the system:
    :meth:`DeviceFuture.fault_steps`/:meth:`~DeviceFuture.fault_codes` (host
    numpy), the serve replica's window enumeration (jitted), and the
    tensor-parallel cross-shard OR-fold all strip attribution-only bits
    (``DRAFT_REJECT``) through this one helper, so "which codes count as a
    fault" cannot silently diverge between the detection paths. Works on
    python ints, numpy arrays and traced jax arrays alike (``ignore`` is a
    static python int; the mask is a numpy uint32 scalar, which both numpy
    and jax promote without a copy).
    """
    if not ignore:
        return words
    keep = np.uint32(~np.uint32(ignore & 0xFFFFFFFF))
    if isinstance(words, int):
        return words & int(keep)
    return words & keep
