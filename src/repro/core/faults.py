"""Deterministic fault injection (testing + benchmarks).

Covers the paper's fault taxonomy (§II-A): soft faults that leave the rank able to
communicate (bit-flips → NaN/overflow, data corruption, divergence, user errors) and
hard faults (rank/node loss), plus stragglers (the runtime condition the paper's
asynchrony is designed around).

Two injection surfaces:

* **inside-step** (device): jitted steps accept an ``inject`` uint32 word; the
  helpers below turn the relevant bits into NaN'd losses / corrupted grads *inside*
  the compiled program, so detection is exercised on the real path.
* **host-level** (simulated cluster): kill a rank thread, delay a rank (straggler),
  corrupt a host batch.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

from .errors import ErrorCode

# injection bits (distinct from ErrorCode — these say what to *break*, the probes
# decide what they *see*)
INJ_NAN_LOSS = 1 << 0
INJ_NAN_GRAD = 1 << 1
INJ_SPIKE_LOSS = 1 << 2
INJ_BAD_DATA = 1 << 3
INJ_STATE_NAN = 1 << 4


@dataclass(frozen=True)
class FaultSpec:
    step: int
    kind: str          # nan_loss|nan_grad|spike_loss|bad_data|state_nan|kill|straggle|user
    rank: int = 0
    magnitude: float = 1.0   # straggle: seconds; spike: factor

    @property
    def inject_bit(self) -> int:
        return {
            "nan_loss": INJ_NAN_LOSS,
            "nan_grad": INJ_NAN_GRAD,
            "spike_loss": INJ_SPIKE_LOSS,
            "bad_data": INJ_BAD_DATA,
            "state_nan": INJ_STATE_NAN,
        }.get(self.kind, 0)


@dataclass
class FaultSchedule:
    specs: Sequence[FaultSpec] = ()

    def at(self, step: int, rank: int | None = None) -> list[FaultSpec]:
        return [s for s in self.specs
                if s.step == step and (rank is None or s.rank == rank)]

    def inject_word(self, step: int, rank: int | None = None) -> int:
        word = 0
        for s in self.at(step, rank):
            word |= s.inject_bit
        return word

    def device_faults(self) -> list[FaultSpec]:
        return [s for s in self.specs if s.inject_bit]

    def host_faults(self) -> list[FaultSpec]:
        return [s for s in self.specs if not s.inject_bit]


# ------------------------------------------------------------------ device helpers
def inject_loss(loss: jax.Array, inject: jax.Array) -> jax.Array:
    """Apply loss-level injections inside a jitted step."""
    inject = inject.astype(jnp.uint32)
    loss = jnp.where((inject & INJ_NAN_LOSS) != 0, jnp.float32(jnp.nan), loss)
    loss = jnp.where((inject & INJ_SPIKE_LOSS) != 0, loss * 1e6, loss)
    return loss


def inject_grads(grads, inject: jax.Array):
    """NaN the first element of every gradient leaf when INJ_NAN_GRAD is set."""
    inject = inject.astype(jnp.uint32)
    on = (inject & INJ_NAN_GRAD) != 0

    def poison(g):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g
        flat = g.reshape(-1)
        flat = flat.at[0].set(jnp.where(on, jnp.asarray(jnp.nan, g.dtype), flat[0]))
        return flat.reshape(g.shape)

    return jax.tree_util.tree_map(poison, grads)


def inject_batch(tokens: jax.Array, inject: jax.Array) -> jax.Array:
    """Make token ids invalid when INJ_BAD_DATA is set (tripped by data_probe)."""
    inject = inject.astype(jnp.uint32)
    on = (inject & INJ_BAD_DATA) != 0
    first = jnp.where(on, jnp.asarray(-1, tokens.dtype),
                      tokens.reshape(-1)[0])
    return tokens.reshape(-1).at[0].set(first).reshape(tokens.shape)


def inject_state(state, inject: jax.Array):
    inject = inject.astype(jnp.uint32)
    on = (inject & INJ_STATE_NAN) != 0

    def poison(s):
        if not jnp.issubdtype(s.dtype, jnp.floating):
            return s
        flat = s.reshape(-1)
        flat = flat.at[0].set(jnp.where(on, jnp.asarray(jnp.nan, s.dtype), flat[0]))
        return flat.reshape(s.shape)

    return jax.tree_util.tree_map(poison, state)


# -------------------------------------------------------------------- host helpers
def apply_host_fault(spec: FaultSpec, ctx=None) -> Optional[ErrorCode]:
    """Execute a host-level fault on the simulated cluster. Returns the error code a
    detector would raise locally, or None for silent faults (kill)."""
    if spec.kind == "kill":
        if ctx is not None:
            ctx.die()  # unwinds the rank thread (hard fault)
        return None
    if spec.kind == "straggle":
        time.sleep(spec.magnitude)
        return ErrorCode.STRAGGLER
    if spec.kind == "user":
        return ErrorCode.USER
    return None
