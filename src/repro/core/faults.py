"""Deterministic fault injection (testing + benchmarks).

Covers the paper's fault taxonomy (§II-A): soft faults that leave the rank able to
communicate (bit-flips → NaN/overflow, data corruption, divergence, user errors) and
hard faults (rank/node loss), plus stragglers (the runtime condition the paper's
asynchrony is designed around).

Two injection surfaces:

* **inside-step** (device): jitted steps accept an ``inject`` uint32 word; the
  helpers below turn the relevant bits into NaN'd losses / corrupted grads *inside*
  the compiled program, so detection is exercised on the real path.
* **host-level** (simulated cluster): kill a rank thread, delay a rank (straggler),
  corrupt a host batch.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .errors import ATTRIBUTION_ONLY, ErrorCode

# injection bits (distinct from ErrorCode — these say what to *break*, the probes
# decide what they *see*)
INJ_NAN_LOSS = 1 << 0
INJ_NAN_GRAD = 1 << 1
INJ_SPIKE_LOSS = 1 << 2
INJ_BAD_DATA = 1 << 3
INJ_STATE_NAN = 1 << 4

_INJ_BITS = {
    "nan_loss": INJ_NAN_LOSS,
    "nan_grad": INJ_NAN_GRAD,
    "spike_loss": INJ_SPIKE_LOSS,
    "bad_data": INJ_BAD_DATA,
    "state_nan": INJ_STATE_NAN,
}
# host-level faults executed on the simulated cluster (not via inject words).
# "shard_kill" is the tensor-parallel hard fault: one shard of a replica's
# model mesh dies, which takes the whole owning replica down (a TP replica is
# one SPMD program — losing a shard is losing the rank) and rides the exact
# RANK_FAILED → epoch-shrink → re-route path a full replica kill takes.
# "host_kill"/"host_stop" are the *process*-level hard faults: SIGKILL (a
# genuinely lost OS process) and SIGSTOP (slow-but-alive) of a multihost
# worker — executed only by the MultiHostSupervisor, which owns the victim
# Popen handles; apply_host_fault has no process to signal and rejects them.
_HOST_KINDS = frozenset({"kill", "shard_kill", "straggle", "user",
                         "host_kill", "host_stop"})
# every legal FaultSpec.kind: the device-word kinds, the host kinds, and
# "code" (inject a raw ErrorCode word in-band — the fuzzer's device-fault-word
# mutation surface, validated by validate_injectable_code)
KNOWN_KINDS = frozenset(_INJ_BITS) | _HOST_KINDS | {"code"}

# ErrorCode bits that may legally be *injected* as faults: every defined soft /
# structural class except the attribution-only lanes (DRAFT_REJECT records an
# expected event, injecting it as a fault would make a reject-only window
# raise — exactly the contract violation the wait-side masking exists to
# prevent) and the hard-fault bits (hard faults are injected as rank kills,
# never as in-band words: a word cannot take a rank down).
_DEFINED_MASK = 0
for _c in ErrorCode:
    _DEFINED_MASK |= _c.value
_HARD_MASK = int(ErrorCode.RANK_FAILED | ErrorCode.COMM_CORRUPTED)
INJECTABLE_CODE_MASK = _DEFINED_MASK & ~int(ATTRIBUTION_ONLY) & ~_HARD_MASK


def validate_injectable_code(code: int | ErrorCode) -> int:
    """Check that ``code`` is a nonzero OR of injectable soft/structural
    :class:`ErrorCode` bits; returns the validated int word.

    Raises ``ValueError`` for the empty word, undefined bits, attribution-only
    lanes (``DRAFT_REJECT``) and hard-fault bits — silently passing any of
    those through would let a fuzzer (or a typo) schedule a "fault" the
    recovery contract explicitly says must never raise."""
    word = int(code)
    if word == 0:
        raise ValueError("cannot inject ErrorCode.OK (empty fault word)")
    bad = word & ~INJECTABLE_CODE_MASK
    if bad:
        names = [c.name for c in ErrorCode
                 if c.value & bad and c.value & (c.value - 1) == 0
                 and c != ErrorCode.OK]
        raise ValueError(
            f"code {word:#x} is not injectable: offending bits "
            f"{names or [hex(bad)]} (attribution-only lanes like DRAFT_REJECT "
            "and hard-fault bits cannot be injected as device fault words)")
    return word


@dataclass(frozen=True)
class FaultSpec:
    step: int
    kind: str          # nan_loss|nan_grad|spike_loss|bad_data|state_nan|code|kill|shard_kill|straggle|user
    rank: Optional[int] = 0  # None = "a seeded-random alive rank" — resolved
                             # to a concrete rank by FaultSchedule.resolve()
    magnitude: float = 1.0   # straggle: seconds; spike: factor
    code: int = 0            # kind="code": the ErrorCode word to latch in-band
    shard: int = 0           # kind="shard_kill": which model-mesh shard dies

    @property
    def inject_bit(self) -> int:
        return _INJ_BITS.get(self.kind, 0)


@dataclass
class FaultSchedule:
    """A deterministic, fully seedable fault plan.

    ``seed`` drives every random choice the schedule (or a consumer holding
    it) makes: :meth:`resolve` materialises ``rank=None`` wildcard specs into
    concrete ranks, and :meth:`rng_for` derives a per-(rank, step) generator
    for consumer-side choices (e.g. which active slot a ``state_nan``
    injection poisons) — so any trajectory built on a schedule replays
    bit-for-bit from ``(specs, seed)`` alone.
    """

    specs: Sequence[FaultSpec] = ()
    seed: int = 0

    def at(self, step: int, rank: int | None = None) -> list[FaultSpec]:
        return [s for s in self.specs
                if s.step == step and (rank is None or s.rank == rank)]

    def inject_word(self, step: int, rank: int | None = None) -> int:
        """OR of the INJ_* device-injection bits scheduled for (step, rank).

        Unknown kinds are rejected loudly: a spec whose kind matches no
        injection surface would otherwise be dropped on the floor and the
        test that scheduled it would silently assert nothing."""
        word = 0
        for s in self.at(step, rank):
            if s.kind not in KNOWN_KINDS:
                raise ValueError(
                    f"unknown fault kind {s.kind!r} (known: "
                    f"{sorted(KNOWN_KINDS)})")
            if s.kind == "code":
                # validated here so a bad spec fails at schedule time even if
                # the consumer only reads the INJ word; the code itself is
                # delivered via code_word()
                validate_injectable_code(s.code)
            word |= s.inject_bit
        return word

    def code_word(self, step: int, rank: int | None = None) -> int:
        """OR of the validated in-band ErrorCode words scheduled for
        (step, rank) via ``kind="code"`` specs."""
        word = 0
        for s in self.at(step, rank):
            if s.kind == "code":
                word |= validate_injectable_code(s.code)
        return word

    def device_faults(self) -> list[FaultSpec]:
        return [s for s in self.specs
                if s.inject_bit or s.kind == "code"]

    def host_faults(self) -> list[FaultSpec]:
        return [s for s in self.specs if s.kind in _HOST_KINDS]

    # ------------------------------------------------------------ determinism
    def rng_for(self, rank: int, step: int) -> np.random.Generator:
        """Per-(rank, step) generator derived from the schedule seed — the
        consumer-side randomness hook (slot picks, victim picks) that keeps
        every injection replayable from the seed alone."""
        return np.random.default_rng((int(self.seed), int(rank), int(step)))

    def resolve(self, ranks: Sequence[int]) -> "FaultSchedule":
        """Materialise ``rank=None`` wildcard specs into concrete members of
        ``ranks``, chosen by the schedule's seeded rng. Deterministic and
        idempotent for already-concrete schedules; each wildcard gets an
        independent draw keyed by its spec index."""
        ranks = sorted(int(r) for r in ranks)
        if not ranks:
            raise ValueError("cannot resolve a schedule over zero ranks")
        out = []
        for i, s in enumerate(self.specs):
            if s.rank is None:
                rng = np.random.default_rng((int(self.seed), 0xFA017, i))
                s = dataclasses.replace(s, rank=int(rng.choice(ranks)))
            out.append(s)
        return FaultSchedule(tuple(out), seed=self.seed)


# ------------------------------------------------------------------ device helpers
def inject_loss(loss: jax.Array, inject: jax.Array) -> jax.Array:
    """Apply loss-level injections inside a jitted step."""
    inject = inject.astype(jnp.uint32)
    loss = jnp.where((inject & INJ_NAN_LOSS) != 0, jnp.float32(jnp.nan), loss)
    loss = jnp.where((inject & INJ_SPIKE_LOSS) != 0, loss * 1e6, loss)
    return loss


def inject_grads(grads, inject: jax.Array):
    """NaN the first element of every gradient leaf when INJ_NAN_GRAD is set."""
    inject = inject.astype(jnp.uint32)
    on = (inject & INJ_NAN_GRAD) != 0

    def poison(g):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g
        flat = g.reshape(-1)
        flat = flat.at[0].set(jnp.where(on, jnp.asarray(jnp.nan, g.dtype), flat[0]))
        return flat.reshape(g.shape)

    return jax.tree_util.tree_map(poison, grads)


def inject_batch(tokens: jax.Array, inject: jax.Array) -> jax.Array:
    """Make token ids invalid when INJ_BAD_DATA is set (tripped by data_probe)."""
    inject = inject.astype(jnp.uint32)
    on = (inject & INJ_BAD_DATA) != 0
    first = jnp.where(on, jnp.asarray(-1, tokens.dtype),
                      tokens.reshape(-1)[0])
    return tokens.reshape(-1).at[0].set(first).reshape(tokens.shape)


def inject_state(state, inject: jax.Array):
    inject = inject.astype(jnp.uint32)
    on = (inject & INJ_STATE_NAN) != 0

    def poison(s):
        if not jnp.issubdtype(s.dtype, jnp.floating):
            return s
        flat = s.reshape(-1)
        flat = flat.at[0].set(jnp.where(on, jnp.asarray(jnp.nan, s.dtype), flat[0]))
        return flat.reshape(s.shape)

    return jax.tree_util.tree_map(poison, state)


# -------------------------------------------------------------------- host helpers
def apply_host_fault(spec: FaultSpec, ctx=None) -> Optional[ErrorCode]:
    """Execute a host-level fault on the simulated cluster. Returns the error
    code a detector would raise locally, or None for silent faults (kill).

    Only host kinds are accepted: handing a device-injection spec (or an
    unknown kind) here is a scheduling bug, and silently returning None would
    make the caller believe the fault fired."""
    if spec.kind in ("kill", "shard_kill"):
        # shard_kill: a TP shard loss is a hard fault of the owning replica —
        # one SPMD program, so the whole rank thread unwinds
        if ctx is not None:
            ctx.die()  # unwinds the rank thread (hard fault)
        return None
    if spec.kind == "straggle":
        time.sleep(spec.magnitude)
        return ErrorCode.STRAGGLER
    if spec.kind == "user":
        return ErrorCode.USER
    if spec.kind in ("host_kill", "host_stop"):
        raise ValueError(
            f"apply_host_fault: {spec.kind!r} targets a real OS process and "
            "is executed by the multihost supervisor (it owns the worker "
            "Popen handles) — the thread-rank cluster has nothing to signal")
    raise ValueError(
        f"apply_host_fault: {spec.kind!r} is not a host fault kind "
        f"(host kinds: {sorted(_HOST_KINDS)}; device kinds are injected "
        "in-band via inject_word/code_word)")
