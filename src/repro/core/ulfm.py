"""ULFM-backed protocol — faithful implementation of paper §III-C.

When the transport advertises ULFM support, no black channel is needed: hard-failure
detection and revocation are provided by the runtime. The protocol becomes:

* ``wait`` is a plain ``MPI_Wait`` that inspects the completion status;
* ``signal_error`` calls ``MPI_Comm_revoke`` — every pending or future operation on
  the communicator fails with ``MPI_ERR_COMM_REVOKED`` on all ranks;
* all ranks then ``MPI_Comm_agree`` on an integer flag (bitwise AND): ranks that
  observed a hard failure (``MPI_ERR_PROC_FAILED``) or are unwinding (corrupted)
  contribute 0; a clean ``signal_error`` contributes 1;
* if the AND is 0 the communicator is corrupted → ``CommCorruptedError``; otherwise
  ``MPI_Comm_shrink`` yields a working communicator (same membership when no rank
  died) and the *same enumeration algorithm as the black channel* runs on it.

This covers hard faults (node loss) that the black channel cannot observe — the
paper's motivation for the dedicated ULFM code path.
"""
from __future__ import annotations

from typing import Optional

from .errors import (
    CommCorruptedError,
    ErrorCode,
    MpiError,
    PropagatedError,
    RankError,
    RankFailedError,
    RevokedError,
)
from .transport import CommContext, RankCtx, ReqState


class UlfmChannel:
    """Per-rank ULFM protocol state for one communicator."""

    def __init__(self, ctx: RankCtx, base: CommContext,
                 default_timeout: float | None = None):
        if not ctx.ulfm:
            raise MpiError(-1, "UlfmChannel requires a ULFM-capable transport")
        self.ctx = ctx
        self.comm = base
        self.alive = True
        self.default_timeout = default_timeout

    @property
    def rank(self) -> int:
        return self.comm.local_rank(self.ctx.rank)

    @property
    def size(self) -> int:
        return self.comm.size

    def _t(self, timeout):
        return timeout if timeout is not None else self.default_timeout

    def track(self, req) -> "Request":
        """ULFM needs no drain bookkeeping: revoke fails every pending request on
        the communicator at the transport level."""
        return req

    def post(self, fn):
        """Issue an operation; a post-time ULFM error (revoked comm / dead peer)
        routes into the agreement phase exactly like a wait-time error — the paper's
        contract is that *any* MPI call site may throw the unified exceptions."""
        if not self.alive:
            raise CommCorruptedError(msg="operation on corrupted communicator")
        try:
            return fn(self.comm)
        except RevokedError:
            self._post_revoke(flag=1, am_signaller=False, my_code=0,
                              timeout=self.default_timeout)
            raise AssertionError("unreachable")  # pragma: no cover
        except RankFailedError:
            self.ctx.revoke(self.comm)
            self._post_revoke(flag=0, am_signaller=True,
                              my_code=int(ErrorCode.RANK_FAILED),
                              timeout=self.default_timeout)
            raise AssertionError("unreachable")  # pragma: no cover

    # ------------------------------------------------------------------- waiting
    def wait(self, request, timeout: float | None = None) -> None:
        """Paper: 'If ULFM is available, the wait method of the Future invokes an
        MPI_Wait, instead of the MPI_Waitany, and checks the return code.'"""
        if not self.alive:
            raise CommCorruptedError(msg="wait on corrupted communicator")
        timeout = self._t(timeout)
        r = self.ctx.wait(request, timeout=timeout)
        if r.state is not ReqState.FAILED:
            return
        err = r.error
        if isinstance(err, RevokedError):
            # someone revoked: join the agreement phase as an innocent party
            self._post_revoke(flag=1, am_signaller=False, my_code=0, timeout=timeout)
        elif isinstance(err, RankFailedError):
            # hard failure observed locally: revoke and vote 'corrupted'
            self.ctx.revoke(self.comm)
            self._post_revoke(flag=0, am_signaller=True,
                              my_code=int(ErrorCode.RANK_FAILED), timeout=timeout)
        else:
            raise MpiError(-1, f"request failed: {err}") from err

    # ---------------------------------------------------------------- signalling
    def signal_error(self, code: int | ErrorCode, *, corrupted: bool = False,
                     timeout: float | None = None, reraise: bool = True) -> None:
        """Paper: 'There are three cases in which the communicator is revoked. The
        first case is the call of the method signal_error.'"""
        if not self.alive:
            raise CommCorruptedError(msg="signal_error on corrupted communicator")
        self.ctx.revoke(self.comm)
        self._post_revoke(flag=0 if corrupted else 1, am_signaller=True,
                          my_code=int(code), timeout=self._t(timeout),
                          reraise=reraise)

    # ------------------------------------------------------------- post-revoke
    def _post_revoke(self, flag: int, am_signaller: bool, my_code: int,
                     timeout: float | None, reraise: bool = True) -> None:
        ctx = self.ctx
        # "the function MPI_Comm_agree is used to determine whether the communicator
        # is corrupted or an error code is signaled"
        ok = ctx.agree(self.comm, flag, timeout=timeout)
        if ok == 0:
            self.alive = False
            # a hard failure or unwinding destructor: communicator unusable
            exc: Exception = CommCorruptedError()
            if reraise:
                raise exc
            return
        # "otherwise MPI_Comm_shrink is called to obtain a valid communicator"
        new_comm = ctx.shrink(self.comm, timeout=timeout)
        old = self.comm
        self.comm = new_comm  # the Comm facade now operates on the shrunk context
        # "Then we proceed with the same algorithm like in the Black-Channel case to
        # propagate the rank numbers and error codes of the failed ranks."
        errors = self._enumerate_failed(new_comm, am_signaller, my_code,
                                        old, timeout)
        if reraise:
            raise PropagatedError(errors)

    def _enumerate_failed(self, comm: CommContext, am_signaller: bool, my_code: int,
                          old_comm: CommContext, timeout: float | None) -> list[RankError]:
        ctx = self.ctx
        my_rank, size = comm.local_rank(ctx.rank), comm.size
        flag = 1 if am_signaller else 0
        idx = ctx.scan(comm, flag, op="sum", timeout=timeout)
        count = ctx.bcast(comm, idx if my_rank == size - 1 else None,
                          root=size - 1, timeout=timeout)
        table = [0] * (2 * count)
        if am_signaller:
            k = idx - 1
            # report ranks in the *old* communicator's numbering so that the
            # application can identify which shard of work was affected
            table[2 * k] = old_comm.local_rank(ctx.rank)
            table[2 * k + 1] = my_code
        table = ctx.allreduce(comm, table, op="emax", timeout=timeout)
        return [RankError(rank=table[2 * i], code=table[2 * i + 1])
                for i in range(count)]

    # ------------------------------------------------------------------ teardown
    def corrupted_teardown(self, timeout: float | None = None) -> None:
        """Destructor-during-unwinding: revoke + vote 0 (paper: 'the other cases are
        when the communicator object is deconstructed during stack unwinding...')."""
        if not self.alive:
            return
        try:
            self.signal_error(ErrorCode.COMM_CORRUPTED, corrupted=True,
                              timeout=self._t(timeout), reraise=False)
        finally:
            self.alive = False

    def shrink_to_survivors(self, timeout: float | None = None) -> CommContext:
        """Recovery aid after ``CommCorruptedError``: agree + shrink among survivors.

        This is the paper's use-case 1 (LFLR): 'clear the broken communicator and
        create a new one with a reduced number of processors'.
        """
        new_comm = self.ctx.shrink(self.comm, timeout=self._t(timeout))
        self.comm = new_comm
        self.alive = True
        return new_comm

    def close(self) -> None:
        self.alive = False
