from .rules import (  # noqa: F401
    batch_shardings,
    batch_spec,
    cache_shardings,
    cache_specs,
    moment_shardings,
    moment_specs,
    param_shardings,
    param_specs,
)
