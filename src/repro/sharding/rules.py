"""Logical sharding rules: param-path patterns → PartitionSpec.

DP over ("pod", "data") for batch; TP/EP over "model" for weights:

* embeddings/logits: vocab over "model";
* attention: fused head×head_dim output dims over "model" (works for every GQA
  config assigned: kv_heads·head_dim is a multiple of 16 in all ten archs);
* MLP: d_ff over "model";
* MoE: experts over "model" (EP); router replicated (tiny, avoids a top-k gather);
* Mamba-2: heads (d_inner) over "model"; B/C group projections + depthwise conv
  replicated (G=1 is not shardable; they are <0.3% of layer bytes);
* RG-LRU: recurrence-branch weights replicated (10 gate blocks don't divide the
  16-way model axis; the branch is ~15% of layer FLOPs — revisit in §Perf);
* norms/scalars: replicated.

ZeRO-1: optimizer moments take the param spec with the first still-replicated,
divisible dim additionally sharded over "data".
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# (path regex, spec template). "M" → model axis, None → replicated.
# Paths look like: stack/periods/b0/attn/wq  (leading stack dim of period-stacked
# params adds one dimension at the FRONT: specs below are for the *layer* dims and
# get a None prepended automatically for stacked leaves.)
RULES: list[tuple[str, tuple]] = [
    (r"embed/embedding$", ("M", None)),
    (r"unembed/kernel$", (None, "M")),
    # 3D projections (d, heads, head_dim): heads over model. The < model_size
    # guard auto-replicates wk/wv when kv_heads < model axis (GQA standard).
    (r"attn/wq$", (None, "M", None)),
    (r"attn/wk$", (None, "M", None)),
    (r"attn/wv$", (None, "M", None)),
    (r"attn/wo$", ("M", None, None)),
    (r"attn/(q_norm|k_norm)$", (None,)),
    (r"mlp/w[ig]$", (None, "M")),
    (r"mlp/wo$", ("M", None)),
    (r"moe/router$", (None, None)),
    (r"moe/w[ig]$", ("M", None, None)),
    (r"moe/wo$", ("M", None, None)),
    (r"ssd/in_[xz]$", (None, "M")),
    (r"ssd/in_[BC]$", (None, None)),
    (r"ssd/in_dt$", (None, "M")),
    (r"ssd/conv_w$", (None, None)),
    (r"ssd/(dt_bias|A_log|D)$", ("M",)),
    (r"ssd/norm_scale$", ("M",)),
    (r"ssd/out$", ("M", None)),
    (r"rglru/", None),               # None template → fully replicated leaf
    (r"norm[12]?/", None),
    (r"final_norm/", None),
    (r"gate_(attn|mlp)$", None),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:  # pragma: no cover
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path_s: str, ndim: int, shape, model_axis, model_size: int):
    for pat, template in RULES:
        if re.search(pat, path_s):
            if template is None:
                return P()
            spec = [model_axis if t == "M" else None for t in template]
            # period-stacked leaves carry a leading num_periods dim
            while len(spec) < ndim:
                spec.insert(0, None)
            # pjit *argument* shardings must divide evenly (intermediates may
            # pad, arguments may not). If the intended dim doesn't divide,
            # fall back to the next divisible dim — e.g. starcoder2's 24 heads
            # on a 16-way model axis shard head_dim (128) instead: the einsum
            # contraction pattern (partial products + psum) is identical.
            for i, ax in enumerate(spec):
                if ax is None or shape[i] % model_size == 0:
                    continue
                spec[i] = None
                order = list(range(i + 1, ndim)) + list(range(0, i))
                for j in order:
                    if (spec[j] is None and shape[j] % model_size == 0
                            and shape[j] >= model_size
                            and not (ndim > len(template) and j == 0)):
                        spec[j] = model_axis
                        break
            return P(*spec)
    return P()  # default: replicated


def param_specs(params_or_shapes, mesh: Mesh):
    """Pytree of PartitionSpec for a param tree (arrays or ShapeDtypeStructs)."""
    model_axis = "model"
    model_size = mesh.shape[model_axis]

    def leaf_spec(path, leaf):
        return _spec_for(_path_str(path), len(leaf.shape), leaf.shape,
                         model_axis, model_size)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_or_shapes)


def param_shardings(params_or_shapes, mesh: Mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  param_specs(params_or_shapes, mesh))


def moment_specs(params_or_shapes, mesh: Mesh):
    """ZeRO-1: param spec + first replicated divisible dim sharded over "data"."""
    data_size = mesh.shape["data"]
    specs = param_specs(params_or_shapes, mesh)

    def zero1(spec: P, leaf):
        shape = leaf.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, ax in enumerate(parts):
            if ax is None and shape[i] % data_size == 0 and shape[i] >= data_size:
                parts[i] = "data"
                break
        return P(*parts)

    return jax.tree_util.tree_map(zero1, specs, params_or_shapes)


def moment_shardings(params_or_shapes, mesh: Mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  moment_specs(params_or_shapes, mesh))


def batch_spec(mesh: Mesh, ndim: int) -> P:
    """Batch dim over all data-parallel axes (pod × data when multi-pod)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(dp, *([None] * (ndim - 1)))


def batch_shardings(batch_shapes, mesh: Mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(s):
        # batch=1 cells (long_500k) replicate the token; sequence parallelism
        # happens in the cache shardings instead
        if s.shape and s.shape[0] % dp_size == 0:
            return NamedSharding(mesh, batch_spec(mesh, len(s.shape)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(spec, batch_shapes)


def cache_specs(cache_shapes, mesh: Mesh, *, shard_seq: bool = False,
                seq_over_model: bool = False):
    """KV/state caches: batch dim over DP axes; kv-head/state dims over model
    where divisible.

    ``shard_seq``: long-context mode (long_500k, batch=1) — shard the capacity
    dim of KV caches over "data" (sequence parallelism for decode).
    ``seq_over_model``: §Perf lever — shard the capacity dim over "model"
    instead of head_dim, so decode attention keeps scores sequence-local and
    the per-layer exchange drops from O(B·H·T) score all-reduces to O(B·H)
    softmax statistics + O(B·H·hd) outputs."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_size = mesh.shape["model"]
    data_size = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(path, leaf):
        shape = leaf.shape
        ps = _path_str(path)
        parts: list = [None] * len(shape)
        # leading dims: optional period-stack dim then batch
        bdim = 1 if len(shape) >= 2 and ps.startswith("periods") else 0
        if "/k" in ps or "/v" in ps or ps.endswith("k") or ps.endswith("v"):
            # KV cache: (..., B, cap, n_kv, head_dim). GQA kv_heads rarely divide
            # the model axis, so shard head_dim (decode contractions psum over it).
            if shard_seq and len(shape) >= 3 and shape[-3] % data_size == 0:
                parts[-3] = dp if len(dp) > 1 else dp[0]
            elif shape[bdim] % data_size == 0:
                parts[bdim] = dp if len(dp) > 1 else dp[0]
            if (seq_over_model and len(shape) >= 3 and parts[-3] is None
                    and shape[-3] % model_size == 0):
                parts[-3] = "model"
            elif shape[-2] % model_size == 0:
                parts[-2] = "model"
            elif shape[-1] % model_size == 0:
                parts[-1] = "model"
        else:
            # SSM/conv/recurrent states: batch over DP, feature dim over model
            if shape[bdim] % data_size == 0:
                parts[bdim] = dp if len(dp) > 1 else dp[0]
            if len(shape) - bdim >= 2 and shape[-1] % model_size == 0:
                parts[-1] = "model"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def cache_shardings(cache_shapes, mesh: Mesh, *, shard_seq: bool = False,
                    seq_over_model: bool = False):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        cache_specs(cache_shapes, mesh, shard_seq=shard_seq,
                    seq_over_model=seq_over_model))


def tp_leaf_spec(shape, size: int, axis: str = "model",
                 floor: int = 1) -> P:
    """TP *storage* spec for one leaf: shard the LAST dim divisible by the
    axis size, searching backwards, never a dim below ``floor`` (dim 0 is the
    slot/pool/page identity dim of serve-cache trees — sharding it would
    split the batch/page address space, not the model). Replicated when no
    dim divides."""
    for i in range(len(shape) - 1, floor - 1, -1):
        if shape[i] % size == 0 and shape[i] >= size:
            parts: list = [None] * len(shape)
            parts[i] = axis
            return P(*parts)
    return P()


def tp_storage_specs(tree, mesh: Mesh, *, axis: str = "model",
                     floor: int = 1):
    """Leaf-wise tensor-parallel storage specs for a serve-cache tree.

    Unlike :func:`cache_specs` (training-side, path-pattern driven, DP+TP),
    this is the serving-TP storage rule: each leaf keeps its leading
    slot/pool dim whole and shards one trailing feature dim over ``axis``
    where divisible. Compute stays replicated — the TP window program
    all-gathers these leaves back to full tensors before the (unchanged)
    scan body runs, which is what keeps the token stream bit-exact vs the
    single-device engine (DESIGN §3.8). Use
    :meth:`repro.launch.paging.PagedLayout.tp_storage_specs` for hybrid
    paged trees (it raises the floor past the page dims)."""
    size = mesh.shape[axis]
    return jax.tree_util.tree_map(
        lambda leaf: tp_leaf_spec(leaf.shape, size, axis, floor), tree)


def tp_storage_shardings(tree, mesh: Mesh, *, axis: str = "model",
                         floor: int = 1):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tp_storage_specs(tree, mesh, axis=axis, floor=floor))
