"""Span-based fault-causality tracing for the serving stack (``repro.obs``).

The paper's core claim is that local errors become *legible, propagated
events* instead of silent deadlocks. Aggregate counters (``ServeMetrics``)
prove recovery happened; they cannot reconstruct *how* — which window a
fault latched in, which slot paid the LFLR re-prefill, which replica a
request landed on after a ULFM shrink. This module adds that substrate:

* :class:`Tracer` — a thread-safe, append-only recorder of Chrome/Perfetto
  ``trace_event`` dicts. Every hot-path call is one dict build + one list
  append under a lock, so an enabled tracer costs ≤2% tok/s on the window
  engine (asserted in ``benchmarks/serving.py``); a :class:`NullTracer`
  (the default everywhere) costs a single attribute check.
* A **trace id** is stamped on every :class:`~repro.serve.queue.Request` the
  first time a :class:`~repro.serve.queue.RequestQueue` accepts it — derived
  from the (unique) request id, so the id survives cross-replica re-routes
  after a replica kill and the post-mortem can stitch the two halves of the
  request's life into one causal chain.
* **Span taxonomy** (the ``cat`` field): ``request`` (submit → terminal
  response, plus first-token instants), ``sched`` (slot assignment,
  requeues), ``window`` (dispatch → retire of one decode window,
  double-buffer occupancy, window waits), ``prefill`` (chunks fed into fused
  windows, blocking prefills), ``page`` (paged-KV allocate / evict /
  reclaim), ``spec`` (draft/verify accept–reject per window), ``fault``
  (the error-word history mapped back onto host time: one event per faulted
  ``(step, slot)`` with the exact :class:`~repro.core.errors.ErrorCode`
  word from ``DeviceFuture.fault_codes()``), ``recovery`` (LFLR lane begin
  → first healthy token), and ``group`` (membership lifecycle: kill / ULFM
  shrink / ledger re-route, plus the elastic events — ``fleet_stop`` when
  the whole fleet crashes, ``ledger_replay`` when a restart reconstructs
  the outstanding set from the write-ahead log, ``state_transfer`` (span,
  ``complete=True`` on success) for the background weights+page-pool copy
  a joiner receives, ``replica_join`` (span) covering warm-up → transfer →
  first exchange on the widened group, and ``autoscale`` instants for
  policy-driven grow/shrink decisions; the multihost supervisor adds
  ``epoch`` instants carrying the agreed member list), and ``host`` (the
  process-level fault domain of ``repro.serve.multihost``: one
  ``heartbeat`` span per worker summarising its beat stream on the
  supervisor lane — ``pid = SUPERVISOR_PID`` — plus ``host_kill`` /
  ``host_stop`` / ``host_resume`` instants for executed faults and
  ``host_suspect`` / ``host_suspect_clear`` / ``host_evict`` instants for
  the failure detector's suspect → evict ladder, each stamped with the
  observed silence and phi score).
* Export is plain ``trace_event`` JSON (``{"traceEvents": [...]}``): load it
  in Perfetto / ``chrome://tracing``, or feed it to the post-mortem CLI
  (``scripts/trace_tool.py``) which reconstructs per-request timelines and a
  fault-causality report. Training runs share the format through
  :func:`event_log_to_events` over the executor's ``EventLog``.

Sampling: ``Tracer(sample=0.1)`` keeps request-scoped spans for a
deterministic ~10% of requests (hash of the request id — no RNG, so a rerun
traces the same requests); engine-scoped spans (windows, faults, group
events) are always kept, because a fault on an unsampled request must still
be attributable.
"""
from __future__ import annotations

import json
import threading
import time
from typing import TYPE_CHECKING, Any, Iterable, Optional

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids a serve<->obs cycle
    from ..core.resilient import EventLog

# Knuth multiplicative hash over the request id: deterministic sampling that
# is stable across reruns and uncorrelated with sequential id assignment.
_HASH_MULT = 2654435761
_HASH_MOD = 1 << 32

# tid of the engine-wide lane (window spans); slot lanes use their slot index.
ENGINE_TID = 1 << 20
# base tid of the tensor-parallel shard lanes: shard s of a TP replica emits
# its reconciliation events (``shard_fanout``) on SHARD_TID + s, so the shard
# fan-out renders as its own lane block above the engine lane.
SHARD_TID = 1 << 21


class Tracer:
    """Thread-safe recorder of ``trace_event`` dicts.

    One tracer per replica (``pid`` = replica rank); a ``ServeGroup`` gives
    each rank thread its own and merges them at export. All timestamps come
    from ``clock`` (monotonic seconds) and are stored as microseconds, the
    trace_event unit.
    """

    enabled = True

    def __init__(self, *, pid: int = 0, clock=time.monotonic,
                 sample: float = 1.0):
        if not 0.0 <= sample <= 1.0:
            raise ValueError(f"sample must be in [0, 1], got {sample}")
        self.pid = pid
        self.clock = clock
        self.sample = float(sample)
        self._lock = threading.Lock()
        self._events: list[dict] = []

    # ----------------------------------------------------------- primitives
    def emit(self, name: str, cat: str, ph: str, ts: float, *,
             dur: float = 0.0, tid: int = ENGINE_TID,
             args: Optional[dict] = None) -> None:
        """Record one event. ``ts``/``dur`` in seconds (converted to µs)."""
        ev = {"name": name, "cat": cat, "ph": ph, "ts": ts * 1e6,
              "pid": self.pid, "tid": tid}
        if ph == "X":
            ev["dur"] = max(dur, 0.0) * 1e6
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, cat: str, *, ts: Optional[float] = None,
                tid: int = ENGINE_TID, **args) -> None:
        self.emit(name, cat, "i", self.clock() if ts is None else ts,
                  tid=tid, args=args or None)

    def span(self, name: str, cat: str, t0: float, t1: float, *,
             tid: int = ENGINE_TID, **args) -> None:
        self.emit(name, cat, "X", t0, dur=t1 - t0, tid=tid, args=args or None)

    # ------------------------------------------------------- request lifecycle
    def sampled(self, request_id: int) -> bool:
        """Deterministic per-request sampling decision."""
        if self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        return ((int(request_id) * _HASH_MULT) % _HASH_MOD
                < self.sample * _HASH_MOD)

    def start_request(self, req, now: float) -> Optional[int]:
        """Stamp-and-record a request's acceptance; returns its trace id (the
        request id — unique by the queue/ledger contract, stable across
        re-routes) or None if sampled out."""
        if not self.sampled(req.id):
            return None
        self.instant("submit", "request", ts=now, trace_id=req.id,
                     prompt_len=len(req.prompt),
                     max_new_tokens=req.max_new_tokens)
        return req.id

    def end_request(self, resp, now: float) -> None:
        """One complete span covering the request's whole life (accept →
        terminal response), reconstructed from the response's latency."""
        if resp.trace_id is None:
            return
        self.span("request", "request", now - resp.latency_s, now,
                  trace_id=resp.trace_id, status=resp.status,
                  tokens=len(resp.tokens), retries=resp.retries,
                  replica=resp.replica,
                  ttft_s=resp.ttft_s, detail=resp.detail or None)

    # --------------------------------------------------------------- queries
    @property
    def num_events(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()


class NullTracer(Tracer):
    """The default tracer: records nothing, costs one attribute check.

    Call sites guard span construction with ``if tracer.enabled:`` so the
    disabled path never builds an args dict — this is what keeps the no-op
    tracer literally free and the token stream bit-exact by construction.
    """

    enabled = False

    def __init__(self):
        super().__init__()

    def emit(self, *a, **kw) -> None:  # noqa: D102 - no-op by design
        pass

    def start_request(self, req, now):
        return None

    def end_request(self, resp, now):
        pass


NULL_TRACER = NullTracer()


# ------------------------------------------------------------------- export
def merge_traces(*tracers: Tracer) -> dict:
    """Merge tracers (e.g. one per group rank) into one trace_event JSON
    object, events sorted by timestamp."""
    events: list[dict] = []
    for tr in tracers:
        events.extend(tr.events())
    events.sort(key=lambda e: (e.get("ts", 0.0), e.get("pid", 0)))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def merge_trace_dicts(*traces: dict) -> dict:
    """Merge already-exported trace objects into one, events re-sorted.

    The crash-restart post-mortem needs this: the pre-crash fleet and the
    replayed fleet are two ``run_ranks`` invocations with two tracer sets,
    but one causal story — submits from the first incarnation pair with
    terminal spans from the second (trace ids survive the write-ahead log),
    so ``validate`` only passes on the merged object."""
    events: list[dict] = []
    for tr in traces:
        events.extend(tr.get("traceEvents", ()))
    events.sort(key=lambda e: (e.get("ts", 0.0), e.get("pid", 0)))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump_trace(path: str, *tracers: Tracer) -> dict:
    """Write the merged trace to ``path``; returns the trace object."""
    trace = merge_traces(*tracers)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
        f.write("\n")
    return trace


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def event_log_to_events(log: "EventLog", *, pid: int = 0) -> list[dict]:
    """Convert a training executor :class:`~repro.core.resilient.EventLog`
    into the same trace_event schema, so one post-mortem tool reads training
    and serving runs alike. Events carry their wall-clock ``t`` (stamped by
    the executor / ``ServeMetrics.to_event_log``) as the timestamp; the step
    duration becomes the span length."""
    out = []
    for ev in log.events:
        e = {"name": ev.kind, "cat": "train", "pid": pid, "tid": 0,
             "ts": ev.t * 1e6,
             "args": {"step": ev.step, "detail": ev.detail or None,
                      "code": ev.code, "action": ev.action}}
        if ev.duration_s:
            e["ph"] = "X"
            e["dur"] = ev.duration_s * 1e6
            # the stamp is taken at the step's *end*; the span starts earlier
            e["ts"] = (ev.t - ev.duration_s) * 1e6
        else:
            e["ph"] = "i"
        out.append(e)
    return out
