"""Post-mortem reconstruction over trace_event JSON (``repro.obs``).

Pure functions from a trace object (``{"traceEvents": [...]}`` as produced by
:func:`repro.obs.trace.merge_traces`) to the two artefacts a human (or the
future fault-injection fuzzer's oracle) wants after a faulted run:

* :func:`request_timelines` — every event of one request's life, in wall
  order, keyed by trace id: submit → slot assignment → prefill chunks →
  decode windows → (faults → recovery lanes →) first/terminal token.
* :func:`fault_report` — one :class:`FaultResolution` per fault event,
  joining the fault to its recovery action and the recovery-complete span
  (or the terminal FAILED/EXPIRED response that abandoned it): the causal
  chain *fault → detection → recovery → re-prefill → first healthy token*.
* :func:`validate` — the round-trip check the CI trace smoke runs: every
  fault resolves, every traced request reaches exactly one terminal span,
  every recovery span closes, every kill chains to a shrink, every elastic
  rejoin chains to a *completed* state transfer, and every multihost
  ``host_evict`` is preceded by a ``host_suspect`` for the same rank and
  followed by an ``epoch`` whose membership excludes it. Returns a list of
  problems (empty = clean).

Everything here is stdlib-only on plain dicts, so ``scripts/trace_tool.py``
stays a dependency-free CLI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional


def _args(ev: dict) -> dict:
    a = ev.get("args")
    return a if isinstance(a, dict) else {}


def _tid_of(ev: dict):
    return _args(ev).get("trace_id")


def events_of(trace: dict) -> list[dict]:
    evs = trace.get("traceEvents", [])
    return sorted(evs, key=lambda e: (e.get("ts", 0.0), e.get("pid", 0)))


def request_timelines(trace: dict) -> dict[int, list[dict]]:
    """Events grouped per trace id, in wall order. Request-scoped engine
    events (decode/prefill/fault/recovery spans carrying a ``trace_id`` arg)
    are included; anonymous engine events (window spans) are not."""
    out: dict[int, list[dict]] = {}
    for ev in events_of(trace):
        tid = _tid_of(ev)
        if tid is not None:
            out.setdefault(tid, []).append(ev)
    return out


@dataclass
class FaultResolution:
    """One fault event joined to its recovery outcome."""

    trace_id: Optional[int]
    pid: int                      # replica rank
    window: Optional[int]         # dispatch counter of the faulted window
    step: Optional[int]           # first faulting step within the window
    slot: Optional[int]
    code: int                     # exact error word from fault_codes()
    code_names: tuple[str, ...]
    action: Optional[str]         # recovery action the policy chose
    detected_ts: float            # wall time the wait surfaced the fault (µs)
    recovery: Optional[dict] = None    # the recovery-complete span, if any
    terminal: Optional[dict] = None    # the request's terminal span, if traced

    @property
    def resolved(self) -> bool:
        """A fault is resolved iff its recovery lane completed, or the
        request was given a terminal answer anyway (FAILED / EXPIRED — the
        serving ABORT is a legal resolution, a silent drop is not)."""
        if self.recovery is not None:
            return True
        return self.terminal is not None

    @property
    def recovery_s(self) -> Optional[float]:
        if self.recovery is None:
            return None
        return ((self.recovery["ts"] + self.recovery.get("dur", 0.0)
                 - self.detected_ts) / 1e6)


def fault_report(trace: dict) -> list[FaultResolution]:
    """Join every ``fault`` event to the recovery span / terminal response
    that resolved it (same trace id, same slot when attributable, later in
    wall time)."""
    evs = events_of(trace)
    faults = [e for e in evs if e.get("cat") == "fault"]
    recoveries = [e for e in evs if e.get("cat") == "recovery"
                  and e.get("name") == "recovery"]
    terminals = [e for e in evs if e.get("cat") == "request"
                 and e.get("name") == "request"]
    out = []
    for f in faults:
        a = _args(f)
        tid = a.get("trace_id")
        rec = None
        for r in recoveries:
            ra = _args(r)
            if ra.get("trace_id") != tid:
                continue
            if r["ts"] + r.get("dur", 0.0) < f["ts"]:
                continue                      # resolved an earlier fault
            if rec is None or r["ts"] < rec["ts"]:
                rec = r
        term = None
        for t in terminals:
            # no ts ordering requirement: detection is deferred by design, so
            # a stale window's fault can legally surface *after* its lane's
            # request was answered — the answer still resolves it
            if _tid_of(t) == tid:
                term = t
                break
        out.append(FaultResolution(
            trace_id=tid, pid=f.get("pid", 0),
            window=a.get("window"), step=a.get("step"), slot=a.get("slot"),
            code=int(a.get("code", 0)),
            code_names=tuple(a.get("code_names", ())),
            action=a.get("action"), detected_ts=f["ts"],
            recovery=rec, terminal=term))
    return out


def group_chains(trace: dict) -> list[dict]:
    """Cross-replica causal chains: one dict per replica kill, linking the
    kill to the ULFM shrink that detected it, the ledger re-routes it caused,
    the re-routed requests' terminal spans on the survivors, and — when the
    elastic layer later re-admitted the same rank — the ``replica_join`` span
    that closed the kill → shrink → rejoin loop."""
    evs = events_of(trace)
    kills = [e for e in evs if e.get("name") == "replica_kill"]
    shrinks = [e for e in evs if e.get("name") == "ulfm_shrink"]
    reroutes = [e for e in evs if e.get("name") == "reroute"]
    joins = [e for e in evs if e.get("name") == "replica_join"]
    terminals = {_tid_of(e): e for e in evs
                 if e.get("cat") == "request" and e.get("name") == "request"}
    chains = []
    for k in kills:
        dead = _args(k).get("rank", k.get("pid"))
        chain_shrinks = [s for s in shrinks if s["ts"] >= k["ts"]
                         and dead not in _args(s).get("survivors", ())]
        chain_routes = [r for r in reroutes
                        if _args(r).get("from_rank") == dead]
        chain_joins = [j for j in joins if j["ts"] >= k["ts"]
                       and _args(j).get("rank") == dead]
        routed = {}
        for r in chain_routes:
            tid = _tid_of(r)
            if tid is None:
                # re-routed before its first queue accept (e.g. while still
                # pending in the ledger): no trace id stamped yet, but the
                # trace id *is* the request id by contract, so the eventual
                # terminal — possibly in a post-restart incarnation — still
                # links by id
                tid = _args(r).get("request")
            routed[tid] = terminals.get(tid)
        chains.append({"kill": k, "dead_rank": dead,
                       "shrinks": chain_shrinks, "reroutes": chain_routes,
                       "terminals": routed, "rejoins": chain_joins})
    return chains


def validate(trace: dict) -> list[str]:
    """Round-trip consistency check; returns problems (empty = clean)."""
    problems: list[str] = []
    evs = events_of(trace)
    if not evs:
        return ["trace carries no events"]
    # every traced request reaches exactly one terminal span
    submits = {}
    terminals: dict[int, int] = {}
    for e in evs:
        tid = _tid_of(e)
        if e.get("name") == "submit":
            submits[tid] = e
        elif e.get("cat") == "request" and e.get("name") == "request":
            terminals[tid] = terminals.get(tid, 0) + 1
    for tid in submits:
        n = terminals.get(tid, 0)
        if n != 1:
            problems.append(
                f"request {tid}: {n} terminal spans (want exactly 1)")
    # terminal spans contain their request's scoped events. The span start is
    # anchored at the submit event when present: the terminal span's own start
    # is reconstructed from the response latency at record time, a hair after
    # the commit that produced it, so the first events of a request's life
    # legitimately precede it by that recording gap.
    timelines = request_timelines(trace)
    for tid, term_n in terminals.items():
        term = next(e for e in evs if e.get("cat") == "request"
                    and e.get("name") == "request" and _tid_of(e) == tid)
        sub = submits.get(tid)
        t0 = sub["ts"] if sub is not None else term["ts"]
        t1 = term["ts"] + term.get("dur", 0.0)
        for ev in timelines.get(tid, ()):
            if ev.get("name") in ("request", "reroute"):
                continue            # reroutes are group-scoped, not contained
            lo = ev["ts"]
            hi = ev["ts"] + ev.get("dur", 0.0)
            if ev.get("cat") == "fault" and lo >= t0 - 1.0:
                continue            # deferred detection: a stale window's
                                    # fault legally surfaces after the answer
            if lo < t0 - 1.0 or hi > t1 + 1.0:     # 1 µs slack
                problems.append(
                    f"request {tid}: {ev.get('name')} at {lo:.0f}µs outside "
                    f"its request span [{t0:.0f}, {t1:.0f}]µs")
    # every fault resolves
    for fr in fault_report(trace):
        if not fr.resolved:
            problems.append(
                f"fault {fr.code_names or fr.code} on trace {fr.trace_id} "
                f"slot {fr.slot} (window {fr.window} step {fr.step}) never "
                "resolved: no recovery span, no terminal response")
    # every kill chains to a shrink
    for chain in group_chains(trace):
        if not chain["shrinks"]:
            problems.append(
                f"replica {chain['dead_rank']} killed but no survivor "
                "recorded a ulfm_shrink")
    # host fault domain (multihost supervisor): an eviction must have been
    # *detected*, never decreed — a host_evict without a preceding
    # host_suspect for the same rank means the heartbeat detector was
    # bypassed (e.g. an EOF shortcut) — and must be followed by an epoch
    # event whose membership excludes the dead rank (the repair half of the
    # suspect → evict → shrink contract, DESIGN §3.9)
    suspects = [(e["ts"], _args(e).get("rank")) for e in evs
                if e.get("name") == "host_suspect"]
    epochs = [e for e in evs if e.get("name") == "epoch"]
    for e in evs:
        if e.get("name") != "host_evict":
            continue
        rank = _args(e).get("rank")
        if not any(r == rank and ts <= e["ts"] + 1.0 for ts, r in suspects):
            problems.append(
                f"host {rank} evicted without a preceding host_suspect "
                "(eviction must come from the failure detector)")
        if not any(ep["ts"] >= e["ts"] - 1.0
                   and rank not in _args(ep).get("members", (rank,))
                   for ep in epochs):
            problems.append(
                f"host {rank} evicted but no subsequent epoch excludes it "
                "(membership was never repaired)")
    # every rejoin chains to a completed state transfer: a rank may not serve
    # on the widened group without having received the weights + page-pool
    # snapshot first (the background lane must have *finished*, not started)
    transfers = [e for e in evs if e.get("name") == "state_transfer"]
    for j in (e for e in evs if e.get("name") == "replica_join"):
        j_end = j["ts"] + j.get("dur", 0.0)
        ok = any(t.get("pid") == j.get("pid")
                 and _args(t).get("complete")
                 and t["ts"] + t.get("dur", 0.0) <= j_end + 1.0  # 1 µs slack
                 for t in transfers)
        if not ok:
            problems.append(
                f"replica {_args(j).get('rank', j.get('pid'))} joined without "
                "a completed state_transfer span preceding the join")
    # tensor-parallel reconciliation: a fault on a TP replica fans out one
    # shard_fanout instant per shard — all shards 0..tp-1 must appear for
    # each (replica, window), or a shard diverged from its peers' view of
    # the folded error word (exactly what the cross-shard OR-fold forbids)
    fanouts: dict[tuple, set[int]] = {}
    fanout_tp: dict[tuple, int] = {}
    for e in evs:
        if e.get("name") != "shard_fanout":
            continue
        a = _args(e)
        key = (e.get("pid", 0), a.get("window"))
        fanouts.setdefault(key, set()).add(int(a.get("shard", -1)))
        fanout_tp[key] = int(a.get("tp", 0))
    for key, shards in fanouts.items():
        tp = fanout_tp[key]
        missing = sorted(set(range(tp)) - shards)
        if missing:
            problems.append(
                f"replica {key[0]} window {key[1]}: fault fanned out to "
                f"shards {sorted(shards)} but not {missing} (tp={tp}) — "
                "cross-shard reconciliation incomplete")
    # a TP shard loss is a hard fault of the whole owning replica: every
    # shard_loss must be followed by that replica's kill (one SPMD program —
    # a surviving half-replica would violate the shard-set contract)
    kills_by_pid = [(e.get("pid", 0), e["ts"]) for e in evs
                    if e.get("name") == "replica_kill"]
    for e in evs:
        if e.get("name") != "shard_loss":
            continue
        pid = e.get("pid", 0)
        if not any(kp == pid and kt >= e["ts"] - 1.0
                   for kp, kt in kills_by_pid):
            problems.append(
                f"replica {pid}: shard {_args(e).get('shard')} lost but the "
                "owning replica never died (a TP replica must fail whole)")
    return problems


# ------------------------------------------------------------ pretty printing
def _fmt_args(a: dict) -> str:
    skip = {"trace_id"}
    parts = [f"{k}={v}" for k, v in a.items()
             if k not in skip and v is not None]
    return " ".join(parts)


def format_timeline(trace: dict, trace_id: int) -> str:
    """Human-readable per-request timeline, timestamps relative to submit."""
    evs = request_timelines(trace).get(trace_id, [])
    if not evs:
        return f"trace {trace_id}: no events"
    t0 = evs[0]["ts"]
    lines = [f"request trace_id={trace_id}"]
    for ev in evs:
        rel = (ev["ts"] - t0) / 1e3
        dur = ev.get("dur")
        dur_s = f" [{dur / 1e3:.2f}ms]" if dur else ""
        lines.append(
            f"  +{rel:9.2f}ms  r{ev.get('pid', 0)}/s{ev.get('tid', 0):<3} "
            f"{ev.get('cat', '?'):8s} {ev.get('name', '?'):14s}{dur_s}  "
            f"{_fmt_args(_args(ev))}")
    return "\n".join(lines)


def format_fault_report(trace: dict) -> str:
    """The causal fault table: fault → attribution → action → resolution."""
    report = fault_report(trace)
    if not report:
        return "no faults recorded"
    lines = [f"{len(report)} fault(s):"]
    for fr in report:
        codes = "|".join(fr.code_names) if fr.code_names else hex(fr.code)
        if fr.recovery is not None:
            res = f"recovered in {fr.recovery_s * 1e3:.1f}ms"
            out = _args(fr.recovery).get("outcome")
            if out and out != "recovered":
                res = f"{out} after {fr.recovery_s * 1e3:.1f}ms"
        elif fr.terminal is not None:
            res = f"terminal {_args(fr.terminal).get('status')}"
        else:
            res = "UNRESOLVED"
        lines.append(
            f"  trace {fr.trace_id} r{fr.pid}: window {fr.window} "
            f"step {fr.step} slot {fr.slot} {codes} "
            f"-> {fr.action or '?'} -> {res}")
    return "\n".join(lines)
