"""repro.obs — end-to-end fault-causality tracing.

Span-based observability for the serving (and training) stack: a trace id is
stamped on every accepted request, carried through scheduler slot assignment,
window dispatch/retire, prefill chunks, paged-KV page movement, speculative
draft/verify, every recovery lane, and the ServeGroup's kill → shrink →
re-route choreography; the on-device ``(K, slots)`` error-word histories are
mapped onto host-time spans so each :class:`~repro.core.errors.ErrorCode`
class becomes a causal edge. Export is Chrome/Perfetto ``trace_event`` JSON;
``scripts/trace_tool.py`` is the post-mortem CLI over it.
"""
from .postmortem import (  # noqa: F401
    FaultResolution,
    events_of,
    fault_report,
    format_fault_report,
    format_timeline,
    group_chains,
    request_timelines,
    validate,
)
from .trace import (  # noqa: F401
    ENGINE_TID,
    NULL_TRACER,
    NullTracer,
    Tracer,
    dump_trace,
    event_log_to_events,
    load_trace,
    merge_trace_dicts,
    merge_traces,
)
