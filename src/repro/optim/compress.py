"""Gradient compression for the cross-pod (DCN) reduction, with error feedback.

On a multi-pod mesh the "pod" axis crosses data-center network, 10-25× slower
than ICI. The standard distributed-optimization trick: reduce in-pod at full
precision (reduce-scatter over "data"), then compress the cross-pod leg.

Two codecs:
* ``int8``  — per-tensor scale quantisation (8×→4× byte reduction vs f32/bf16);
* ``topk``  — magnitude top-k sparsification with *error feedback* (the residual
  of what was not transmitted is added to the next step's gradient — guarantees
  the compression error stays bounded instead of accumulating).

``compressed_psum`` composes with ``shard_map`` over the pod axis; the error-
feedback buffer is part of the optimizer state (sharded like moments).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    codec: str = "none"        # none | int8 | topk
    topk_fraction: float = 0.01
    error_feedback: bool = True


# ------------------------------------------------------------------ int8 codec
def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ------------------------------------------------------------------ topk codec
def topk_mask(x: jax.Array, fraction: float) -> jax.Array:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.size * fraction))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_with_feedback(g: jax.Array, residual: jax.Array,
                           cfg: CompressionConfig):
    """Returns (payload_to_reduce, new_residual). Pure — usable inside jit."""
    g = g.astype(jnp.float32)
    if cfg.error_feedback:
        g = g + residual
    if cfg.codec == "topk":
        mask = topk_mask(g, cfg.topk_fraction)
        sent = g * mask
        new_residual = g - sent if cfg.error_feedback else jnp.zeros_like(g)
        return sent, new_residual
    if cfg.codec == "int8":
        q, scale = quantize_int8(g)
        sent = dequantize_int8(q, scale)
        new_residual = g - sent if cfg.error_feedback else jnp.zeros_like(g)
        return sent, new_residual
    return g, jnp.zeros_like(g)


def compressed_psum(g: jax.Array, residual: jax.Array, axis_name: str,
                    cfg: CompressionConfig):
    """Inside shard_map over the pod axis: compress → psum → mean.

    Note the int8 payload itself is what crosses DCN on real hardware; here the
    dequantised tensor is psum'ed (XLA has no int8 all-reduce), so the *numerics*
    of quantised reduction are exact while the dry-run's collective-bytes term
    models the payload via ``wire_bytes_factor``.
    """
    sent, new_residual = compress_with_feedback(g, residual, cfg)
    n = jax.lax.psum(1, axis_name)
    reduced = jax.lax.psum(sent, axis_name) / n
    return reduced, new_residual


def wire_bytes_factor(cfg: CompressionConfig) -> float:
    """Bytes-on-wire multiplier vs f32 (for the roofline collective term)."""
    if cfg.codec == "int8":
        return 0.25
    if cfg.codec == "topk":
        # value + index per surviving element
        return cfg.topk_fraction * 2.0
    return 1.0


def init_residuals(params) -> dict:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
