"""AdamW with ZeRO-1-shardable moments, global-norm clipping, LR schedules.

Pure-function optimizer (no optax dependency): state is a pytree that the
sharding rules partition (moments additionally sharded over "data" — ZeRO-1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup → cosine decay (fp32 scalar)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, opt_state, step, *,
                 lr_scale=1.0):
    """One AdamW step; returns (new_params, new_opt_state, stats)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = schedule(cfg, step) * lr_scale
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_ = cfg.b1 * m + (1 - cfg.b1) * gf
        v_ = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mh = m_ / bc1
        vh = v_ / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        p_ = p.astype(jnp.float32) - lr * delta
        return p_.astype(p.dtype), m_, v_

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}


def reset_moments(opt_state):
    """Paper use case 2 ('hierarchical escalation'): reset the solver state —
    the optimizer-moments analogue of a Krylov restart — keeping the params."""
    return jax.tree_util.tree_map(jnp.zeros_like, opt_state)
