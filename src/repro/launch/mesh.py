"""Production meshes (TPU v5e: 256 chips/pod, 16×16 ICI torus).

``make_production_mesh`` is a FUNCTION (importing this module never touches jax
device state). Single-pod: (16, 16) = ("data", "model"). Multi-pod: (2, 16, 16) =
("pod", "data", "model") — the "pod" axis carries data parallelism over DCN plus
the (optionally compressed) cross-pod gradient reduction.
"""
from __future__ import annotations

import jax

try:                                  # jax >= 0.5: explicit axis types exist,
    from jax.sharding import AxisType  # pin ours to Auto (GSPMD decides)

    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
except ImportError:                   # jax 0.4.x: Auto is the only behaviour
    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2, pod: int = 0):
    """Small mesh over forced host devices (tests / examples)."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))


def dp_size(mesh) -> int:
    out = mesh.shape["data"]
    if "pod" in mesh.shape:
        out *= mesh.shape["pod"]
    return out
