"""Step factories: jitted train / prefill / decode steps with the paper's in-band
error channel integrated (every step returns ``(outputs, metrics, error_word)``),
plus ShapeDtypeStruct input specs and shardings for every (arch × shape) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..core.detect import ProbeConfig, loss_probe, state_probe, step_probe
from ..core.errors import ErrorCode
from ..core.faults import inject_batch, inject_grads, inject_loss
from ..models import build_model
from ..optim import AdamWConfig, adamw_update, init_opt_state, reset_moments
from ..sharding import (
    batch_shardings,
    cache_shardings,
    moment_shardings,
    param_shardings,
)


# ----------------------------------------------------------------- perf options
from dataclasses import dataclass as _dataclass


@_dataclass(frozen=True)
class PerfOptions:
    """Beyond-paper performance levers (see EXPERIMENTS.md §Perf).

    microbatch      — gradient accumulation over k microbatches (scan): activation
                      memory ÷ k at the cost of one grads-sized fp32 accumulator.
    ce_chunk        — chunked cross-entropy: never materialise (B,S,V) logits.
    seq_shard       — sequence-parallel residual stream: constrain activations to
                      P(dp, "model", None) between blocks so GSPMD lowers the
                      Megatron all-reduces to reduce-scatter + all-gather.
    cache_seq_model — decode KV caches sharded on the *capacity* dim over "model"
                      (scores stay sequence-sharded; softmax/psum exchanges tiny
                      (B,H) statistics instead of (B,H,T) score tensors).
    probes          — the in-band device channel on/off (off only for overhead
                      measurement — never in production).
    window          — decode-window size K for serving: scan K fused slot-decode
                      steps fully on device with deferred fault detection
                      (``make_decode_window``); 0 = per-token decode.
    donate          — donate caches/slot state to the decode window so XLA
                      updates them in place (no per-window cache copy).
    overlap         — fuse admission/LFLR prefill into the decode windows
                      (``make_prefill_decode_window``): joining or recovering
                      sequences advance their cache by a prompt chunk *inside*
                      the window scan, so prefill never stalls the token
                      stream; ignored when ``window == 0``.
    page            — paged KV pool page size for serving (``launch.paging``):
                      full-attention caches become a shared page pool addressed
                      through a per-slot page table, so long prompts and short
                      chats share HBM; 0 = one contiguous block per slot.
    speculate       — speculative decode windows (``make_speculative_decode_
                      window``): each window step drafts ``draft_len`` tokens
                      with a shallow-exit self-draft over the first
                      ``draft_layers`` layers, then verifies all drafts in one
                      batched full-model forward — up to ``draft_len + 1``
                      tokens per full-model step, token-bit-exact vs the plain
                      window engine; rejected drafts are attributed in-band
                      via ``ErrorCode.DRAFT_REJECT``. Requires ``window > 0``
                      and a pure full-attention architecture.
    draft_len       — tokens proposed per speculative window step (D).
    draft_layers    — layers of the shallow-exit drafter.
    """

    microbatch: int = 0
    ce_chunk: int = 0
    seq_shard: bool = False
    cache_seq_model: bool = False
    probes: bool = True
    ep_constraint: bool = False   # MoE dispatch buffers constrained E-over-model
    window: int = 0
    donate: bool = True
    overlap: bool = True
    page: int = 0
    speculate: bool = False
    draft_len: int = 3
    draft_layers: int = 1

    @classmethod
    def parse(cls, spec: str) -> "PerfOptions":
        """'mb=8,ce=2048,sp=1,cacheseq=1,probes=0,ep=1,window=8,donate=1,
        overlap=1,page=16,spec=1,dlen=3,dlayers=1' → PerfOptions."""
        kw: dict = {}
        for part in (spec or "").split(","):
            if not part:
                continue
            k, v = part.split("=")
            k = {"mb": "microbatch", "ce": "ce_chunk", "sp": "seq_shard",
                 "cacheseq": "cache_seq_model", "probes": "probes",
                 "ep": "ep_constraint", "win": "window", "window": "window",
                 "donate": "donate", "overlap": "overlap",
                 "page": "page", "spec": "speculate", "speculate": "speculate",
                 "dlen": "draft_len", "draft_len": "draft_len",
                 "dlayers": "draft_layers", "draft_layers": "draft_layers"}[k]
            kw[k] = bool(int(v)) if k in ("seq_shard", "cache_seq_model",
                                          "probes", "ep_constraint",
                                          "donate", "overlap",
                                          "speculate") else int(v)
        return cls(**kw)


BASELINE = PerfOptions()

# Dry-run cost-variant compiles set this so the microbatch scan is unrolled
# (cost_analysis counts while bodies once; see dryrun._corrected_costs).
MB_UNROLL = False


# -------------------------------------------------------------------- factories
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    probe_cfg: ProbeConfig | None = None, *, impl: str = "auto",
                    perf: PerfOptions = BASELINE):
    """(state, batch, inject) → (state', metrics, error_word).

    The error word is the in-band device channel (DESIGN.md §2): probes over loss,
    the full gradient stream, input tokens and the MoE router are OR-combined into
    one uint32 that the host's DeviceFuture converts into the paper's exceptions.
    """
    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig()
    probe_cfg = probe_cfg or ProbeConfig()

    from ..models import transformer as _tf

    def _loss_and_grads(params, batch, tokens_inj):
        def loss_fn(p):
            b = dict(batch)
            if tokens_inj is not None:
                b["tokens"] = tokens_inj
            loss, aux = model.loss(p, b, impl=impl, ce_chunk=perf.ce_chunk)
            return loss, aux

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state, batch, inject):
        if True:
            tokens = batch.get("tokens")
            tokens_inj = (inject_batch(tokens, inject)
                          if tokens is not None else None)
            if perf.microbatch > 1:
                k = perf.microbatch

                def slice_mb(x, i):
                    B = x.shape[0]
                    return jax.lax.dynamic_slice_in_dim(x, i * (B // k),
                                                        B // k, 0)

                def body(carry, i):
                    g_acc, l_acc, d_acc = carry
                    b_i = {kk: slice_mb(v, i) for kk, v in batch.items()}
                    t_i = slice_mb(tokens_inj, i) if tokens_inj is not None else None
                    (loss, aux), grads = _loss_and_grads(state["params"], b_i,
                                                         t_i)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32) / k, g_acc,
                        grads)
                    return (g_acc, l_acc + loss / k,
                            d_acc + aux["dropped_fraction"] / k), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                import repro.launch.steps as _steps_mod
                (grads, loss, dropped), _ = jax.lax.scan(
                    body, (g0, jnp.float32(0), jnp.float32(0)),
                    jnp.arange(k),
                    unroll=True if _steps_mod.MB_UNROLL else 1)
                aux = {"dropped_fraction": dropped}
            else:
                (loss, aux), grads = _loss_and_grads(state["params"], batch,
                                                     tokens_inj)
            loss = inject_loss(loss, inject)
            grads = inject_grads(grads, inject)
            if perf.probes:
                word = step_probe(
                    loss, grads,
                    tokens=tokens_inj,
                    vocab_size=cfg.vocab_size if tokens is not None else None,
                    router_dropped=(aux["dropped_fraction"]
                                    if cfg.is_moe else None),
                    cfg=probe_cfg)
            else:
                word = jnp.uint32(0)
            new_params, new_opt, stats = adamw_update(
                opt_cfg, state["params"], grads, state["opt"], state["step"],
                lr_scale=state["lr_scale"])
            new_state = {"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1,
                         "lr_scale": state["lr_scale"]}
            metrics = {"loss": loss, "grad_norm": stats["grad_norm"],
                       "lr": stats["lr"],
                       "dropped_fraction": aux["dropped_fraction"]}
            return new_state, metrics, word

    return train_step


def make_prefill_step(cfg: ModelConfig, probe_cfg: ProbeConfig | None = None, *,
                      impl: str = "auto"):
    model = build_model(cfg)
    probe_cfg = probe_cfg or ProbeConfig()

    def prefill_step(params, batch):
        logits, aux = model.forward(
            params, batch.get("tokens"),
            inputs_embeds=batch.get("inputs_embeds"),
            img_embeds=batch.get("img_embeds"), impl=impl)
        # serve-side probe: non-finite logits ⇒ NONFINITE_LOSS-class soft fault
        word = loss_probe(jnp.max(jnp.abs(logits)),
                          ProbeConfig(loss_divergence_threshold=jnp.inf))
        return logits, word

    return prefill_step


def make_decode_step(cfg: ModelConfig, probe_cfg: ProbeConfig | None = None):
    model = build_model(cfg)
    probe_cfg = probe_cfg or ProbeConfig()

    def decode_step(params, cache, token, pos):
        logits, new_cache = model.decode_step(params, token, cache, pos)
        # probe recurrent states only (KV re-probing would double memory traffic)
        words = [loss_probe(jnp.max(jnp.abs(logits)),
                            ProbeConfig(loss_divergence_threshold=jnp.inf))]
        rec = _recurrent_states(new_cache)
        if rec:
            words.append(state_probe(rec, probe_cfg))
        word = functools.reduce(lambda a, b: a | b, words)
        return logits, new_cache, word

    return decode_step


def make_slot_decode_step(cfg: ModelConfig, probe_cfg: ProbeConfig | None = None):
    """Per-slot decode for continuous batching (``repro.serve``).

    vmap of the single-sequence decode step over a leading *slot* axis, so every
    slot carries its own absolute position — the shape continuous batching
    needs, since slots join and leave the batch at different offsets:

      params                      shared across slots (in_axes=None)
      caches  pytree, leaves (S, ...)  stack of per-sequence (batch=1) caches
      tokens  (S, 1, 1) int32
      pos     (S,) int32               per-slot absolute position

    Returns ``(logits (S, 1, 1, V), new caches, error words (S,))``. The word
    is *per slot* (slots are independent under vmap), which is what makes
    per-sequence LFLR possible: the serve replica runs the word vector through
    the paper's enumeration algorithm (``core/device_channel.py``) so the
    resulting ``PropagatedError`` carries exact ``(slot, code)`` pairs instead
    of one blurred word for the whole batch.

    The per-slot body IS ``make_decode_step(cfg)`` — sharing it is what makes
    the serving LFLR recompute (prefill via the scalar decode step) reproduce
    the batched trajectory exactly.
    """
    return jax.vmap(make_decode_step(cfg, probe_cfg),
                    in_axes=(None, 0, 0, 0))


def _paged_slot_step(slot_step, paged):
    """Wrap the vmapped slot-decode step with page-table addressing.

    ``hybrid`` is the paged cache tree (pools + dense stacks); ``table`` the
    ``(S, max_pages)`` page table. Gather builds each slot's contiguous view
    (unmapped pages read as zeros — bit-identical to a fresh contiguous
    cache), the unchanged slot step runs on the views, and scatter writes
    them back through the table (unmapped pages dropped, so a lane that owns
    no pages writes nowhere). The in-band page probe ORs ``PAGE_FAULT`` into
    the slot's word iff the position being written is unmapped.
    """

    def step(params, hybrid, tokens, pos, table):
        views = paged.gather(hybrid, table)
        logits, views, words = slot_step(params, views, tokens, pos)
        hybrid = paged.scatter(hybrid, views, table)
        return logits, hybrid, words | paged.probe(table, pos)

    return step


# ------------------------------------------------------- tensor parallelism
#: the serving-TP mesh axis name (matches the training rules in
#: ``repro.sharding.rules`` so one mesh can serve both).
TP_AXIS = "model"


def _get_shard_map():
    try:
        return jax.shard_map            # public API on newer jax
    except AttributeError:
        from jax.experimental.shard_map import shard_map
        return shard_map


@_dataclass(frozen=True)
class TPContext:
    """Everything a window factory needs to shard itself over a "model" axis.

    ``param_specs``/``cache_specs`` are PartitionSpec pytrees describing how
    the params / serve-cache (or hybrid pool) leaves are STORED across the
    mesh (``repro.sharding.rules.param_specs`` / ``tp_storage_specs``).
    Compute stays replicated: the TP window program all-gathers every sharded
    leaf back to its full value before the unchanged window body runs — see
    :func:`_tp_window`.
    """

    mesh: Any
    param_specs: Any
    cache_specs: Any

    @property
    def size(self) -> int:
        return int(self.mesh.shape[TP_AXIS])


def _tp_gather(x, spec):
    """All-gather a storage-sharded leaf back to its full value (``tiled``
    keeps element order, so the gathered tensor is bit-equal to the
    single-device original)."""
    for i, ax in enumerate(spec):
        if ax == TP_AXIS:
            return jax.lax.all_gather(x, TP_AXIS, axis=i, tiled=True)
    return x


def _tp_slice(x, spec, size: int):
    """Inverse of :func:`_tp_gather`: slice this shard's block back out of a
    full leaf before it leaves the shard_map program."""
    for i, ax in enumerate(spec):
        if ax == TP_AXIS:
            k = x.shape[i] // size
            return jax.lax.dynamic_slice_in_dim(
                x, jax.lax.axis_index(TP_AXIS) * k, k, i)
    return x


def _tp_window(body, tp: TPContext, *, n_rest: int, words_index: int,
               n_out: int, donate: bool):
    """Wrap an un-jitted window body in a shard_map over the "model" axis.

    Storage sharded, compute replicated: params and caches arrive as their
    per-shard slices (specs from ``tp``), are all-gathered to the full
    tensors inside the program, and the UNCHANGED window body runs on them —
    so the token stream is bit-exact vs the single-device engine by
    construction (no contraction is ever split, so XLA reduction order never
    enters). The output's cache leaves are sliced back to their shard before
    leaving the program; tokens / words / feeds come out replicated.

    The returned jitted function takes one extra TRAILING argument ``inj`` of
    shape ``(tp, K, S)`` uint32 — per-shard scheduled fault words (the
    fuzzer's shard-targeted surface; zeros when idle; sharded ``P("model")``
    so each shard sees only its own ``(1, K, S)`` slice). Each shard ORs its
    slice into its local ``(K, S)`` word history *before* the cross-shard
    fold::

        words = reduce_or(all_gather(local_words | inj[shard]))

    This is the paper's error-propagation contract applied across the shards
    of one model: a word latched on ANY shard is in EVERY shard's folded
    history, so the host's deferred detection, ``(step, slot)`` attribution
    and LFLR routing behave identically no matter which shard misbehaved —
    no shard can diverge from its peers' recovery decision (the TP analogue
    of "no rank deadlocks waiting for a peer that already failed").
    """
    shard_map = _get_shard_map()
    size = tp.size

    def tp_body(params, caches, *rest_and_inj):
        *rest, inj = rest_and_inj
        pfull = jax.tree_util.tree_map(_tp_gather, params, tp.param_specs)
        cfull = jax.tree_util.tree_map(_tp_gather, caches, tp.cache_specs)
        out = list(body(pfull, cfull, *rest))
        words = out[words_index].astype(jnp.uint32) | inj[0]
        allw = jax.lax.all_gather(words, TP_AXIS)
        out[words_index] = jax.lax.reduce(allw, jnp.uint32(0),
                                          jax.lax.bitwise_or, (0,))
        out[-1] = jax.tree_util.tree_map(
            lambda x, s: _tp_slice(x, s, size), out[-1], tp.cache_specs)
        return tuple(out)

    in_specs = ((tp.param_specs, tp.cache_specs) + (P(),) * n_rest
                + (P(TP_AXIS),))
    out_specs = (P(),) * (n_out - 1) + (tp.cache_specs,)
    try:
        mapped = shard_map(tp_body, mesh=tp.mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)
    except TypeError:   # newer jax renamed the replication-check kwarg
        mapped = shard_map(tp_body, mesh=tp.mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
    return jax.jit(mapped, donate_argnums=(1,) if donate else ())


def make_decode_window(cfg: ModelConfig, probe_cfg: ProbeConfig | None = None,
                       *, window: int, donate: bool = True, paged=None,
                       tp: TPContext | None = None):
    """Pipelined decode window: K fused slot-decode steps in one device program.

    The serving hot path must not pay a host-device round trip per token — the
    paper's asynchrony contract (errors latch in-band and raise at the *wait*,
    not eagerly at every operation) applied to decoding. ``lax.scan`` runs
    ``window`` iterations of :func:`make_slot_decode_step` fully on device:
    greedy argmax is computed *inside* the scan and fed back as the next input
    token, so the token chain never touches the host; per-step per-slot error
    words are stacked into a ``(K, slots)`` history so the host can defer fault
    detection to the window boundary and still attribute a fault to its exact
    ``(step, slot)`` (LFLR replays greedy from the last committed boundary —
    deterministic, hence bit-exact).

    Signature of the returned jitted function::

      window_step(params, caches, tokens, pos)
        caches  pytree, leaves (S, ...)   donated when ``donate`` (in-place)
        tokens  (S, 1, 1) int32           input token per slot
        pos     (S,) int32                per-slot absolute position
      → (tokens (K, S) int32,             greedy token emitted per step × slot
         words  (K, S) uint32,            per-(step, slot) error-word history
         next_tok (S, 1, 1) int32,        device-resident feed for window N+1
         new caches)

    ``next_tok``/``new caches`` let the replica dispatch window N+1 *before*
    reading back window N's token block (double-buffered commit loop): the
    chain's data dependencies live entirely on device.

    With ``paged`` (a :class:`~repro.launch.paging.PagedLayout`) the caches
    argument is the hybrid pool tree and the function takes a trailing
    ``table (S, max_pages) int32`` page-table argument; gather/scatter page
    addressing runs *inside* the window scan, so the zero-sync on-device
    token chain is untouched and the produced tokens are bit-exact vs the
    contiguous layout.

    With ``tp`` (a :class:`TPContext`) the whole window is shard_mapped over
    the "model" mesh axis (:func:`_tp_window`): params/caches are passed as
    their per-shard storage slices, the function takes one extra trailing
    ``inj (tp, K, S) uint32`` per-shard injection argument, and the returned
    word history is the cross-shard OR-fold.
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    slot_step = make_slot_decode_step(cfg, probe_cfg)

    if paged is not None:
        pstep = _paged_slot_step(slot_step, paged)

        def paged_window_step(params, hybrid, tokens, pos, table):
            def body(carry, _):
                hybrid, tok, p = carry
                logits, hybrid, words = pstep(params, hybrid, tok, p, table)
                nxt = jnp.argmax(logits[:, 0, 0, :], axis=-1).astype(jnp.int32)
                return (hybrid, nxt[:, None, None], p + 1), (nxt, words)

            (hybrid, next_tok, _), (toks, words) = jax.lax.scan(
                body, (hybrid, jnp.asarray(tokens, jnp.int32),
                       jnp.asarray(pos, jnp.int32)), None, length=window)
            return toks, words.astype(jnp.uint32), next_tok, hybrid

        if tp is not None:
            return _tp_window(paged_window_step, tp, n_rest=3,
                              words_index=1, n_out=4, donate=donate)
        return jax.jit(paged_window_step,
                       donate_argnums=(1,) if donate else ())

    def window_step(params, caches, tokens, pos):
        def body(carry, _):
            caches, tok, p = carry
            logits, caches, words = slot_step(params, caches, tok, p)
            nxt = jnp.argmax(logits[:, 0, 0, :], axis=-1).astype(jnp.int32)
            return (caches, nxt[:, None, None], p + 1), (nxt, words)

        (caches, next_tok, _), (toks, words) = jax.lax.scan(
            body, (caches, jnp.asarray(tokens, jnp.int32),
                   jnp.asarray(pos, jnp.int32)), None, length=window)
        return toks, words.astype(jnp.uint32), next_tok, caches

    if tp is not None:
        return _tp_window(window_step, tp, n_rest=2, words_index=1, n_out=4,
                          donate=donate)
    return jax.jit(window_step, donate_argnums=(1,) if donate else ())


def make_prefill_decode_window(cfg: ModelConfig,
                               probe_cfg: ProbeConfig | None = None, *,
                               window: int, donate: bool = True, paged=None,
                               tp: TPContext | None = None):
    """Fused decode+prefill window: chunked prefill rides the decode scan.

    The last synchronous edge of the serving pipeline is admission / LFLR
    re-prefill: a full-length blocking prefill between windows freezes every
    healthy slot while one slot joins or recovers. This window step makes
    prefill a first-class citizen of the decode window (Sarathi-style chunking
    folded into the paper's asynchrony contract): inside the *same*
    ``lax.scan`` dispatch, decoding slots advance by greedy feedback while a
    joining/recovering slot consumes up to K tokens of its prompt chunk —
    per-slot ``jnp.where`` on the input token is the only difference from
    :func:`make_decode_window`, so a window with no chunk is computation-
    identical (bit-exact) to the decode-only window.

    Signature of the returned jitted function::

      window_step(params, caches, tokens, pos, chunk, rem)
        caches  pytree, leaves (S, ...)   donated when ``donate``
        tokens  (S, 1, 1) int32           greedy feedback feed per slot
        pos     (S,) int32                per-slot absolute position
        chunk   (K, S) int32              prompt tokens to feed per step × slot
        rem     (S,) int32                prompt-feed steps for each slot:
                                          step k consumes ``chunk[k, s]`` iff
                                          ``k < rem[s]``, else greedy feedback
      → (tokens (K, S), words (K, S), next_tok (S, 1, 1), new caches)

    Flip semantics: when a chunk exhausts a slot's prompt at step ``rem-1``,
    that step's argmax — the logits after the *last* prompt token — is the
    sequence's first generated token, and steps ``rem .. K-1`` continue greedy
    decode for it in the same window. This is exactly the computation the
    synchronous path performs (prefill logits → argmax → feed back), so the
    trajectory is bit-exact vs blocking admission; the host simply knows that
    only steps ``>= rem-1`` of that lane's token block are real. A fault
    latched during a chunk lands in the same ``(K, slots)`` word history as
    decode faults and is attributed to its exact ``(step, slot)`` — recovery
    re-queues the lane without ever blocking the host.

    With ``paged`` the caches argument is the hybrid pool tree and the
    function takes a trailing ``table`` page-table argument (see
    :func:`make_decode_window`); a chunking lane writes its prompt through
    the same gather/scatter addressing, so admission and LFLR page
    re-acquisition ride the window exactly like the contiguous engine.
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    slot_step = make_slot_decode_step(cfg, probe_cfg)

    if paged is not None:
        pstep = _paged_slot_step(slot_step, paged)

        def paged_window_step(params, hybrid, tokens, pos, chunk, rem, table):
            rem = jnp.asarray(rem, jnp.int32)

            def body(carry, xs):
                chunk_row, k = xs
                hybrid, tok, p = carry
                feed = (k < rem)[:, None, None]
                inp = jnp.where(feed, chunk_row[:, None, None], tok)
                logits, hybrid, words = pstep(params, hybrid, inp, p, table)
                nxt = jnp.argmax(logits[:, 0, 0, :], axis=-1).astype(jnp.int32)
                return (hybrid, nxt[:, None, None], p + 1), (nxt, words)

            (hybrid, next_tok, _), (toks, words) = jax.lax.scan(
                body, (hybrid, jnp.asarray(tokens, jnp.int32),
                       jnp.asarray(pos, jnp.int32)),
                (jnp.asarray(chunk, jnp.int32),
                 jnp.arange(window, dtype=jnp.int32)))
            return toks, words.astype(jnp.uint32), next_tok, hybrid

        if tp is not None:
            return _tp_window(paged_window_step, tp, n_rest=5,
                              words_index=1, n_out=4, donate=donate)
        return jax.jit(paged_window_step,
                       donate_argnums=(1,) if donate else ())

    def window_step(params, caches, tokens, pos, chunk, rem):
        rem = jnp.asarray(rem, jnp.int32)

        def body(carry, xs):
            chunk_row, k = xs
            caches, tok, p = carry
            feed = (k < rem)[:, None, None]
            inp = jnp.where(feed, chunk_row[:, None, None], tok)
            logits, caches, words = slot_step(params, caches, inp, p)
            nxt = jnp.argmax(logits[:, 0, 0, :], axis=-1).astype(jnp.int32)
            return (caches, nxt[:, None, None], p + 1), (nxt, words)

        (caches, next_tok, _), (toks, words) = jax.lax.scan(
            body, (caches, jnp.asarray(tokens, jnp.int32),
                   jnp.asarray(pos, jnp.int32)),
            (jnp.asarray(chunk, jnp.int32),
             jnp.arange(window, dtype=jnp.int32)))
        return toks, words.astype(jnp.uint32), next_tok, caches

    if tp is not None:
        return _tp_window(window_step, tp, n_rest=4, words_index=1, n_out=4,
                          donate=donate)
    return jax.jit(window_step, donate_argnums=(1,) if donate else ())


def make_speculative_decode_window(cfg: ModelConfig,
                                   probe_cfg: ProbeConfig | None = None, *,
                                   window: int, draft_len: int,
                                   draft_layers: int, donate: bool = True,
                                   paged=None, tp: TPContext | None = None):
    """Speculative decode window: draft-and-verify inside one dispatch.

    The zero-sync window (:func:`make_decode_window`) pays one full-model
    forward per emitted token. This window makes the *emission rate* exceed
    the full-model step rate while keeping the paper's asynchrony contract:
    each of the K window steps

    1. **drafts** ``D = draft_len`` tokens per slot with a shallow-exit
       self-draft — the first ``draft_layers`` layers of the *same* weights
       (reusing the same caches, hence the same paged addressing), then the
       final norm + unembedding;
    2. **verifies** all ``D+1`` positions in ONE batched full-model forward
       (:meth:`~repro.models.model.Model.verify_step`): greedy acceptance —
       draft ``d_{i+1}`` survives iff it equals the full model's argmax after
       ``d_i`` — so every emitted token is a full-model argmax and the stream
       is **token-bit-exact** vs the plain window engine, steady and faulted
       (the verify stack reproduces the decode step's arithmetic per row);
    3. records rejected drafts as the in-band, attribution-only
       ``ErrorCode.DRAFT_REJECT`` lane of the ``(K, slots)`` word history —
       a speculation miss is a *local event carried through asynchronous
       execution*, exactly like the paper's soft faults, except the host
       masks it out of the fault-raising word at the wait.

    A rejected draft's cache writes are never rolled back: full-attention
    K/V writes are positional and idempotent, and every stale entry sits at a
    position strictly beyond the accepted prefix, so it is overwritten before
    any masked read reaches it. This is why speculation requires a pure
    full-attention architecture (ring buffers and recurrent states advance
    destructively; :meth:`Model.supports_speculation`).

    Signature of the returned jitted function::

      window_step(params, caches, tokens, pos, chunk, rem[, table])
        caches  pytree, leaves (S, ...)   donated when ``donate``
        tokens  (S, 1, 1) int32           greedy feedback feed per slot
        pos     (S,) int32                per-slot absolute position
                                          (device-resident: advance is
                                          data-dependent, so the position
                                          chain must never touch the host)
        chunk   (K, D+1, S) int32         prompt tokens per step × row × slot
        rem     (S,) int32                total pending prompt tokens per
                                          slot this window (≤ K·(D+1))
      → (tokens (K, S, D+1) int32,        full-model argmaxes per step × slot
         counts (K, S) int32,             consumed positions per step × slot
                                          (prompt rows + accepted tokens,
                                          1 ≤ count ≤ D+1)
         words  (K, S) uint32,            per-(step, slot) error-word history
         next_tok (S, 1, 1) int32,        device-resident feed for window N+1
         next_pos (S,) int32,             device-resident position chain
         new caches)

    Prompt feed rides the verify width: step k of lane s force-feeds its
    next ``rem_k = clip(rem - k·(D+1), 0, D+1)`` pending prompt tokens into
    verify rows ``0 .. rem_k-1`` (forced accepted — they are given, not
    speculated), so admission/LFLR prefill advances up to D+1 tokens per
    full-model step instead of one, and speculation starts *inside* the flip
    step: rows past the prompt chain off the last prompt token's argmax.
    Only rows ``rem_k-1 .. counts[k,s]-1`` of a flip step (and every row
    ``< counts`` of later steps) carry committable tokens; the host commits
    that variable-length stream per lane.

    With ``paged`` the caches argument is the hybrid pool tree plus a
    trailing ``table`` argument; gather/scatter run once per window step
    around the draft+verify pair, and the page probe checks the pages
    covering the *accepted* prefix (a dropped write on an accepted position
    is ledger divergence; rejected positions' dropped writes are not).
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if draft_len < 1:
        raise ValueError(f"draft_len must be >= 1, got {draft_len}")
    if not 0 < draft_layers < cfg.num_layers:
        raise ValueError(
            f"draft_layers must be in [1, num_layers), got {draft_layers} "
            f"for {cfg.num_layers} layers")
    model = build_model(cfg)
    if not model.supports_speculation():
        raise ValueError(
            f"{cfg.name}: speculative decode windows require a pure "
            "full-attention, non-MoE architecture (ring buffers and "
            "recurrent states cannot absorb rejected-draft over-writes)")
    D = int(draft_len)
    # probe_cfg is accepted for signature parity with the other window
    # factories; the speculative window probes logits only (the gated
    # architectures have no recurrent state to state-probe), with the same
    # finite-check-only threshold the plain decode step applies to logits.
    probe_threshold = ProbeConfig(loss_divergence_threshold=jnp.inf)

    def _verify_one(params, cache, tokens, pos):
        logits, cache = model.verify_step(params, tokens, cache, pos)
        word = loss_probe(jnp.max(jnp.abs(logits)), probe_threshold)
        return logits, cache, word

    verify_slot = jax.vmap(_verify_one, in_axes=(None, 0, 0, 0))
    draft_chain_slot = jax.vmap(
        lambda params, cache, tok, pos, override, n_forced: model.draft_chain(
            params, tok, cache, pos, draft_layers=draft_layers, draft_len=D,
            override=override, n_forced=n_forced),
        in_axes=(None, 0, 0, 0, 0, 0))
    REJECT = jnp.uint32(int(ErrorCode.DRAFT_REJECT))

    def macro_step(params, views, tok, p, chunk_rows, k, rem):
        """One draft+verify step on (gathered) per-slot cache views.

        ``chunk_rows`` is this step's (D+1, S) prompt-feed block; ``rem`` the
        per-slot total pending prompt tokens for the whole window. Rows still
        inside the prompt are force-fed (and force-accepted); the rest chain
        off the drafter.
        """
        rem_k = jnp.clip(rem - k * (D + 1), 0, D + 1)       # (S,) prompt rows
        # shallow-exit draft chain: D greedy proposals per slot in one call,
        # each row's input overridden by the prompt while the prompt lasts.
        # The drafts' shallow-layer cache writes are recomputed and
        # overwritten by the verify pass below, so they never leak into
        # verified state.
        t0 = jnp.where((rem_k > 0)[:, None, None],
                       chunk_rows[0][:, None, None], tok)
        proposals, views = draft_chain_slot(
            params, views, t0, p, jnp.transpose(chunk_rows[1:]), rem_k)
        seq = jnp.concatenate([t0[:, 0, :], proposals[:, 0, :]],
                              axis=1)                       # (S, D+1)
        # batched full-model verify over all D+1 positions
        vlogits, views, words = verify_slot(params, views, seq[:, None, :], p)
        g = jnp.argmax(vlogits[:, 0, :, :], axis=-1).astype(jnp.int32)
        # acceptance: prompt rows are given (forced), then the leading run of
        # drafts matching the full model's own argmax chain; +1 for the bonus
        # token after the run
        rows = jnp.arange(1, D + 1, dtype=jnp.int32)[None, :]
        ok = (rows < rem_k[:, None]) | (g[:, :D] == seq[:, 1:])
        a = 1 + jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
        a = a.astype(jnp.int32)
        # forced rows (row 0 is always given: prompt or committed feedback);
        # a speculation miss latched iff any *actual* draft was rejected
        forced = jnp.maximum(rem_k, 1)
        words = words | jnp.where((forced <= D) & (a < D + 1), REJECT,
                                  jnp.uint32(0))
        next_tok = jnp.take_along_axis(g, (a - 1)[:, None], axis=1)
        return views, next_tok[:, :, None], p + a, g, a, words

    if paged is not None:

        def paged_window_step(params, hybrid, tokens, pos, chunk, rem, table):
            rem = jnp.asarray(rem, jnp.int32)

            def body(carry, xs):
                chunk_rows, k = xs
                hybrid, tok, p = carry
                views = paged.gather(hybrid, table)
                views, ntok, np_, g, a, words = macro_step(
                    params, views, tok, p, chunk_rows, k, rem)
                hybrid = paged.scatter(hybrid, views, table)
                words = words | paged.probe(table, p + a - 1)
                return (hybrid, ntok, np_), (g, a, words)

            (hybrid, next_tok, next_pos), (toks, counts, words) = jax.lax.scan(
                body, (hybrid, jnp.asarray(tokens, jnp.int32),
                       jnp.asarray(pos, jnp.int32)),
                (jnp.asarray(chunk, jnp.int32),
                 jnp.arange(window, dtype=jnp.int32)))
            return (toks, counts.astype(jnp.int32), words.astype(jnp.uint32),
                    next_tok, next_pos, hybrid)

        if tp is not None:
            return _tp_window(paged_window_step, tp, n_rest=5,
                              words_index=2, n_out=6, donate=donate)
        return jax.jit(paged_window_step,
                       donate_argnums=(1,) if donate else ())

    def window_step(params, caches, tokens, pos, chunk, rem):
        rem = jnp.asarray(rem, jnp.int32)

        def body(carry, xs):
            chunk_rows, k = xs
            caches, tok, p = carry
            caches, ntok, np_, g, a, words = macro_step(
                params, caches, tok, p, chunk_rows, k, rem)
            return (caches, ntok, np_), (g, a, words)

        (caches, next_tok, next_pos), (toks, counts, words) = jax.lax.scan(
            body, (caches, jnp.asarray(tokens, jnp.int32),
                   jnp.asarray(pos, jnp.int32)),
            (jnp.asarray(chunk, jnp.int32),
             jnp.arange(window, dtype=jnp.int32)))
        return (toks, counts.astype(jnp.int32), words.astype(jnp.uint32),
                next_tok, next_pos, caches)

    if tp is not None:
        return _tp_window(window_step, tp, n_rest=4, words_index=2, n_out=6,
                          donate=donate)
    return jax.jit(window_step, donate_argnums=(1,) if donate else ())


def make_chunked_prefill(cfg: ModelConfig,
                         probe_cfg: ProbeConfig | None = None, *,
                         chunk: int, donate: bool = False, paged=None):
    """Standalone chunked prefill: advance an *existing* cache by ≤C tokens.

    ``chunk_step(params, cache, tokens, n, start_pos)`` for ``tokens`` of
    static shape (B, C) feeds ``tokens[:, :n]`` (traced ``n``) through the
    decode step starting at ``start_pos`` → ``(last logits, cache, word)``.
    One compile serves every chunk length ≤ C.

    This is the building block the fused window embeds: chaining chunks is
    bit-identical to :func:`make_cache_prefill` over the concatenation
    (same decode step, same positions), so a prefill split across decode
    windows reproduces the one-shot trajectory exactly. Unlike
    ``make_cache_prefill`` it takes the cache as an argument — the caller owns
    allocation, which is what lets a serving lane resume a half-built cache
    chunk by chunk.

    With ``paged`` the signature becomes ``chunk_step(params, hybrid, row,
    slot, tokens, n, start_pos)``: the advanced cache lives in the shared
    pool, addressed through one slot's ``(max_pages,)`` page-table ``row``
    (writes to unmapped pages drop; the page probe latches ``PAGE_FAULT``),
    and dense (non-paged) state is read/written at ``slot`` of the stacked
    tree. Chaining paged chunks is bit-identical to the contiguous chain for
    the same reason the contiguous chain matches the one-shot prefill: same
    decode step, same positions, and the gathered view is bit-equal to the
    contiguous cache.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    step_fn = make_decode_step(cfg, probe_cfg)

    if paged is not None:

        def paged_chunk_step(params, hybrid, row, slot, tokens, n, start_pos):
            tokens = jnp.asarray(tokens, jnp.int32)
            logits0 = jnp.zeros((tokens.shape[0], 1, cfg.vocab_size),
                                jnp.float32)

            def body(i, carry):
                hybrid, word, _ = carry
                view = paged.gather_slot(hybrid, row, slot)
                tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
                p = jnp.asarray(start_pos, jnp.int32) + i
                logits, view, w = step_fn(params, view, tok, p)
                hybrid = paged.scatter_slot(hybrid, view, row, slot)
                w = w | paged.probe(row[None, :], p[None])[0]
                return (hybrid, word | w, logits.astype(jnp.float32))

            hybrid, word, logits = jax.lax.fori_loop(
                0, jnp.asarray(n, jnp.int32), body,
                (hybrid, jnp.uint32(0), logits0))
            return logits, hybrid, word

        return jax.jit(paged_chunk_step,
                       donate_argnums=(1,) if donate else ())

    def chunk_step(params, cache, tokens, n, start_pos):
        tokens = jnp.asarray(tokens, jnp.int32)
        logits0 = jnp.zeros((tokens.shape[0], 1, cfg.vocab_size), jnp.float32)

        def body(i, carry):
            cache, word, _ = carry
            tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
            logits, cache, w = step_fn(params, cache, tok,
                                       jnp.asarray(start_pos, jnp.int32) + i)
            return (cache, word | w, logits.astype(jnp.float32))

        cache, word, logits = jax.lax.fori_loop(
            0, jnp.asarray(n, jnp.int32), body,
            (cache, jnp.uint32(0), logits0))
        return logits, cache, word

    return jax.jit(chunk_step, donate_argnums=(1,) if donate else ())


def make_cache_prefill(cfg: ModelConfig, probe_cfg: ProbeConfig | None = None,
                       *, fused: bool = False, paged=None,
                       donate: bool = False):
    """Cache-producing prefill built by reusing the decode step.

    Returns ``prefill(params, tokens, max_len, start_pos=0)`` for ``tokens``
    of shape (B, S) → ``(last-position logits, cache, combined error word)``.

    This is the recompute path of serving LFLR: re-running it over
    prompt + generated tokens rebuilds a poisoned sequence's state exactly
    (greedy decode is deterministic), so recovery never restarts the request.

    Two implementations, both token-by-token through the *same* decode step
    (sharing the step is what makes the LFLR recompute reproduce the batched
    trajectory exactly):

    * ``fused=False`` — a host loop of S jitted step dispatches (the PR-1
      path: simple, one compile, but S dispatch overheads per prefill);
    * ``fused=True``  — one jitted ``lax.fori_loop`` whose trip count is the
      *traced* real length: tokens are padded to the (static) cache capacity
      so one compile serves every prompt/recompute length, but only the real
      steps execute — no wasted padded iterations, no masking, and the body
      is the same decode step, so the result is bit-identical to the loop.
      This is the serving window engine's admission/LFLR path: one dispatch
      per prefill instead of S.

    With ``paged`` the signature becomes ``prefill(params, hybrid, row, slot,
    tokens, start_pos=0)`` (``fused`` implied): the rebuilt cache is written
    straight into the slot's pool pages through its page-table ``row``, after
    an in-program scrub of those pages and a fresh reset of the slot's dense
    state — the whole blocking re-prefill is one dispatch and never leaves
    stale (possibly poisoned) bytes behind in a recycled page.
    """
    model = build_model(cfg)
    step_fn = make_decode_step(cfg, probe_cfg)

    if paged is not None:
        # donate: the hybrid argument is the FULL multi-slot pool — an
        # out-of-place update here would transiently double the very HBM the
        # paged layout exists to save (the caller must rebind its pool to the
        # returned tree before any retry)
        chunked = make_chunked_prefill(cfg, probe_cfg, chunk=paged.max_len,
                                       paged=paged, donate=donate)

        @functools.partial(jax.jit, donate_argnums=(0,) if donate else ())
        def fresh_slot(hybrid, row, slot):
            hybrid = paged.scrub(hybrid, row)
            return paged.reset_slot(hybrid, model.init_cache(1, paged.max_len),
                                    slot)

        def prefill(params, hybrid, row, slot, tokens, start_pos: int = 0):
            tokens = jnp.asarray(tokens, jnp.int32)
            if tokens.ndim != 2 or tokens.shape[1] == 0:
                raise ValueError(f"tokens must be (B, S>0), got {tokens.shape}")
            _, S = tokens.shape
            if S > paged.max_len:
                raise ValueError(
                    f"prompt of {S} tokens exceeds capacity {paged.max_len}")
            hybrid = fresh_slot(hybrid, jnp.asarray(row, jnp.int32),
                                jnp.int32(slot))
            padded = jnp.pad(tokens, ((0, 0), (0, paged.max_len - S)))
            logits, hybrid, word = chunked(
                params, hybrid, jnp.asarray(row, jnp.int32), jnp.int32(slot),
                padded, jnp.int32(S), jnp.int32(start_pos))
            return logits, hybrid, word

        return prefill

    if not fused:
        step = jax.jit(step_fn)

        def prefill(params, tokens, max_len: int, start_pos: int = 0):
            tokens = jnp.asarray(tokens, jnp.int32)
            if tokens.ndim != 2 or tokens.shape[1] == 0:
                raise ValueError(f"tokens must be (B, S>0), got {tokens.shape}")
            _, S = tokens.shape
            cache = model.init_cache(tokens.shape[0], max_len)
            word = jnp.uint32(0)
            logits = None
            for i in range(S):
                logits, cache, w = step(params, cache, tokens[:, i:i + 1],
                                        jnp.int32(start_pos + i))
                word = word | w
            return logits, cache, word

        return prefill

    @functools.partial(jax.jit, static_argnums=(2,))
    def run(params, tokens_padded, max_len: int, n, start_pos):
        B, _ = tokens_padded.shape
        cache0 = model.init_cache(B, max_len)
        logits0 = jnp.zeros((B, 1, cfg.vocab_size), jnp.float32)

        def body(i, carry):
            cache, word, _ = carry
            tok = jax.lax.dynamic_slice_in_dim(tokens_padded, i, 1, axis=1)
            logits, cache, w = step_fn(params, cache, tok, start_pos + i)
            return (cache, word | w, logits.astype(jnp.float32))

        return jax.lax.fori_loop(0, n, body,
                                 (cache0, jnp.uint32(0), logits0))

    def prefill(params, tokens, max_len: int, start_pos: int = 0):
        tokens = jnp.asarray(tokens, jnp.int32)
        if tokens.ndim != 2 or tokens.shape[1] == 0:
            raise ValueError(f"tokens must be (B, S>0), got {tokens.shape}")
        _, S = tokens.shape
        if S > max_len:
            raise ValueError(f"prompt of {S} tokens exceeds capacity {max_len}")
        padded = jnp.pad(tokens, ((0, 0), (0, max_len - S)))
        cache, word, last = run(params, padded, int(max_len), jnp.int32(S),
                                jnp.int32(start_pos))
        return last, cache, word

    return prefill


def _recurrent_states(cache) -> list:
    out = []

    def visit(path, leaf):
        keys = [getattr(k, "key", None) for k in path]
        if any(k in ("ssm", "h") for k in keys):
            out.append(leaf)
        return leaf

    jax.tree_util.tree_map_with_path(visit, cache)
    return out


def make_reset_opt_fn(cfg: ModelConfig):
    """Paper use case 2: optimizer-moment reset + lr decay ('solver restart')."""

    @jax.jit
    def reset(state, lr_scale):
        return {"params": state["params"],
                "opt": reset_moments(state["opt"]),
                "step": state["step"],
                "lr_scale": state["lr_scale"] * lr_scale}

    return reset


# ------------------------------------------------------------------ input specs
def _tok(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for one global batch (train / prefill)."""
    B, S = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {"labels": _tok((B, S))}
    if cfg.family == "audio":
        batch["inputs_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                      jnp.bfloat16)
    else:
        batch["tokens"] = _tok((B, S))
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.img_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def state_specs(cfg: ModelConfig) -> dict:
    model = build_model(cfg)
    params = model.param_shapes()
    opt = jax.eval_shape(init_opt_state, params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "lr_scale": jax.ShapeDtypeStruct((), jnp.float32)}


def state_shardings(cfg: ModelConfig, mesh) -> dict:
    specs = state_specs(cfg)
    return {
        "params": param_shardings(specs["params"], mesh),
        "opt": {k: moment_shardings(specs["params"], mesh)
                for k in ("m", "v")},
        "step": NamedSharding(mesh, P()),
        "lr_scale": NamedSharding(mesh, P()),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                kind: str | None = None, perf: PerfOptions = BASELINE):
    """(args, in_shardings) for the cell's step function.

    train  → (state, batch, inject)
    prefill→ (params, batch)
    decode → (params, cache, token, pos)
    """
    kind = kind or shape.kind
    repl = NamedSharding(mesh, P())
    if kind == "train":
        st = state_specs(cfg)
        batch = batch_specs(cfg, shape)
        args = (st, batch, jax.ShapeDtypeStruct((), jnp.uint32))
        shardings = (state_shardings(cfg, mesh), batch_shardings(batch, mesh),
                     repl)
        return args, shardings
    if kind == "prefill":
        st = state_specs(cfg)["params"]
        batch = batch_specs(cfg, shape)
        return (st, batch), (param_shardings(st, mesh),
                             batch_shardings(batch, mesh))
    if kind == "decode":
        model = build_model(cfg)
        st = state_specs(cfg)["params"]
        B = shape.global_batch
        cache = model.cache_shapes(B, shape.seq_len)
        token = _tok((B, 1))
        shard_seq = shape.name == "long_500k"
        args = (st, cache, token, jax.ShapeDtypeStruct((), jnp.int32))
        shardings = (param_shardings(st, mesh),
                     cache_shardings(cache, mesh, shard_seq=shard_seq,
                                     seq_over_model=perf.cache_seq_model),
                     batch_shardings({"t": token}, mesh)["t"], repl)
        return args, shardings
    raise ValueError(kind)


def make_step_for(cfg: ModelConfig, shape: ShapeConfig, *, impl: str = "auto",
                  perf: PerfOptions = BASELINE):
    if shape.kind == "train":
        return make_train_step(cfg, impl=impl, perf=perf)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, impl=impl)
    return make_decode_step(cfg)
