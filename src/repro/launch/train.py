"""End-to-end resilient training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --steps 50 \
        --smoke --inject "12:nan_grad,25:spike_loss"

Wires together: model + optimizer + deterministic pipeline + the paper's
technique (in-band error channel → DeviceFuture → RecoveryPolicy) + async
checkpointing. ``--smoke`` uses the reduced config (CPU-runnable); the full
configs are exercised via the dry-run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, smoke_config
from ..core import ExecutorConfig, FaultSchedule, FaultSpec, ResilientExecutor
from ..core.detect import ProbeConfig
from ..core.recovery import RecoveryPolicy
from ..checkpoint import Checkpointer
from ..data.pipeline import DataIterator, PipelineConfig
from ..optim import AdamWConfig, init_opt_state
from ..models import build_model
from .steps import make_reset_opt_fn, make_train_step


def parse_inject(spec: str) -> FaultSchedule:
    specs = []
    if spec:
        for part in spec.split(","):
            step_s, kind = part.split(":")
            specs.append(FaultSpec(step=int(step_s), kind=kind))
    return FaultSchedule(specs)


def build_train_setup(cfg, *, batch_size: int, seq_len: int, seed: int = 0,
                      lr: float = 3e-4, total_steps: int = 1000):
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(total_steps // 20, 5),
                          total_steps=total_steps)
    probe_cfg = ProbeConfig(loss_divergence_threshold=50.0)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, probe_cfg),
                      donate_argnums=())
    params = model.init(jax.random.PRNGKey(seed))
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.int32(0), "lr_scale": jnp.float32(1.0)}
    pipe = DataIterator(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, batch_size=batch_size,
        seed=seed, family=cfg.family if cfg.family in ("audio", "vlm") else "lm",
        d_model=cfg.d_model, img_tokens=cfg.img_tokens))
    return model, step_fn, state, pipe, opt_cfg


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--inject", default="", help="e.g. '12:nan_grad,25:spike_loss'")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model, step_fn, state, pipe, opt_cfg = build_train_setup(
        cfg, batch_size=args.batch, seq_len=args.seq, total_steps=args.steps)

    ckpt = Checkpointer(args.ckpt_dir)
    executor = ResilientExecutor(
        step_fn,
        policy=RecoveryPolicy(can_shrink=False),
        config=ExecutorConfig(good_state_interval=10,
                              checkpoint_interval=args.ckpt_every),
        checkpointer=ckpt,
        reset_opt_fn=make_reset_opt_fn(cfg),
    )
    faults = parse_inject(args.inject)

    t0 = time.monotonic()
    state, log = executor.run(state, pipe, args.steps, faults=faults)
    dt = time.monotonic() - t0
    ok = [e for e in log.events if e.kind == "ok"]
    fl = log.faults()
    print(f"\narch={cfg.name} steps={args.steps} wall={dt:.1f}s "
          f"ok={len(ok)} faults={len(fl)}")
    for e in fl:
        print(f"  step {e.step}: code={e.code:#x} action={e.action} ({e.detail})")
    ckpt.wait()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
