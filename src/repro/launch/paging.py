"""Paged KV/state pool: device-resident page table over a shared page pool.

The serving cache layout of PR 1–3 gives every decode slot one contiguous
``max_len`` block, so a 16-token chat reserves as much HBM as a 2k-token
prompt — concurrency is capped by the *worst-case* sequence, not the actual
traffic. This module applies the paper's core move — scope state to the
smallest recoverable unit — to cache memory (vLLM-style paging):

* leaves of the per-slot cache whose capacity dimension equals ``max_len``
  (full-attention K/V) are pooled into ``(num_pages, page_size, ...)`` arrays
  shared by all slots;
* a ``(slots, max_pages)`` int32 **page table** maps each slot's logical page
  to a physical page; unassigned entries hold the out-of-range sentinel
  ``num_pages``;
* ring buffers (sliding-window KV) and O(1) recurrent states (SSM / RG-LRU)
  stay densely stacked per slot — paging them buys nothing, every entry is
  always live.

Addressing is gather/scatter with *explicit* out-of-bounds semantics, which
is what makes the paged engine token-bit-exact vs the contiguous layout and
fault-safe against cross-slot pollution:

* **gather** uses ``pool.at[table].get(mode="fill", fill_value=0)`` — an
  unassigned logical page reads as zeros, exactly the content of a freshly
  reset contiguous cache, so attention over the gathered view computes the
  same bits;
* **scatter** uses ``pool.at[table].set(..., mode="drop")`` — a lane that
  owns no page (a deferred or just-reclaimed prefill lane) writes *nowhere*:
  a poisoned lane's NaNs can never leak into a page another slot might read;
* the **page probe** (:meth:`PagedLayout.probe`) checks in-band that the page
  a step writes to is mapped, OR-ing :data:`~repro.core.errors.ErrorCode`
  ``PAGE_FAULT`` into the slot's error word — ledger corruption surfaces as
  an exception at the wait, like every other fault in this codebase, and the
  LFLR re-queue (free + re-acquire pages) repairs it.

Ownership (free list, per-slot ledger, watermark admission, eviction) is host
logic and lives in :class:`repro.serve.scheduler.PageAllocator`; this module
is the device side only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.errors import ErrorCode


def pages_for(n_tokens: int, page_size: int) -> int:
    """Physical pages needed to hold ``n_tokens`` cache positions."""
    return -(-max(int(n_tokens), 0) // page_size)


@dataclass(frozen=True)
class _LeafSpec:
    cap_axis: int        # capacity axis in the *per-slot* leaf (== ndim - 3)
    page_shape: tuple    # per-page shape (per-slot shape with cap → page_size)
    dtype: Any


class PagedLayout:
    """Device-side layout: which cache leaves are pooled, and how to address them.

    Built from one per-slot (batch=1) cache tree. A leaf is **paged** iff it
    is a K/V buffer (dict key ``"k"``/``"v"``) whose capacity axis (always
    ``ndim - 3`` for KV layouts ``(..., cap, n_kv, head_dim)``) has size
    ``max_len`` — full-attention caches. Sliding-window rings
    (``cap < max_len``) and non-KV state stay dense.
    """

    def __init__(self, slot_cache: Any, max_len: int, *, page_size: int,
                 num_pages: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if max_len % page_size != 0:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of page_size "
                f"({page_size}) so the gathered view is exactly the "
                "contiguous layout")
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.max_len = int(max_len)
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.max_pages = max_len // page_size
        self.sentinel = self.num_pages           # out-of-range ⇒ fill/drop
        # positions one sequence can ever hold state for: a pool smaller than
        # max_len bounds every lane (admission must clamp to this too) — and
        # growth/probing past it would demand pages that cannot exist
        self.capacity_tokens = min(self.max_len,
                                   self.num_pages * self.page_size)
        shapes = jax.eval_shape(lambda t: t, slot_cache)
        self._specs: dict[str, _LeafSpec] = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            if not self._leaf_is_paged(path, leaf):
                continue
            c = leaf.ndim - 3
            page_shape = (leaf.shape[:c] + (self.page_size,)
                          + leaf.shape[c + 1:])
            self._specs[jax.tree_util.keystr(path)] = _LeafSpec(
                cap_axis=c, page_shape=page_shape, dtype=leaf.dtype)

    # ------------------------------------------------------------ classification
    def _leaf_is_paged(self, path, leaf) -> bool:
        keys = [getattr(k, "key", None) for k in path]
        return (keys and keys[-1] in ("k", "v") and leaf.ndim >= 3
                and leaf.shape[leaf.ndim - 3] == self.max_len)

    @property
    def has_paged_leaves(self) -> bool:
        return bool(self._specs)

    def _spec(self, path) -> Optional[_LeafSpec]:
        return self._specs.get(jax.tree_util.keystr(path))

    def is_paged_path(self, path) -> bool:
        return self._spec(path) is not None

    # ----------------------------------------------------------------- building
    def init_hybrid(self, slot_cache: Any, num_slots: int) -> Any:
        """Hybrid cache tree: paged leaves → ``(num_pages, *page_shape)``
        pools, dense leaves → ``(num_slots, *per_slot)`` stacks (the PR-1
        layout). Same tree structure as the contiguous stacked caches."""

        def build(path, leaf):
            spec = self._spec(path)
            if spec is not None:
                return jnp.zeros((self.num_pages, *spec.page_shape),
                                 spec.dtype)
            return jnp.broadcast_to(leaf[None],
                                    (num_slots, *leaf.shape)).copy()

        return jax.tree_util.tree_map_with_path(build, slot_cache)

    def empty_table(self, num_slots: int):
        import numpy as np
        return np.full((num_slots, self.max_pages), self.sentinel, np.int32)

    # ----------------------------------------------------------- gather/scatter
    def gather(self, hybrid: Any, table) -> Any:
        """Hybrid tree + ``(S, max_pages)`` table → per-slot stacked view tree
        (identical in shape and **bits** to the contiguous layout: unassigned
        pages read as zeros)."""

        def g(path, leaf):
            spec = self._spec(path)
            if spec is None:
                return leaf
            v = leaf.at[table].get(mode="fill", fill_value=0)
            v = jnp.moveaxis(v, 1, spec.cap_axis + 1)    # (S, ..., M, page, ..)
            s = v.shape
            c = spec.cap_axis + 1
            return v.reshape(*s[:c], s[c] * s[c + 1], *s[c + 2:])

        return jax.tree_util.tree_map_with_path(g, hybrid)

    def scatter(self, hybrid: Any, views: Any, table) -> Any:
        """Write per-slot views back through the page table. Entries mapped to
        the sentinel are dropped — an unmapped lane writes nowhere."""
        flat_h, treedef = jax.tree_util.tree_flatten_with_path(hybrid)
        flat_v = jax.tree_util.tree_leaves(views)

        out = []
        for (path, leaf), view in zip(flat_h, flat_v):
            spec = self._spec(path)
            if spec is None:
                out.append(view)
                continue
            c = spec.cap_axis + 1
            s = view.shape
            v = view.reshape(*s[:c], self.max_pages, self.page_size,
                             *s[c + 1:])
            v = jnp.moveaxis(v, c, 1)                    # (S, M, *page_shape)
            out.append(leaf.at[table].set(v.astype(leaf.dtype), mode="drop"))
        return jax.tree_util.tree_unflatten(treedef, out)

    def gather_slot(self, hybrid: Any, row, slot) -> Any:
        """Single-slot view: ``row`` is that slot's ``(max_pages,)`` table row."""

        def g(path, leaf):
            spec = self._spec(path)
            if spec is None:
                return leaf[slot]
            v = leaf.at[row].get(mode="fill", fill_value=0)  # (M, *page_shape)
            v = jnp.moveaxis(v, 0, spec.cap_axis)
            s = v.shape
            c = spec.cap_axis
            return v.reshape(*s[:c], s[c] * s[c + 1], *s[c + 2:])

        return jax.tree_util.tree_map_with_path(g, hybrid)

    def scatter_slot(self, hybrid: Any, view: Any, row, slot) -> Any:
        flat_h, treedef = jax.tree_util.tree_flatten_with_path(hybrid)
        flat_v = jax.tree_util.tree_leaves(view)
        out = []
        for (path, leaf), v in zip(flat_h, flat_v):
            spec = self._spec(path)
            if spec is None:
                out.append(leaf.at[slot].set(v.astype(leaf.dtype)))
                continue
            c = spec.cap_axis
            s = v.shape
            v = v.reshape(*s[:c], self.max_pages, self.page_size, *s[c + 1:])
            v = jnp.moveaxis(v, c, 0)
            out.append(leaf.at[row].set(v.astype(leaf.dtype), mode="drop"))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------- probes
    def probe(self, table, pos) -> jax.Array:
        """In-band page-ownership probe: per-slot PAGE_FAULT word iff *any*
        logical page up to (and including) the one holding the slot's current
        write position is unmapped — an unmapped write page drops the new
        KV entry, and an unmapped earlier page silently reads as zeros, so
        both are table/ledger divergence that must surface at the wait
        rather than corrupt the stream. Free / deferred lanes are masked out
        by the caller's enumeration mask, like every other per-slot word."""
        pos = jnp.asarray(pos, jnp.int32)
        if not self._specs:
            return jnp.zeros(pos.shape, jnp.uint32)
        # clamp to pool capacity: positions past it are over-decode steps
        # whose tokens are discarded at retirement — their dropped writes are
        # not ledger divergence (growth never maps pages that can't exist)
        lp = jnp.clip(pos, 0, self.capacity_tokens - 1) // self.page_size
        live = jnp.arange(self.max_pages)[None, :] <= lp[:, None]
        unmapped = (table < 0) | (table >= self.num_pages)
        bad = jnp.any(live & unmapped, axis=1)
        return jnp.where(bad, jnp.uint32(int(ErrorCode.PAGE_FAULT)),
                         jnp.uint32(0))

    # -------------------------------------------------------------- maintenance
    def scrub(self, hybrid: Any, page_ids) -> Any:
        """Zero the given physical pages in every pool (sentinel entries are
        dropped). This is the paged analogue of the fused fresh-cache reset:
        it rides the device chain at (re)allocation, so a page recycled from
        a faulted or evicted sequence can never leak stale state — including
        NaNs — to its next owner."""
        page_ids = jnp.asarray(page_ids, jnp.int32)

        def s(path, leaf):
            if self._spec(path) is None:
                return leaf
            return leaf.at[page_ids].set(jnp.zeros((), leaf.dtype),
                                         mode="drop")

        return jax.tree_util.tree_map_with_path(s, hybrid)

    def reset_slot(self, hybrid: Any, fresh: Any, slot) -> Any:
        """Reset one slot's *dense* leaves to the fresh per-slot cache; pools
        are untouched (their reset is :meth:`scrub` of the slot's pages). The
        pair is the paged analogue of the contiguous fused cache reset that
        the overlapped admission/LFLR lane rides on the device chain."""

        def r(path, leaf, f):
            if self._spec(path) is not None:
                return leaf
            return leaf.at[slot].set(f.astype(leaf.dtype))

        return jax.tree_util.tree_map_with_path(r, hybrid, fresh)

    # ------------------------------------------------------ tensor parallelism
    def tp_storage_specs(self, hybrid: Any, mesh, *, axis: str = "model"):
        """TP *storage* PartitionSpecs for a hybrid pool tree.

        Pool leaves ``(num_pages, ..., page_size, ...)`` shard a trailing
        feature dim only — never the page dim (dim 0) nor the page-size dim:
        the page address space stays whole on every shard, so all shards are
        addressed through ONE logical (replicated) page table and each holds
        its feature-slice of every page ("per-shard KV partitions sharing one
        logical page table"). Dense ``(num_slots, ...)`` stacks follow the
        plain serve-cache rule (:func:`repro.sharding.rules.tp_storage_specs`,
        floor past the slot dim). Compute stays replicated — the TP window
        all-gathers the pool back to full before gather/scatter addressing
        runs, so paged TP is bit-exact vs single-device paged by the same
        argument as the contiguous engine.
        """
        from ..sharding.rules import tp_leaf_spec
        size = mesh.shape[axis]

        def spec(path, leaf):
            ls = self._spec(path)
            # pool leaf: page dim 0, page_size at cap_axis + 1 — both off
            # limits; dense stack: only the slot dim 0 is off limits
            floor = (ls.cap_axis + 2) if ls is not None else 1
            return tp_leaf_spec(leaf.shape, size, axis, floor)

        return jax.tree_util.tree_map_with_path(spec, hybrid)

    # -------------------------------------------------------------- accounting
    def page_bytes(self) -> int:
        """HBM bytes of ONE physical page across all pooled leaves."""
        total = 0
        for spec in self._specs.values():
            n = 1
            for d in spec.page_shape:
                n *= d
            total += n * jnp.dtype(spec.dtype).itemsize
        return total

    def pool_bytes(self) -> int:
        return self.num_pages * self.page_bytes()

    def contiguous_paged_bytes_per_slot(self) -> int:
        """Bytes ONE slot's paged leaves occupy in the contiguous layout
        (= max_pages pages) — the equal-HBM-budget comparison baseline."""
        return self.max_pages * self.page_bytes()
