"""Elastic scaling: continue training after losing ranks/devices.

Two layers, matching the executor layer of the DESIGN.md §2 layer map:

1. **Single-controller re-mesh** (``shrink_remesh``): after a (simulated) device
   loss, rebuild a smaller mesh, re-derive shardings from the same logical rules
   and ``device_put`` the surviving state onto it. With a data-axis shrink the
   global batch per step drops; the deterministic pipeline reshards by changing
   its (num_shards, shard) only.

2. **Multi-controller elastic trainer** (``ElasticTrainer``): the paper's full
   choreography on the thread-rank runtime — data-parallel ranks, gradient
   all-reduce through ``Comm``/``Future`` (waits raise the paper's exceptions),
   soft faults propagated via ``signal_error``, hard faults (rank kill) detected
   by ULFM, survivors ``shrink``, restore the lost shard's contribution from the
   buddy store, re-partition the stream, and keep training. This is use case 1
   (LFLR) + use case 3 (rollback fallback) of the paper, driving real training.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import BuddyStore
from ..core import (
    Comm,
    CommCorruptedError,
    ErrorCode,
    PropagatedError,
    initialize,
    run_ranks,
)
from ..core.faults import FaultSchedule, apply_host_fault
from ..sharding import batch_shardings, moment_shardings, param_shardings


# ------------------------------------------------------------ 1. re-mesh layer
def shrink_remesh(state, new_mesh, *, donate: bool = False):
    """Re-shard a train state onto a smaller mesh using the same logical rules."""
    p_shard = param_shardings(state["params"], new_mesh)
    m_shard = moment_shardings(state["params"], new_mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(new_mesh, P())
    new_state = {
        "params": jax.device_put(state["params"], p_shard),
        "opt": {"m": jax.device_put(state["opt"]["m"], m_shard),
                "v": jax.device_put(state["opt"]["v"], m_shard)},
        "step": jax.device_put(state["step"], repl),
        "lr_scale": jax.device_put(state["lr_scale"], repl),
    }
    return new_state


# ------------------------------------------- 2. multi-controller elastic trainer
@dataclass
class ElasticResult:
    rank: int
    steps_done: int = 0
    final_loss: float = float("nan")
    world_sizes: list = field(default_factory=list)
    events: list = field(default_factory=list)
    weights: Optional[np.ndarray] = None


def _make_local_step(dim: int, lr: float):
    """Tiny data-parallel model (linear regression) — the protocol under test is
    the communication/recovery choreography, not the model."""

    @jax.jit
    def local_grad(w, x, y):
        pred = x @ w
        loss = jnp.mean((pred - y) ** 2)
        g = jax.grad(lambda w_: jnp.mean((x @ w_ - y) ** 2))(w)
        return loss, g

    return local_grad


def elastic_train(nranks: int, steps: int, *, dim: int = 16, lr: float = 0.1,
                  faults: FaultSchedule | None = None, seed: int = 0,
                  timeout: float = 30.0) -> list:
    """Run the elastic trainer on ``nranks`` simulated hosts; returns per-rank
    ElasticResult. Survivors finish all ``steps`` even if ranks die."""
    faults = faults or FaultSchedule()
    buddies = BuddyStore(nranks)

    # ground-truth weights for the regression stream
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal((dim, 1)).astype(np.float32)

    def rank_fn(ctx):
        inst = initialize(ctx, default_timeout=timeout)
        comm = inst.comm_world()
        res = ElasticResult(rank=ctx.rank)
        local_grad = _make_local_step(dim, lr)
        w = jnp.zeros((dim, 1), jnp.float32)
        step = 0
        while step < steps:
            res.world_sizes.append(comm.size)
            # host-level faults for this rank at this step
            for spec in faults.at(step, ctx.rank):
                if spec.kind == "kill":
                    apply_host_fault(spec, ctx)     # never returns
            # deterministic per-(rank, step) batch over the *current* membership
            bg = np.random.default_rng(1000 * step + comm.rank)
            x = bg.standard_normal((8, dim)).astype(np.float32)
            y = x @ w_true
            loss, g = local_grad(w, jnp.asarray(x), jnp.asarray(y))
            code = 0
            for spec in faults.at(step, ctx.rank):
                if spec.kind == "nan_grad":
                    g = jnp.full_like(g, jnp.nan)
            if not bool(jnp.all(jnp.isfinite(g))):
                code = int(ErrorCode.NONFINITE_GRAD)
            try:
                if code:
                    comm.signal_error(code)     # raises PropagatedError locally
                fut = comm.all_reduce(np.asarray(g, np.float64), op="sum")
                g_sum = fut.wait()
                w = w - lr * jnp.asarray(g_sum, jnp.float32) / comm.size
                step += 1
                res.steps_done += 1
                if step % 5 == 0:
                    buddies.push(comm.rank, step, {"w": w})
            except PropagatedError as e:
                # LFLR: skip the poisoned update everywhere, keep going
                res.events.append(("propagated", step, [x.rank for x in e.errors]))
                step += 1
                continue
            except CommCorruptedError:
                # hard fault: shrink, recover from buddy coverage, continue
                comm.shrink_to_survivors()
                got = None
                for r in buddies.ranks_covered():
                    got = buddies.recover(r)
                    if got is not None:
                        break
                if got is not None:
                    ck_step, shard = got
                    w = jnp.asarray(shard["w"])
                    step = ck_step
                res.events.append(("shrink", step, comm.size))
                continue
        res.final_loss = float(loss)
        res.weights = np.asarray(w)
        return res

    results = run_ranks(nranks, rank_fn, ulfm=True, join_timeout=timeout * 4)
    return results
