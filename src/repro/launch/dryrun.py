import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   This module is the ONLY place the 512 placeholder devices are forced.

"""Multi-pod dry-run: lower + compile every (arch × input-shape) cell on the
production meshes and record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells, both meshes
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multipod-only --out artifacts/dryrun

Success of ``lower().compile()`` for every cell on the 16×16 (single-pod) and
2×16×16 (multi-pod) meshes is deliverable (e); the JSON artifacts feed §Roofline.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import SHAPES, ARCHS, cell_skip_reason, get_config
from ..roofline.analysis import RooflineTerms, model_flops_for
from ..roofline.hlo import estimate_hbm_bytes, op_histogram, parse_collectives
from .mesh import make_production_mesh
from .steps import BASELINE, PerfOptions, input_specs, make_step_for


def _compile_variant(cfg, shape, mesh, impl, *, inner_unroll: bool = False,
                     perf: PerfOptions = BASELINE):
    """Compile one config variant; return (compiled, cost, coll, hlo)."""
    from jax.sharding import PartitionSpec as P

    from ..models import attention as attention_mod
    from ..models import moe as moe_mod
    from ..models import transformer as transformer_mod

    step = make_step_for(cfg, shape, impl=impl, perf=perf)
    args, shardings = input_specs(cfg, shape, mesh, perf=perf)
    donate = (0,) if shape.kind == "train" else (
        (1,) if shape.kind == "decode" else ())
    from . import steps as steps_mod

    prev = attention_mod.INNER_UNROLL
    prev_spec = transformer_mod.ACTIVATION_SPEC
    prev_espec = moe_mod.EXPERT_SPEC
    prev_mb = steps_mod.MB_UNROLL
    attention_mod.INNER_UNROLL = inner_unroll
    steps_mod.MB_UNROLL = inner_unroll
    if perf.seq_shard:
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        transformer_mod.ACTIVATION_SPEC = P(dp, "model", None)
    if perf.ep_constraint:
        moe_mod.EXPERT_SPEC = P(None, "model", None, None)
    try:
        with mesh:
            jitted = jax.jit(step, in_shardings=shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
    finally:
        attention_mod.INNER_UNROLL = prev
        transformer_mod.ACTIVATION_SPEC = prev_spec
        moe_mod.EXPERT_SPEC = prev_espec
        steps_mod.MB_UNROLL = prev_mb
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    return compiled, cost, coll, hlo


def _corrected_costs(cfg, shape, mesh, impl, full_hlo, perf=BASELINE):
    """Exact per-step costs despite two CPU-backend artifacts:

    1. ``cost_analysis`` counts a ``lax.scan``/while body ONCE regardless of
       trip count (verified empirically). FLOPs are linear in depth, so two
       *unrolled* shallow variants (1 and 2 periods + remainder, inner scans
       unrolled) give an exact per-period delta:
       flops = v1 + (v2 − v1) × (num_periods − 1).
    2. ``bytes accessed`` sums ops *inside* fusion computations (VMEM/register
       traffic on a real TPU). HBM bytes and collective bytes are instead
       measured on the FULL compiled module with the fusion-boundary,
       while-trip-count-aware analyzer — no extrapolation (which CSE across
       unrolled microbatches would otherwise distort).

    Returns (flops, hbm_bytes, coll_bytes, coll_by_kind).
    """
    np_ = cfg.num_periods
    rem = len(cfg.remainder_layers)
    cfg1 = cfg.replace(num_layers=cfg.period + rem, scan_layers=False)
    cfg2 = cfg.replace(num_layers=2 * cfg.period + rem, scan_layers=False)
    _, c1, _, _ = _compile_variant(cfg1, shape, mesh, impl, inner_unroll=True,
                                   perf=perf)
    _, c2, _, _ = _compile_variant(cfg2, shape, mesh, impl, inner_unroll=True,
                                   perf=perf)
    f1, f2 = float(c1.get("flops", 0)), float(c2.get("flops", 0))
    flops = f1 + (f2 - f1) * (np_ - 1)
    est = estimate_hbm_bytes(full_hlo)
    return (flops, float(est["total_bytes"]), float(est["collective_total"]),
            est["collective_bytes_by_kind"])


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             impl: str = "auto", keep_hlo: bool = False,
             config_override=None, perf: PerfOptions = BASELINE) -> dict:
    """Lower + compile one cell; returns the artifact dict."""
    cfg = config_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "ok": False,
    }
    t0 = time.monotonic()
    try:
        compiled, cost, coll, hlo = _compile_variant(cfg, shape, mesh, impl,
                                                      perf=perf)
        t_compile = time.monotonic() - t0
        mem = compiled.memory_analysis()
        flops, bytes_, coll_bytes, coll_by_kind = _corrected_costs(
            cfg, shape, mesh, impl, hlo, perf=perf)
        terms = RooflineTerms(
            chips=chips,
            hlo_flops_per_device=flops,
            hlo_bytes_per_device=bytes_,
            collective_bytes_per_device=coll_bytes,
            model_flops=model_flops_for(cfg, shape),
        )
        rec.update(
            ok=True,
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_live_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
            },
            cost_raw={k: v for k, v in cost.items()
                      if k in ("flops", "bytes accessed", "transcendentals")},
            collectives_raw=coll.to_dict(),
            collectives_by_kind_corrected=coll_by_kind,
            roofline=terms.to_dict(),
            hlo_ops={k: v for k, v in list(op_histogram(hlo).items())[:20]},
        )
        if keep_hlo:
            rec["hlo_text"] = hlo
    except Exception as e:  # noqa: BLE001 - a failing cell is a reported bug
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.monotonic() - t0, 2)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--impl", default="auto")
    ap.add_argument("--include-skipped", action="store_true",
                    help="attempt cells that are documented skips")
    ap.add_argument("--perf", default="",
                    help="perf levers, e.g. 'mb=8,ce=2048,sp=1,cacheseq=1'")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if not args.multipod_only:
        meshes.append(False)
    if not args.singlepod_only:
        meshes.append(True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            reason = cell_skip_reason(arch, shape)
            if reason and not args.include_skipped:
                for mp in meshes:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "ok": True, "skipped": reason}
                    _write(out_dir, rec)
                print(f"SKIP  {arch:24s} {shape:12s} ({reason})", flush=True)
                continue
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                rec = run_cell(arch, shape, multi_pod=mp, impl=args.impl,
                               perf=PerfOptions.parse(args.perf))
                _write(out_dir, rec)
                if rec["ok"]:
                    r = rec["roofline"]
                    print(f"OK    {arch:24s} {shape:12s} {mesh_name:8s} "
                          f"compile={rec['compile_s']:7.1f}s "
                          f"dom={r['dominant']:10s} "
                          f"frac={r['roofline_fraction']:.3f} "
                          f"mem/dev={rec['memory']['peak_live_bytes']/2**30:.2f}GiB",
                          flush=True)
                else:
                    failures += 1
                    print(f"FAIL  {arch:24s} {shape:12s} {mesh_name:8s} "
                          f"{rec['error']}", flush=True)
    print(f"\ndone; failures={failures}")
    return 1 if failures else 0


def _write(out_dir: Path, rec: dict) -> None:
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json".replace("/", "_")
    (out_dir / name).write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    raise SystemExit(main())
