"""Deterministic, shardable, checkpointable synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard) — so (a) the iterator
state is a single integer that travels inside checkpoints (restart resumes the
*exact* stream), (b) after an elastic shrink the surviving hosts re-shard the
stream by changing ``num_shards``/``shard`` only, and (c) fault-injection tests
can corrupt a batch without touching pipeline state.

The token stream is a Markov-ish mixture over a synthetic vocabulary with
enough structure that cross-entropy demonstrably falls during the quickstart
run (pure-random tokens would train to a constant)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-shard batch
    seed: int = 0
    num_shards: int = 1
    shard: int = 0
    family: str = "lm"         # lm | audio | vlm
    d_model: int = 0           # audio/vlm stubs
    img_tokens: int = 0


def _batch_rng(cfg: PipelineConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        (cfg.seed * 1_000_003 + step) * 65_521 + cfg.shard)


def make_batch(cfg: PipelineConfig, step: int) -> dict:
    """Pure function of (config, step): the whole pipeline contract."""
    rng = _batch_rng(cfg, step)
    B, S, V = cfg.batch_size, cfg.seq_len, cfg.vocab_size
    # structured stream: per-sequence drift + short-range repetition
    base = rng.integers(0, V, size=(B, 1))
    drift = rng.integers(-3, 4, size=(B, S)).cumsum(axis=1)
    noise = rng.integers(0, V // 8 + 1, size=(B, S))
    tokens = np.abs(base + drift * (V // 64 + 1) + noise) % V
    tokens = tokens.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = tokens[:, 0]
    batch = {"labels": jnp.asarray(labels)}
    if cfg.family == "audio":
        emb = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
        batch["inputs_embeds"] = jnp.asarray(emb)
    else:
        batch["tokens"] = jnp.asarray(tokens)
    if cfg.family == "vlm":
        img = rng.standard_normal((B, cfg.img_tokens, cfg.d_model)) * 0.02
        batch["img_embeds"] = jnp.asarray(img.astype(np.float32))
    return batch


@dataclass
class DataIterator:
    """Stateful wrapper with a checkpointable cursor."""

    cfg: PipelineConfig
    step: int = 0

    def __iter__(self) -> "DataIterator":
        return self

    def __next__(self) -> dict:
        b = make_batch(self.cfg, self.step)
        self.step += 1
        return b

    # --- checkpoint / elastic hooks ---
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed,
                "shard": self.cfg.shard, "num_shards": self.cfg.num_shards}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])

    def reshard(self, num_shards: int, shard: int) -> "DataIterator":
        """Elastic shrink: same stream, new shard layout, same cursor."""
        import dataclasses

        new_cfg = dataclasses.replace(self.cfg, num_shards=num_shards,
                                      shard=shard)
        return DataIterator(new_cfg, step=self.step)
