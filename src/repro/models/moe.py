"""Mixture-of-Experts FFN: top-k routing, capacity buffers, expert-parallel layout.

TPU-native dispatch (GShard/Switch lineage): tokens are scattered into per-expert
capacity buffers so the expert matmuls are dense einsums that shard cleanly over the
expert axis (EP on the ``model`` mesh axis).

Dispatch is *grouped per batch row* (vmap over B): the position-in-expert cumsum
runs along the sequence axis inside each row, so it never crosses data-parallel
shards — no cross-device cumsum chains in the SPMD partitioning. Capacity is
therefore per (row, expert): C = ceil(cf · S · K / E).

Tokens beyond capacity are dropped and the dropped fraction is returned — it feeds
the paper's ``ROUTER_OVERFLOW`` soft-fault probe (``repro.core.detect.router_probe``),
making router pathologies a first-class propagated error instead of a silent
quality regression.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _dense_init

# Optional PartitionSpec for the (B, E, C, d) dispatch buffers, set by the launch
# layer (§Perf lever "ep"): constraining E over "model" makes GSPMD move tokens
# to their experts with an all-to-all-shaped scatter instead of all-gathering
# the full capacity buffers onto every device.
EXPERT_SPEC = None


def _constrain_e(x):
    if EXPERT_SPEC is not None:
        import jax as _jax

        return _jax.lax.with_sharding_constraint(x, EXPERT_SPEC)
    return x


def init_moe(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": _dense_init(ks[0], (d, E), dtype=jnp.float32),  # fp32 routing
        "wo": _dense_init(ks[3], (E, f, d), dtype=dtype),
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["wi"] = _dense_init(ks[1], (E, d, f), dtype=dtype)
        p["wg"] = _dense_init(ks[2], (E, d, f), dtype=dtype)
    else:
        p["wi"] = _dense_init(ks[1], (E, d, f), dtype=dtype)
    return p


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(cfg.expert_capacity_factor * tokens_per_group
            * cfg.num_experts_per_tok / cfg.num_experts)
    return max(8, -(-c // 8) * 8)     # lane-friendly multiple of 8


def _dispatch_row(xt, expert_idx, gate_vals, E: int, C: int):
    """One batch row. xt:(S,d), expert_idx/gate_vals:(S,K) → (E,C,d) buffers plus
    combine metadata."""
    S, d = xt.shape
    K = expert_idx.shape[1]
    flat_idx = expert_idx.reshape(-1)                        # (S*K,)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]
    keep = pos < C
    buf_idx = jnp.where(keep, flat_idx * C + pos, E * C)     # trash row at E*C
    token_of = jnp.repeat(jnp.arange(S), K)
    buffers = jnp.zeros((E * C + 1, d), xt.dtype)
    buffers = buffers.at[buf_idx].set(xt[token_of], mode="drop")
    return buffers[: E * C].reshape(E, C, d), (buf_idx, token_of, keep)


def _combine_row(out_e, meta, gate_vals, S: int):
    buf_idx, token_of, keep = meta
    E_C, d = out_e.reshape(-1, out_e.shape[-1]).shape
    flat_out = out_e.reshape(E_C, d)
    safe_idx = jnp.where(keep, buf_idx, 0)
    gathered = flat_out[safe_idx] * keep[:, None].astype(flat_out.dtype)
    weighted = gathered * gate_vals.reshape(-1)[:, None].astype(flat_out.dtype)
    return jax.ops.segment_sum(weighted, token_of, num_segments=S)


def apply_moe(p, x, cfg):
    """x: (B, S, d) → (B, S, d), plus aux dict (dropped fraction, load)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(S, cfg)

    logits = (x.astype(jnp.float32) @ p["router"])           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    buffers, meta = jax.vmap(
        lambda xt, ei, gv: _dispatch_row(xt, ei, gv, E, C)
    )(x, expert_idx, gate_vals)                              # (B, E, C, d)
    buffers = _constrain_e(buffers)

    h = jnp.einsum("becd,edf->becf", buffers, p["wi"])
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buffers, p["wg"])) * h
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buffers, p["wg"]),
                        approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    out_e = _constrain_e(jnp.einsum("becf,efd->becd", h, p["wo"]))  # (B,E,C,d)

    combined = jax.vmap(lambda oe, m, gv: _combine_row(oe, m, gv, S))(
        out_e, meta, gate_vals)

    keep = meta[2]
    dropped_fraction = 1.0 - jnp.mean(keep.astype(jnp.float32))
    load = jnp.mean(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32),
                    axis=(0, 1, 2)) * E
    aux = {"dropped_fraction": dropped_fraction, "load_max": jnp.max(load)}
    return combined.reshape(B, S, d), aux
