"""Block assembly + depth stacking.

Heterogeneous stacks are expressed as a repeating ``block_pattern``; the stack scans
over pattern *periods* (``lax.scan`` with the per-position blocks unrolled inside the
body), so HLO size scales with the period length, not the depth — essential for
compile times at 48–64 layers. Remainder layers (depth not divisible by the period)
are applied unrolled after the scan.

Block types:
  attn     — self-attention (full)   + MLP/MoE
  sliding  — self-attention (window) + MLP/MoE
  cross    — cross-attention to image embeddings + MLP (VLM layers, gated)
  ssd      — Mamba-2 mixer (no MLP: the mixer is the block)
  rglru    — Griffin recurrent block + MLP
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .attention import (
    attention_decode,
    attention_train,
    attention_verify,
    cross_attention_decode,
    init_attention,
    init_kv_cache,
    precompute_cross_kv,
)
from .layers import apply_mlp, apply_norm, init_mlp, init_norm
from .moe import apply_moe, init_moe
from .rglru import init_rglru, init_rglru_cache, rglru_decode, rglru_mixer
from .ssm import init_mamba2, init_mamba2_cache, mamba2_decode, mamba2_mixer

ATTN_KINDS = ("attn", "sliding", "cross")

# Optional PartitionSpec for the residual stream between blocks, set by the
# launch layer (sequence parallelism: P(dp_axes, "model", None) makes GSPMD
# lower the Megatron-TP activation all-reduces into reduce-scatter + all-gather
# pairs and shards the norm/probe elementwise work over the model axis).
ACTIVATION_SPEC = None


def _constrain(x):
    if ACTIVATION_SPEC is not None:
        return jax.lax.with_sharding_constraint(x, ACTIVATION_SPEC)
    return x


# ------------------------------------------------------------------------- init
def init_block(key, cfg, btype: str, dtype):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": init_norm(cfg, jnp.float32)}
    if btype in ATTN_KINDS:
        p["attn"] = init_attention(ks[0], cfg, dtype)
        p["norm2"] = init_norm(cfg, jnp.float32)
        if btype == "cross":
            p["gate_attn"] = jnp.zeros((), jnp.float32)
            p["gate_mlp"] = jnp.zeros((), jnp.float32)
        if cfg.is_moe:
            p["moe"] = init_moe(ks[1], cfg, dtype)
        elif cfg.d_ff:
            p["mlp"] = init_mlp(ks[1], cfg, dtype=dtype)
    elif btype == "ssd":
        p["ssd"] = init_mamba2(ks[0], cfg, dtype)
    elif btype == "rglru":
        p["rglru"] = init_rglru(ks[0], cfg, dtype)
        p["norm2"] = init_norm(cfg, jnp.float32)
        if cfg.d_ff:
            p["mlp"] = init_mlp(ks[1], cfg, dtype=dtype)
    else:
        raise ValueError(f"unknown block type {btype}")
    return p


# ------------------------------------------------------------------ train paths
def _ffn(p, h, cfg):
    """MLP or MoE sub-block; returns (out, dropped_fraction)."""
    if cfg.is_moe:
        out, aux = apply_moe(p["moe"], h, cfg)
        return out, aux["dropped_fraction"]
    if cfg.d_ff:
        return apply_mlp(p["mlp"], h, cfg.mlp_kind), jnp.float32(0)
    return jnp.zeros_like(h), jnp.float32(0)


def apply_block_train(p, x, positions, cfg, btype: str, *,
                      img_embeds=None, impl: str = "auto"):
    """Pre-norm residual block. Returns (x, dropped_fraction)."""
    drop = jnp.float32(0)
    if btype in ATTN_KINDS:
        h = apply_norm(p["norm1"], x, cfg.norm)
        window = cfg.sliding_window if btype == "sliding" else 0
        kv_src = img_embeds if btype == "cross" else None
        a = attention_train(p["attn"], h, positions, cfg, window=window,
                            kv_src=kv_src, impl=impl)
        if btype == "cross":
            a = a * jnp.tanh(p["gate_attn"]).astype(a.dtype)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg.norm)
        f, drop = _ffn(p, h, cfg)
        if btype == "cross":
            f = f * jnp.tanh(p["gate_mlp"]).astype(f.dtype)
        x = x + f
    elif btype == "ssd":
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + mamba2_mixer(p["ssd"], h, cfg, impl=impl)
    elif btype == "rglru":
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + rglru_mixer(p["rglru"], h, cfg, impl=impl)
        h = apply_norm(p["norm2"], x, cfg.norm)
        f, drop = _ffn(p, h, cfg)
        x = x + f
    return x, drop


def init_stack(key, cfg, dtype):
    """Period-stacked parameters: ``periods[f"b{pos}"]`` has leading dim
    num_periods; ``rest`` holds the remainder layers unrolled."""
    n_per = cfg.num_periods
    keys = jax.random.split(key, cfg.num_layers + 1)
    periods = {}
    for pos, btype in enumerate(cfg.block_pattern):
        layer_params = [init_block(keys[c * cfg.period + pos], cfg, btype, dtype)
                        for c in range(n_per)]
        periods[f"b{pos}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *layer_params)
    rest = [init_block(keys[n_per * cfg.period + i], cfg, btype, dtype)
            for i, btype in enumerate(cfg.remainder_layers)]
    return {"periods": periods, "rest": rest}


def _remat_policy(cfg):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots_saveable":
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable


def apply_stack_train(stack, x, positions, cfg, *, img_embeds=None,
                      impl: str = "auto"):
    """Scan over periods; returns (x, mean dropped_fraction)."""

    def period_body(x, period_params):
        drop_acc = jnp.float32(0)
        for pos, btype in enumerate(cfg.block_pattern):
            x = _constrain(x)
            x, d = apply_block_train(period_params[f"b{pos}"], x, positions, cfg,
                                     btype, img_embeds=img_embeds, impl=impl)
            drop_acc = drop_acc + d
        return x, drop_acc

    policy = _remat_policy(cfg)
    body = period_body if policy is None else jax.checkpoint(
        period_body, policy=policy)

    if cfg.num_periods > 0:
        if cfg.scan_layers:
            x, drops = jax.lax.scan(lambda c, p: body(c, p), x, stack["periods"])
            drop_total = jnp.sum(drops)
        else:
            drop_total = jnp.float32(0)
            for i in range(cfg.num_periods):
                pp = jax.tree_util.tree_map(lambda a: a[i], stack["periods"])
                x, d = body(x, pp)
                drop_total = drop_total + d
    else:
        drop_total = jnp.float32(0)
    for i, btype in enumerate(cfg.remainder_layers):
        x, d = apply_block_train(stack["rest"][i], x, positions, cfg, btype,
                                 img_embeds=img_embeds, impl=impl)
        drop_total = drop_total + d
    n_ffn = max(sum(1 for b in cfg.pattern_layers if b != "ssd"), 1)
    return x, drop_total / n_ffn


# ----------------------------------------------------------------------- caches
def init_block_cache(batch, cfg, btype: str, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    if btype == "attn":
        return init_kv_cache(batch, max_len, cfg.num_kv_heads, hd, dtype)
    if btype == "sliding":
        cap = min(cfg.sliding_window, max_len)
        return init_kv_cache(batch, cap, cfg.num_kv_heads, hd, dtype)
    if btype == "cross":
        return init_kv_cache(batch, cfg.img_tokens, cfg.num_kv_heads, hd, dtype)
    if btype == "ssd":
        return init_mamba2_cache(batch, cfg, dtype)
    if btype == "rglru":
        return init_rglru_cache(batch, cfg, dtype)
    raise ValueError(btype)


def init_stack_cache(batch, cfg, max_len: int, dtype):
    n_per = cfg.num_periods
    periods = {}
    for pos, btype in enumerate(cfg.block_pattern):
        one = init_block_cache(batch, cfg, btype, max_len, dtype)
        periods[f"b{pos}"] = jax.tree_util.tree_map(
            lambda v: jnp.broadcast_to(v[None], (n_per, *v.shape)).copy(), one)
    rest = [init_block_cache(batch, cfg, btype, max_len, dtype)
            for btype in cfg.remainder_layers]
    return {"periods": periods, "rest": rest}


def apply_block_decode(p, x, cache, pos, cfg, btype: str):
    drop = jnp.float32(0)
    if btype in ATTN_KINDS:
        h = apply_norm(p["norm1"], x, cfg.norm)
        if btype == "cross":
            a = cross_attention_decode(p["attn"], h, cache, cfg)
            a = a * jnp.tanh(p["gate_attn"]).astype(a.dtype)
            new_cache = cache  # static image K/V
        else:
            window = cfg.sliding_window if btype == "sliding" else 0
            a, new_cache = attention_decode(p["attn"], h, cache, pos, cfg,
                                            window=window)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg.norm)
        f, drop = _ffn(p, h, cfg)
        if btype == "cross":
            f = f * jnp.tanh(p["gate_mlp"]).astype(f.dtype)
        x = x + f
    elif btype == "ssd":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, new_cache = mamba2_decode(p["ssd"], h, cache, cfg)
        x = x + y
    elif btype == "rglru":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, new_cache = rglru_decode(p["rglru"], h, cache, cfg)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm)
        f, drop = _ffn(p, h, cfg)
        x = x + f
    else:
        raise ValueError(btype)
    return x, new_cache, drop


def apply_block_verify(p, x, cache, pos, cfg, btype: str):
    """Multi-token decode block (speculative verify). Full attention only:
    ring buffers and recurrent states advance destructively, so they cannot
    absorb the over-writes a rejected draft leaves behind."""
    if btype != "attn":
        raise ValueError(
            f"speculative verify supports full-attention blocks only, "
            f"got {btype!r}")
    h = apply_norm(p["norm1"], x, cfg.norm)
    a, new_cache = attention_verify(p["attn"], h, cache, pos, cfg)
    x = x + a
    h = apply_norm(p["norm2"], x, cfg.norm)
    f, drop = _ffn(p, h, cfg)
    x = x + f
    return x, new_cache, drop


def apply_stack_verify(stack, x, caches, pos, cfg):
    """T-token verify through the whole stack; returns (x, new_caches).

    Structure mirrors :func:`apply_stack_decode` exactly (same period scan,
    same remainder unroll) with the multi-token verify block, so each token
    row computes the single-token decode arithmetic at its own position.
    """

    def period_body(x, inputs):
        pp, pc = inputs
        new_pc = {}
        for i, btype in enumerate(cfg.block_pattern):
            x, c, _ = apply_block_verify(pp[f"b{i}"], x, pc[f"b{i}"], pos,
                                         cfg, btype)
            new_pc[f"b{i}"] = c
        return x, new_pc

    if cfg.num_periods > 0:
        if cfg.scan_layers:
            x, new_periods = jax.lax.scan(
                period_body, x, (stack["periods"], caches["periods"]))
        else:
            outs = []
            for i in range(cfg.num_periods):
                pp = jax.tree_util.tree_map(lambda a: a[i], stack["periods"])
                pc = jax.tree_util.tree_map(lambda a: a[i], caches["periods"])
                x, npc = period_body(x, (pp, pc))
                outs.append(npc)
            new_periods = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs)
    else:
        new_periods = caches["periods"]
    new_rest = []
    for i, btype in enumerate(cfg.remainder_layers):
        x, c, _ = apply_block_verify(stack["rest"][i], x, caches["rest"][i],
                                     pos, cfg, btype)
        new_rest.append(c)
    return x, {"periods": new_periods, "rest": new_rest}


def _draft_layer_slices(stack, caches, cfg, num_layers: int):
    """(params, cache, writeback) triple per drafted layer.

    Period-stacked layers are sliced out once; ``writeback(caches, new)``
    re-inserts the advanced per-layer caches in one ``.at[c].set`` per layer
    — the draft *chain* slices and writes back once around all D steps, so
    the stacked-leaf copies don't scale with draft depth.
    """
    n_scan = cfg.num_periods * cfg.period
    if not 0 < num_layers <= cfg.num_layers:
        raise ValueError(
            f"draft layers must be in [1, {cfg.num_layers}], got {num_layers}")
    layers = []
    for l in range(num_layers):
        if l < n_scan:
            c, pat = l // cfg.period, l % cfg.period
            key = f"b{pat}"
            pp = jax.tree_util.tree_map(lambda a: a[c], stack["periods"][key])
            pc = jax.tree_util.tree_map(lambda a: a[c], caches["periods"][key])

            def wb(caches, nc, c=c, key=key):
                caches["periods"][key] = jax.tree_util.tree_map(
                    lambda full, one: full.at[c].set(one),
                    caches["periods"][key], nc)

            layers.append((pp, pc, cfg.block_pattern[pat], wb))
        else:
            i = l - n_scan

            def wb(caches, nc, i=i):
                caches["rest"][i] = nc

            layers.append((stack["rest"][i], caches["rest"][i],
                           cfg.remainder_layers[i], wb))
    return layers


def apply_stack_decode(stack, x, caches, pos, cfg):
    """One-token decode through the whole stack; returns (x, new_caches, drop)."""

    def period_body(carry, inputs):
        x, drop_acc = carry
        pp, pc = inputs
        new_pc = {}
        for i, btype in enumerate(cfg.block_pattern):
            x, c, d = apply_block_decode(pp[f"b{i}"], x, pc[f"b{i}"], pos, cfg,
                                         btype)
            new_pc[f"b{i}"] = c
            drop_acc = drop_acc + d
        return (x, drop_acc), new_pc

    drop = jnp.float32(0)
    if cfg.num_periods > 0:
        if cfg.scan_layers:
            (x, drop), new_periods = jax.lax.scan(
                period_body, (x, drop), (stack["periods"], caches["periods"]))
        else:
            outs = []
            for i in range(cfg.num_periods):
                pp = jax.tree_util.tree_map(lambda a: a[i], stack["periods"])
                pc = jax.tree_util.tree_map(lambda a: a[i], caches["periods"])
                (x, drop), npc = period_body((x, drop), (pp, pc))
                outs.append(npc)
            new_periods = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs)
    else:
        new_periods = caches["periods"]
    new_rest = []
    for i, btype in enumerate(cfg.remainder_layers):
        x, c, d = apply_block_decode(stack["rest"][i], x, caches["rest"][i],
                                     pos, cfg, btype)
        new_rest.append(c)
        drop = drop + d
    return x, {"periods": new_periods, "rest": new_rest}, drop
