"""Top-level model: init / forward / loss / decode — uniform over all 10 archs.

``[audio]``/``[vlm]`` modality frontends are STUBS per the assignment: callers pass
precomputed frame/patch embeddings (``inputs_embeds`` / ``img_embeds``); only the
transformer backbone is modelled.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import precompute_cross_kv
from .layers import (
    apply_norm,
    chunked_cross_entropy,
    embed_tokens,
    init_embed,
    init_norm,
    init_unembed,
    softmax_cross_entropy,
    unembed,
)
from .transformer import (
    _draft_layer_slices,
    apply_block_decode,
    apply_stack_decode,
    apply_stack_train,
    apply_stack_verify,
    init_stack,
    init_stack_cache,
)


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class Model:
    """Functional model bound to a config (params are explicit pytrees)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------- params
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        k_embed, k_stack, k_un = jax.random.split(key, 3)
        params = {
            "embed": init_embed(k_embed, cfg, dt),
            "stack": init_stack(k_stack, cfg, dt),
            "final_norm": init_norm(cfg, jnp.float32),
        }
        un = init_unembed(k_un, cfg, dt)
        if un:
            params["unembed"] = un
        return params

    def param_shapes(self) -> dict:
        """Shape/dtype tree without allocation (dry-run / sharding planning)."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------------ forward
    def forward(self, params, tokens=None, *, inputs_embeds=None, img_embeds=None,
                impl: str = "auto"):
        """Full-sequence forward → fp32 logits (train and prefill)."""
        cfg = self.cfg
        x, aux = self.backbone(params, tokens, inputs_embeds=inputs_embeds,
                               img_embeds=img_embeds, impl=impl)
        logits = unembed(params.get("unembed"), params["embed"], x,
                         cfg.tie_embeddings, cfg.logit_softcap)
        return logits, aux

    def loss(self, params, batch, *, impl: str = "auto", ce_chunk: int = 0):
        if ce_chunk:
            x, aux = self.backbone(
                params, batch.get("tokens"),
                inputs_embeds=batch.get("inputs_embeds"),
                img_embeds=batch.get("img_embeds"), impl=impl)
            cfg = self.cfg

            def unembed_fn(xc):
                return unembed(params.get("unembed"), params["embed"], xc,
                               cfg.tie_embeddings, cfg.logit_softcap)

            loss = chunked_cross_entropy(x, batch["labels"], unembed_fn,
                                         ce_chunk)
            return loss, aux
        logits, aux = self.forward(
            params, batch.get("tokens"),
            inputs_embeds=batch.get("inputs_embeds"),
            img_embeds=batch.get("img_embeds"), impl=impl)
        loss = softmax_cross_entropy(logits, batch["labels"],
                                     batch.get("loss_mask"))
        return loss, aux

    def backbone(self, params, tokens=None, *, inputs_embeds=None,
                 img_embeds=None, impl: str = "auto"):
        """Forward up to (but excluding) the unembedding (for chunked CE)."""
        cfg = self.cfg
        dt = _dtype(cfg)
        if inputs_embeds is not None:
            x = inputs_embeds.astype(dt)
        else:
            x = embed_tokens(params["embed"], tokens, dt)
        if cfg.embed_scale != 1.0:
            x = x * jnp.asarray(cfg.embed_scale, dt)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if img_embeds is not None:
            img_embeds = img_embeds.astype(dt)
        x, drop = apply_stack_train(params["stack"], x, positions, cfg,
                                    img_embeds=img_embeds, impl=impl)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        return x, {"dropped_fraction": drop}

    # ------------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_len: int) -> dict:
        return init_stack_cache(batch, self.cfg, max_len, _dtype(self.cfg))

    def cache_shapes(self, batch: int, max_len: int) -> dict:
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def decode_step(self, params, token, cache, pos):
        """One new token against an existing cache (serve_step for decode cells).

        token: (B, 1) int32; pos: scalar int32 (global position). Returns
        (fp32 logits (B, 1, V), new cache).
        """
        cfg = self.cfg
        dt = _dtype(cfg)
        x = embed_tokens(params["embed"], token, dt)
        if cfg.embed_scale != 1.0:
            x = x * jnp.asarray(cfg.embed_scale, dt)
        x, new_cache, _ = apply_stack_decode(params["stack"], x, cache, pos, cfg)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params.get("unembed"), params["embed"], x,
                         cfg.tie_embeddings, cfg.logit_softcap)
        return logits, new_cache

    def supports_speculation(self) -> bool:
        """Speculative decode windows need every cache write to be positional
        and idempotent, so a rejected draft's stale entries are overwritten
        before anything reads them: pure full-attention stacks only (ring
        buffers and recurrent states advance destructively), and no MoE (the
        router's capacity accounting couples tokens across the verify batch,
        breaking per-row equality with sequential decode)."""
        cfg = self.cfg
        return (all(b == "attn" for b in cfg.pattern_layers)
                and not cfg.is_moe)

    def verify_step(self, params, tokens, cache, pos):
        """T-token decode ("speculative verify") against an existing cache.

        tokens: (B, T) int32 at positions ``pos .. pos+T-1``; pos: scalar
        int32. Returns (fp32 logits (B, T, V), new cache). Row ``t`` computes
        exactly :meth:`decode_step` at position ``pos+t`` (the verify stack
        mirrors the decode stack per token row), so accepted tokens — and the
        cache entries they leave behind — are bit-equal to sequential decode.
        """
        cfg = self.cfg
        dt = _dtype(cfg)
        x = embed_tokens(params["embed"], tokens, dt)
        if cfg.embed_scale != 1.0:
            x = x * jnp.asarray(cfg.embed_scale, dt)
        x, new_cache = apply_stack_verify(params["stack"], x, cache, pos, cfg)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params.get("unembed"), params["embed"], x,
                         cfg.tie_embeddings, cfg.logit_softcap)
        return logits, new_cache

    def draft_chain(self, params, token, cache, pos, *, draft_layers: int,
                    draft_len: int, override=None, n_forced=None):
        """``draft_len`` chained shallow-exit draft steps in ONE call.

        The chain slices the drafter's layer params/caches out of the
        period-stacked trees once and writes them back once, so the stacked-
        leaf copies (the dominant drafter cost at small scale) don't scale
        with draft depth. ``override``/``n_forced`` force-feed pending prompt
        tokens through the chain: proposal ``d+1`` is replaced by
        ``override[d]`` while ``d+1 < n_forced`` (the speculative window's
        verify-width prompt feed).

        token: (B, 1) int32 at position ``pos``. Returns
        (proposals (B, draft_len) int32, new cache).
        """
        cfg = self.cfg
        dt = _dtype(cfg)
        work = {"periods": dict(cache["periods"]), "rest": list(cache["rest"])}
        layers = _draft_layer_slices(params["stack"], work, cfg, draft_layers)
        local = [pc for _, pc, _, _ in layers]
        tok = token
        outs = []
        for d in range(draft_len):
            x = embed_tokens(params["embed"], tok, dt)
            if cfg.embed_scale != 1.0:
                x = x * jnp.asarray(cfg.embed_scale, dt)
            for i, (pp, _, btype, _) in enumerate(layers):
                x, local[i], _ = apply_block_decode(pp, x, local[i], pos + d,
                                                    cfg, btype)
            x = apply_norm(params["final_norm"], x, cfg.norm)
            logits = unembed(params.get("unembed"), params["embed"], x,
                             cfg.tie_embeddings, cfg.logit_softcap)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(
                jnp.int32)[:, None]
            if override is not None:
                nxt = jnp.where(d + 1 < n_forced, override[d:d + 1][None, :],
                                nxt)
            outs.append(nxt)
            tok = nxt
        for i, (_, _, _, wb) in enumerate(layers):
            wb(work, local[i])
        return jnp.concatenate(outs, axis=1), work

    def prefill(self, params, tokens, *, img_embeds=None, impl: str = "auto"):
        """Prefill returning logits only (the prefill_32k cells lower this).

        Cache-producing prefill for interactive serving is
        ``launch.steps.make_cache_prefill`` (decode-loop based; exact,
        small-scale), driven by the ``repro.serve`` subsystem.
        """
        return self.forward(params, tokens, img_embeds=img_embeds, impl=impl)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
