"""Mamba-2 (SSD — state-space duality) mixer: chunked train path + O(1) decode.

Three implementations of the SSD scan:

* ``ssd_naive_ref`` — per-token recurrence via ``lax.scan`` (the oracle);
* ``ssd_chunked``  — the paper's chunked algorithm (intra-chunk 'attention-like'
  quadratic term + inter-chunk state recurrence), pure jnp. Default lowering path;
* Pallas kernel (``repro.kernels.ssd_scan``) for the intra-chunk hot loop on TPU.

Layout: x:(B,S,H,P) heads, B/C:(B,S,G,N) groups (G | H), dt:(B,S,H), A:(H,).
Recurrence per head: h_t = exp(dt_t·A)·h_{t-1} + dt_t·B_t⊗x_t;  y_t = C_t·h_t + D·x_t.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _dense_init, rms_norm_vec


# ------------------------------------------------------------------------- init
def init_mamba2(key, cfg, dtype=jnp.float32):
    d, din = cfg.d_model, cfg.d_inner
    H, P = cfg.ssm_nheads, cfg.ssm_head_dim
    G, N, W = cfg.ssm_ngroups, cfg.ssm_state_dim, cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    conv_dim = din + 2 * G * N
    return {
        "in_x": _dense_init(ks[0], (d, din), dtype=dtype),
        "in_z": _dense_init(ks[1], (d, din), dtype=dtype),
        "in_B": _dense_init(ks[2], (d, G * N), dtype=dtype),
        "in_C": _dense_init(ks[3], (d, G * N), dtype=dtype),
        "in_dt": _dense_init(ks[4], (d, H), dtype=dtype),
        "conv_w": _dense_init(ks[5], (W, conv_dim), scale=0.5, dtype=dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log) = -1 init
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((din,), dtype),
        "out": _dense_init(ks[6], (din, d), dtype=dtype),
    }


def _causal_conv(u, w):
    """u: (B,S,C), w: (W,C) depthwise causal conv along S."""
    W = w.shape[0]
    up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(W):
        out = out + up[:, i: i + u.shape[1]] * w[i]
    return out


def _segsum(x):
    """x: (..., L) → (..., L, L) with out[i,j] = sum_{k=j+1..i} x_k (i ≥ j)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


# ----------------------------------------------------------------------- oracle
def ssd_naive_ref(x, dt, A, B, C):
    """Per-token scan. x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,g,n) → y:(b,s,h,p)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)   # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2)

    def step(hstate, inputs):
        xt, dtt, Bt, Ct = inputs       # (b,h,p), (b,h), (b,h,n), (b,h,n)
        a = jnp.exp(dtt * A)           # (b,h)
        hstate = (hstate * a[..., None, None]
                  + (dtt[..., None] * xt)[..., :, None] * Bt[..., None, :])
        y = jnp.einsum("bhpn,bhn->bhp", hstate, Ct)
        return hstate, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bh.transpose(1, 0, 2, 3).astype(jnp.float32),
          Ch.transpose(1, 0, 2, 3).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


# ---------------------------------------------------------------------- chunked
def ssd_chunked(x, dt, A, B, C, chunk: int = 128):
    """Chunked SSD (Mamba-2 §6): O(S·L) intra + O(S/L) inter-chunk recurrence."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    # fold dt into x: contribution of token j is dt_j·B_j⊗x_j
    xd = xf * dtf[..., None]
    abar = dtf * A                                      # (b,s,h) log-decay per step
    # chunk views
    xc = xd.reshape(b, nc, L, h, p)
    ac = abar.reshape(b, nc, L, h)
    Bc = jnp.repeat(B, rep, axis=2).astype(jnp.float32).reshape(b, nc, L, h, n)
    Cc = jnp.repeat(C, rep, axis=2).astype(jnp.float32).reshape(b, nc, L, h, n)

    # --- intra-chunk (quadratic, 'attention-like') ---
    Lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))   # (b,nc,h,L,L)
    scores = jnp.einsum("bclhn,bcmhn->bchlm", Cc, Bc)   # (b,nc,h,L,L)
    y_diag = jnp.einsum("bchlm,bchlm,bcmhp->bclhp", scores, Lmat, xc)

    # --- chunk states ---
    cum = jnp.cumsum(ac, axis=2)                         # (b,nc,L,h)
    total = cum[:, :, -1]                                # (b,nc,h)
    decay_states = jnp.exp(total[:, :, None] - cum)      # (b,nc,L,h)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bc, decay_states, xc)

    # --- inter-chunk recurrence over chunk states ---
    def step(hprev, inp):
        st, tot = inp                                    # (b,h,p,n), (b,h)
        hnew = hprev * jnp.exp(tot)[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, hprevs = jax.lax.scan(step, h0, (states.transpose(1, 0, 2, 3, 4),
                                        total.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)             # (b,nc,h,p,n) state *before* chunk

    # --- off-diagonal contribution ---
    decay_in = jnp.exp(cum)                              # (b,nc,L,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cc, hprevs, decay_in)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ full mixer
def mamba2_mixer(p, x, cfg, impl: str = "auto"):
    """x: (B,S,d) → (B,S,d). Train/prefill path."""
    B_, S, d = x.shape
    H, P = cfg.ssm_nheads, cfg.ssm_head_dim
    G, N = cfg.ssm_ngroups, cfg.ssm_state_dim
    xs = x @ p["in_x"]
    z = x @ p["in_z"]
    Bv = x @ p["in_B"]
    Cv = x @ p["in_C"]
    dt = x @ p["in_dt"]
    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(x.dtype)))
    xs = conv_out[..., : cfg.d_inner].reshape(B_, S, H, P)
    Bv = conv_out[..., cfg.d_inner: cfg.d_inner + G * N].reshape(B_, S, G, N)
    Cv = conv_out[..., cfg.d_inner + G * N:].reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    if impl == "auto":
        impl = "chunked"
    if impl == "naive":
        y = ssd_naive_ref(xs, dt, A, Bv, Cv)
    elif impl == "pallas":
        from ..kernels.ssd_scan import ssd_scan
        y = ssd_scan(xs, dt, A, Bv, Cv, chunk=cfg.ssm_chunk)
    else:
        y = ssd_chunked(xs, dt, A, Bv, Cv, chunk=cfg.ssm_chunk)
    y = y + xs * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B_, S, cfg.d_inner)
    y = rms_norm_vec(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out"]


# ----------------------------------------------------------------------- decode
def init_mamba2_cache(batch, cfg, dtype):
    H, P, N = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state_dim
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state_dim
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def mamba2_decode(p, x, cache, cfg):
    """One-token step: O(1) state update (this is why long_500k runs for SSM)."""
    B_, S, d = x.shape
    assert S == 1
    H, P = cfg.ssm_nheads, cfg.ssm_head_dim
    G, N = cfg.ssm_ngroups, cfg.ssm_state_dim
    xs = x @ p["in_x"]
    z = x @ p["in_z"]
    Bv = x @ p["in_B"]
    Cv = x @ p["in_C"]
    dt = x @ p["in_dt"]
    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)[:, 0]       # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w))
    new_conv = window[:, 1:]
    xs1 = conv_out[:, : cfg.d_inner].reshape(B_, H, P)
    Bv1 = conv_out[:, cfg.d_inner: cfg.d_inner + G * N].reshape(B_, G, N)
    Cv1 = conv_out[:, cfg.d_inner + G * N:].reshape(B_, G, N)
    rep = H // G
    Bh = jnp.repeat(Bv1, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cv1, rep, axis=1).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt1 * A)                                          # (B,H)
    hstate = (cache["ssm"] * a[..., None, None]
              + (dt1[..., None] * xs1.astype(jnp.float32))[..., :, None]
              * Bh[..., None, :])
    y = jnp.einsum("bhpn,bhn->bhp", hstate, Ch)
    y = y + xs1.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm_vec(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out"], {"ssm": hstate, "conv": new_conv}
