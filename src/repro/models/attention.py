"""Attention: GQA/MQA/MHA, full / sliding-window / cross, train + decode paths.

Three interchangeable SDPA implementations:

* ``sdpa_ref`` — naive full-materialisation oracle (tests, tiny shapes only);
* ``sdpa_chunked`` — online-softmax over KV chunks inside a scan: O(S·C) live
  memory, the flash algorithm expressed in pure jnp. This is the default lowering
  path (CPU dry-runs and the XLA-TPU fallback);
* Pallas flash kernel (``repro.kernels.flash_attention``) — the TPU hot path,
  numerically validated against ``sdpa_ref`` in interpret mode.

All take q:(B,S,Hq,D), k/v:(B,T,Hkv,D) and broadcast KV heads by GQA grouping.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import _dense_init, apply_rope, rms_norm_vec

NEG_INF = -1e30

# Dry-run cost-variant compiles set this to fully unroll the inner KV scan so
# ``cost_analysis`` (which counts a while-loop body once) sees exact FLOPs.
INNER_UNROLL = False


# ------------------------------------------------------------------------- init
def init_attention(key, cfg, dtype=jnp.float32):
    """Projection weights are kept 3D — (d, heads, head_dim) — so tensor-parallel
    sharding lands on the head dimension directly (a fused (d, H·hd) layout forces
    GSPMD to reshard through the reshape whenever kv_heads doesn't divide the
    model axis, which is the common GQA case)."""
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    import math
    sc = 1.0 / math.sqrt(cfg.d_model)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, cfg.num_heads, hd), scale=sc,
                          dtype=dtype),
        "wk": _dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads, hd), scale=sc,
                          dtype=dtype),
        "wv": _dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads, hd), scale=sc,
                          dtype=dtype),
        "wo": _dense_init(ks[3], (cfg.num_heads, hd, cfg.d_model),
                          scale=1.0 / math.sqrt(cfg.num_heads * hd), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(p, xq, xkv, cfg):
    q = jnp.einsum("bsd,dhe->bshe", xq, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", xkv, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", xkv, p["wv"])
    if cfg.qk_norm:
        q = rms_norm_vec(q, p["q_norm"])
        k = rms_norm_vec(k, p["k_norm"])
    return q, k, v


# ----------------------------------------------------------------------- oracle
def sdpa_ref(q, k, v, *, causal: bool, window: int = 0,
             q_offset: int = 0) -> jax.Array:
    """Naive SDPA oracle. window>0 ⇒ sliding (keys within `window` of the query)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) / jnp.sqrt(float(D))
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


# ----------------------------------------------------------- chunked (jnp flash)
#
# Flash-structured attention in pure jnp with a CUSTOM VJP: the backward
# recomputes per-chunk probabilities from saved (q, k, v, out, lse) instead of
# letting autodiff save the O(S·T) probability tensors — without this, each
# layer's backward writes/reads ~4 GiB of residuals per 2048² chunk pair and the
# memory roofline term is fiction. GQA is expressed with grouped einsums
# (B,S,Kv,g,D vs B,T,Kv,D) so KV heads are never materialised ``repeat``-ed.
def _chunk_ranges(nq, nk, q_chunk, kv_chunk, q_offset, causal, window):
    """Static per-q-chunk KV ranges (and the transpose for the backward)."""
    q_ranges = []
    for qi in range(nq):
        q_lo = qi * q_chunk + q_offset
        q_hi = (qi + 1) * q_chunk - 1 + q_offset
        k_first, k_last = 0, nk - 1
        if causal:
            k_last = min(k_last, q_hi // kv_chunk)
        if window:
            k_first = max(0, (q_lo - window + 1) // kv_chunk)
        q_ranges.append((k_first, max(k_last - k_first + 1, 1)))
    kv_ranges = []
    for kj in range(nk):
        k_lo, k_hi = kj * kv_chunk, (kj + 1) * kv_chunk - 1
        q_first, q_last = 0, nq - 1
        if causal:
            q_first = max(0, (k_lo - q_offset) // q_chunk)
        if window:
            q_last = min(q_last, (k_hi + window - 1 - q_offset) // q_chunk)
        kv_ranges.append((q_first, max(q_last - q_first + 1, 1)))
    return q_ranges, kv_ranges


def _mask_for(qpos, kpos, causal, window, T):
    mask = kpos[None, :] < T
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return mask


def _flash_fwd_impl(q, k, v, causal, window, q_offset, q_chunk, kv_chunk):
    B, S, Hq, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    g = Hq // Kv
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    nq, nk = -(-S // q_chunk), -(-T // kv_chunk)
    Sp, Tp = nq * q_chunk, nk * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0))).reshape(
        B, Sp, Kv, g, D)
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    scale = 1.0 / jnp.sqrt(float(D))
    q_ranges, _ = _chunk_ranges(nq, nk, q_chunk, kv_chunk, q_offset, causal,
                                window)

    outs, lses = [], []
    for qi in range(nq):
        k_first, n_steps = q_ranges[qi]
        qs = jax.lax.slice_in_dim(qp, qi * q_chunk, (qi + 1) * q_chunk,
                                  axis=1).astype(jnp.float32)
        qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        def body(carry, kj, qs=qs, qpos=qpos):
            acc, m, l = carry
            ks = jax.lax.dynamic_slice_in_dim(
                kp, kj * kv_chunk, kv_chunk, 1).astype(jnp.float32)
            vs = jax.lax.dynamic_slice_in_dim(
                vp, kj * kv_chunk, kv_chunk, 1).astype(jnp.float32)
            s = jnp.einsum("bskgd,btkd->bkgst", qs, ks) * scale
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.where(_mask_for(qpos, kpos, causal, window, T)[None, None,
                                                                   None],
                          s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))      # (B,Kv,g,qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = (acc * corr[..., None]
                   + jnp.einsum("bkgst,btkd->bkgsd", p, vs))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, Kv, g, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, Kv, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Kv, g, q_chunk), jnp.float32)
        ks_idx = jnp.arange(k_first, k_first + n_steps, dtype=jnp.int32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), ks_idx,
                                      unroll=True if INNER_UNROLL else 1)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))             # (B,Kv,g,qc)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.transpose(0, 3, 1, 2, 4))            # (B,qc,Kv,g,D)
        lses.append(lse)
    out = jnp.concatenate(outs, axis=1)[:, :S]
    lse = jnp.concatenate(lses, axis=3)[..., :S]             # (B,Kv,g,S)
    return out.reshape(B, S, Hq, D).astype(q.dtype), lse


def _flash(q, k, v, causal, window, q_offset, q_chunk, kv_chunk):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, q_chunk,
                             kv_chunk)
    return out


def _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, q_chunk,
                               kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, q_chunk, kv_chunk, res, do):
    q, k, v, out, lse = res
    B, S, Hq, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    g = Hq // Kv
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    nq, nk = -(-S // q_chunk), -(-T // kv_chunk)
    Sp, Tp = nq * q_chunk, nk * kv_chunk
    scale = 1.0 / jnp.sqrt(float(D))
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0))).reshape(
        B, Sp, Kv, g, D)
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    dop = jnp.pad(do.astype(jnp.float32),
                  ((0, 0), (0, Sp - S), (0, 0), (0, 0))).reshape(
        B, Sp, Kv, g, D)
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, Sp - S)))
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    delta = jnp.pad(delta, ((0, 0), (0, Sp - S), (0, 0))).reshape(
        B, Sp, Kv, g).transpose(0, 2, 3, 1)                   # (B,Kv,g,Sp)
    q_ranges, kv_ranges = _chunk_ranges(nq, nk, q_chunk, kv_chunk, q_offset,
                                        causal, window)

    def recompute(qs, qpos, kj):
        ks = jax.lax.dynamic_slice_in_dim(
            kp, kj * kv_chunk, kv_chunk, 1).astype(jnp.float32)
        vs = jax.lax.dynamic_slice_in_dim(
            vp, kj * kv_chunk, kv_chunk, 1).astype(jnp.float32)
        s = jnp.einsum("bskgd,btkd->bkgst", qs, ks) * scale
        kpos = kj * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.where(_mask_for(qpos, kpos, causal, window, T)[None, None,
                                                               None],
                      s, NEG_INF)
        return s, ks, vs

    # ---- dq: loop q chunks, scan kv chunks ----
    dqs = []
    for qi in range(nq):
        k_first, n_steps = q_ranges[qi]
        sl = lambda a: jax.lax.slice_in_dim(a, qi * q_chunk,
                                            (qi + 1) * q_chunk, axis=1)
        qs = sl(qp).astype(jnp.float32)
        dos = sl(dop)
        lse_q = jax.lax.slice_in_dim(lsep, qi * q_chunk, (qi + 1) * q_chunk,
                                     axis=3)
        delta_q = jax.lax.slice_in_dim(delta, qi * q_chunk,
                                       (qi + 1) * q_chunk, axis=3)
        qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        def body(dq_acc, kj, qs=qs, dos=dos, lse_q=lse_q, delta_q=delta_q,
                 qpos=qpos):
            s, ks, vs = recompute(qs, qpos, kj)
            p = jnp.exp(s - lse_q[..., None])
            dp = jnp.einsum("bskgd,btkd->bkgst", dos, vs)
            ds = p * (dp - delta_q[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bkgst,btkd->bskgd", ds, ks)
            return dq_acc, None

        dq0 = jnp.zeros((B, q_chunk, Kv, g, D), jnp.float32)
        ks_idx = jnp.arange(k_first, k_first + n_steps, dtype=jnp.int32)
        dq_qi, _ = jax.lax.scan(body, dq0, ks_idx,
                                unroll=True if INNER_UNROLL else 1)
        dqs.append(dq_qi)
    dq = jnp.concatenate(dqs, axis=1)[:, :S].reshape(B, S, Hq, D)

    # ---- dk, dv: loop kv chunks, scan q chunks ----
    dks, dvs = [], []
    for kj in range(nk):
        q_first, n_steps = kv_ranges[kj]
        kpos = kj * kv_chunk + jnp.arange(kv_chunk)

        def body(carry, qi, kpos=kpos, kj=kj):
            dk_acc, dv_acc = carry
            qs = jax.lax.dynamic_slice_in_dim(
                qp, qi * q_chunk, q_chunk, 1).astype(jnp.float32)
            dos = jax.lax.dynamic_slice_in_dim(dop, qi * q_chunk, q_chunk, 1)
            lse_q = jax.lax.dynamic_slice_in_dim(lsep, qi * q_chunk, q_chunk, 3)
            delta_q = jax.lax.dynamic_slice_in_dim(delta, qi * q_chunk,
                                                   q_chunk, 3)
            qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset
            ks = jax.lax.dynamic_slice_in_dim(
                kp, kj * kv_chunk, kv_chunk, 1).astype(jnp.float32)
            vs = jax.lax.dynamic_slice_in_dim(
                vp, kj * kv_chunk, kv_chunk, 1).astype(jnp.float32)
            s = jnp.einsum("bskgd,btkd->bkgst", qs, ks) * scale
            s = jnp.where(_mask_for(qpos, kpos, causal, window, T)[None, None,
                                                                   None],
                          s, NEG_INF)
            p = jnp.exp(s - lse_q[..., None])
            dv_acc = dv_acc + jnp.einsum("bkgst,bskgd->btkd", p, dos)
            dp = jnp.einsum("bskgd,btkd->bkgst", dos, vs)
            ds = p * (dp - delta_q[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum("bkgst,bskgd->btkd", ds, qs)
            return (dk_acc, dv_acc), None

        dk0 = jnp.zeros((B, kv_chunk, Kv, D), jnp.float32)
        dv0 = jnp.zeros((B, kv_chunk, Kv, D), jnp.float32)
        qs_idx = jnp.arange(q_first, q_first + n_steps, dtype=jnp.int32)
        (dk_kj, dv_kj), _ = jax.lax.scan(body, (dk0, dv0), qs_idx,
                                         unroll=True if INNER_UNROLL else 1)
        dks.append(dk_kj)
        dvs.append(dv_kj)
    dk = jnp.concatenate(dks, axis=1)[:, :T]
    dv = jnp.concatenate(dvs, axis=1)[:, :T]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash_vjp = jax.custom_vjp(_flash, nondiff_argnums=(3, 4, 5, 6, 7))
_flash_vjp.defvjp(_flash_fwd, _flash_bwd)


def sdpa_chunked(q, k, v, *, causal: bool, window: int = 0, q_offset: int = 0,
                 q_chunk: int = 2048, kv_chunk: int = 2048) -> jax.Array:
    """Flash attention in pure jnp (custom-VJP recompute backward)."""
    return _flash_vjp(q, k, v, causal, window, q_offset, q_chunk, kv_chunk)


def sdpa(q, k, v, *, causal: bool, window: int = 0, q_offset: int = 0,
         impl: str = "auto") -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "chunked"
    if impl == "pallas":
        from ..kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if impl == "chunked":
        return sdpa_chunked(q, k, v, causal=causal, window=window,
                            q_offset=q_offset)
    return sdpa_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)


# ------------------------------------------------------------------ train paths
def attention_train(p, x, positions, cfg, *, window: int = 0,
                    kv_src: Optional[jax.Array] = None,
                    impl: str = "auto") -> jax.Array:
    """Self- or cross-attention over a full sequence."""
    cross = kv_src is not None
    xkv = kv_src if cross else x
    q, k, v = _project_qkv(p, x, xkv, cfg)
    if not cross and cfg.rope_style != "none":
        q = apply_rope(q, positions, theta=cfg.rope_theta, style=cfg.rope_style,
                       fraction=cfg.rope_fraction)
        k = apply_rope(k, positions, theta=cfg.rope_theta, style=cfg.rope_style,
                       fraction=cfg.rope_fraction)
    causal = cfg.causal and not cross
    out = sdpa(q, k, v, causal=causal, window=0 if cross else window, impl=impl)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


# ----------------------------------------------------------------- decode paths
def init_kv_cache(batch: int, length: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype),
    }


def attention_decode(p, x, cache, pos, cfg, *, window: int = 0,
                     impl: str = "ref"):
    """One-token decode. ``cache`` holds (k, v) of capacity T (full) or W (ring).

    pos: scalar int32 — global position of the new token. Sliding-window layers
    use a ring buffer of capacity ``window``: slot = pos % window; masking is done
    via reconstructed slot positions, so the cache stays O(window) regardless of
    sequence length (this is what makes long_500k decode sub-quadratic AND
    sub-linear in memory for local layers).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    posv = jnp.full((B, 1), pos, jnp.int32)
    if cfg.rope_style != "none":
        q = apply_rope(q, posv, theta=cfg.rope_theta, style=cfg.rope_style,
                       fraction=cfg.rope_fraction)
        k_new = apply_rope(k_new, posv, theta=cfg.rope_theta,
                           style=cfg.rope_style, fraction=cfg.rope_fraction)
    cap = cache["k"].shape[1]
    slot = pos % cap if window else jnp.minimum(pos, cap - 1)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1)

    # reconstruct the global position of every slot for masking
    slots = jnp.arange(cap)
    if window:
        # ring: slot s holds position p with p ≡ s (mod cap), the largest p ≤ pos
        delta = (slot - slots) % cap
        slot_pos = pos - delta
        valid = (slot_pos >= 0) & (slot_pos > pos - window)
    else:
        slot_pos = slots
        valid = slots <= pos

    group = cfg.num_heads // cfg.num_kv_heads
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) / jnp.sqrt(float(hd))
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vr.astype(jnp.float32))
    out = jnp.einsum("bshe,hed->bsd", out.astype(x.dtype), p["wo"])
    return out, {"k": k, "v": v}


def attention_verify(p, x, cache, pos, cfg):
    """Multi-token decode ("verify"): T new tokens at positions
    ``pos .. pos+T-1`` against an existing full-attention cache.

    The speculative decode window's verification pass: all T new K/V entries
    are written first (out-of-capacity positions are *dropped*, never clamped
    — a clamp would clobber the last in-range entry before an in-range query
    reads it), then every query attends over the full capacity with its own
    per-position causal mask. Each query row performs exactly the arithmetic
    of :func:`attention_decode` at that position (same projections, same rope,
    same full-capacity scores + masked softmax), so the verified logits — and
    the K/V entries left in the cache — are bit-equal to T sequential decode
    steps over the same tokens. Full (non-windowed) attention only: ring
    buffers can not absorb speculative over-writes (a rejected draft's write
    would destroy the ring entry a later real step still attends).
    """
    B, T = x.shape[:2]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(p, x, x, cfg)
    qpos = pos + jnp.arange(T, dtype=jnp.int32)
    posv = jnp.broadcast_to(qpos[None, :], (B, T))
    if cfg.rope_style != "none":
        q = apply_rope(q, posv, theta=cfg.rope_theta, style=cfg.rope_style,
                       fraction=cfg.rope_fraction)
        k_new = apply_rope(k_new, posv, theta=cfg.rope_theta,
                           style=cfg.rope_style, fraction=cfg.rope_fraction)
    cap = cache["k"].shape[1]
    k = cache["k"].at[:, qpos].set(k_new, mode="drop")
    v = cache["v"].at[:, qpos].set(v_new, mode="drop")

    slots = jnp.arange(cap)
    valid = slots[None, :] <= qpos[:, None]          # (T, cap) per-query mask

    group = cfg.num_heads // cfg.num_kv_heads
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) / jnp.sqrt(float(hd))
    scores = jnp.where(valid[None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # the probs·V contraction and the output projection are computed per
    # query row: XLA-CPU's tiling (hence accumulation order) for these two
    # ops depends on the number of query rows, so batched forms diverge from
    # the decode step in low-order bits; a T=1 slice has the decode step's
    # exact shapes and lowers identically at the capacities the serving
    # engines run and are fenced at (caps up to a few hundred — see
    # tests/test_serve_spec.py). At very large capacities the backend may
    # partition big contractions across threads, where bit-equality between
    # any two programs stops being guaranteeable; emitted tokens remain
    # full-model argmaxes (a self-consistent greedy stream), they may just
    # differ from the single-token engine near exact logit ties.
    vrf = vr.astype(jnp.float32)
    rows = []
    for t in range(T):
        o_t = jnp.einsum("bhst,bthd->bshd", probs[:, :, t:t + 1, :], vrf)
        rows.append(jnp.einsum("bshe,hed->bsd", o_t.astype(x.dtype),
                               p["wo"]))
    out = jnp.concatenate(rows, axis=1)
    return out, {"k": k, "v": v}


def cross_attention_decode(p, x, img_kv, cfg):
    """Decode-time cross attention against static (precomputed) image K/V."""
    B = x.shape[0]
    q, _, _ = _project_qkv(p, x, x, cfg)
    k, v = img_kv["k"], img_kv["v"]
    group = cfg.num_heads // cfg.num_kv_heads
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    hd = cfg.resolved_head_dim
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) / jnp.sqrt(float(hd))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vr.astype(jnp.float32))
    out = jnp.einsum("bshe,hed->bsd", out.astype(x.dtype), p["wo"])
    return out


def precompute_cross_kv(p, img_embeds, cfg):
    """Prefill-time K/V projection of the (stubbed) image embeddings."""
    k = jnp.einsum("bsd,dhe->bshe", img_embeds, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", img_embeds, p["wv"])
    if cfg.qk_norm:
        k = rms_norm_vec(k, p["k_norm"])
    return {"k": k, "v": v}
