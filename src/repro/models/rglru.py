"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (per channel):  h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)
with a_t = exp(−c·softplus(Λ)·r_t), r_t = σ(W_a x_t), i_t = σ(W_x x_t); the gate
projections are block-diagonal over ``lru_heads`` blocks (Griffin §2.4). Train path
uses ``jax.lax.associative_scan`` (log-depth); a Pallas kernel
(``repro.kernels.rglru_scan``) implements the block-parallel scan for TPU. Decode is
an O(1) single-step update.

Block layout (the Griffin recurrent block): x → [linear → GeLU] ⊗ [linear →
causal-conv → RG-LRU] → linear out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _dense_init

C_SCALE = 8.0


def init_rglru(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.resolved_lru_width
    nb = cfg.lru_heads or cfg.num_heads
    blk = w // nb
    ks = jax.random.split(key, 6)
    return {
        "wx": _dense_init(ks[0], (d, w), dtype=dtype),          # recurrence branch
        "wy": _dense_init(ks[1], (d, w), dtype=dtype),          # gate branch
        "conv_w": _dense_init(ks[2], (4, w), scale=0.5, dtype=dtype),
        "gate_a": _dense_init(ks[3], (nb, blk, blk), dtype=dtype),
        "gate_i": _dense_init(ks[4], (nb, blk, blk), dtype=dtype),
        # Λ init so that a ≈ 0.9..0.999 at r=0.5 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(jnp.linspace(0.9, 4.0, w))).astype(jnp.float32),
        "out": _dense_init(ks[5], (w, d), dtype=dtype),
    }


def _blockdiag(x, w_blocks):
    """x: (B,S,w) @ block-diagonal weights (nb, blk, blk) → (B,S,w)."""
    B, S, w = x.shape
    nb, blk, _ = w_blocks.shape
    xb = x.reshape(B, S, nb, blk)
    return jnp.einsum("bsnk,nkj->bsnj", xb, w_blocks).reshape(B, S, w)


def _causal_conv(u, w):
    W = w.shape[0]
    up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(W):
        out = out + up[:, i: i + u.shape[1]] * w[i]
    return out


def _gates(p, xr):
    """r, i gates and log-decay a from the recurrence-branch activations."""
    r = jax.nn.sigmoid(_blockdiag(xr, p["gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_blockdiag(xr, p["gate_i"]).astype(jnp.float32))
    log_a = -C_SCALE * jax.nn.softplus(p["lam"]) * r      # (B,S,w), ≤ 0
    return log_a, i


def rglru_scan_ref(x_in, log_a):
    """Oracle: sequential scan. x_in = i⊙x (already gated), log_a: (B,S,w)."""
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x_in

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    B, S, w = x_in.shape
    _, hs = jax.lax.scan(step, jnp.zeros((B, w), jnp.float32),
                         (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def rglru_scan_assoc(x_in, log_a):
    """Log-depth associative scan: elements (a, b) compose as (a2·a1, a2·b1+b2)."""
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x_in

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return hs


def rglru_mixer(p, x, cfg, impl: str = "auto"):
    """x: (B,S,d) → (B,S,d). Train/prefill path."""
    xr = x @ p["wx"]
    gate = jax.nn.gelu((x @ p["wy"]).astype(jnp.float32), approximate=True)
    xr = _causal_conv(xr, p["conv_w"].astype(x.dtype))
    log_a, i = _gates(p, xr)
    x_in = i * xr.astype(jnp.float32)
    if impl == "auto":
        impl = "assoc"
    if impl == "ref":
        h = rglru_scan_ref(x_in, log_a)
    elif impl == "pallas":
        from ..kernels.rglru_scan import rglru_scan
        h = rglru_scan(x_in, log_a)
    else:
        h = rglru_scan_assoc(x_in, log_a)
    y = (h * gate).astype(x.dtype)
    return y @ p["out"]


# ----------------------------------------------------------------------- decode
def init_rglru_cache(batch, cfg, dtype):
    w = cfg.resolved_lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 3, w), dtype),     # width-4 conv → 3 history steps
    }


def rglru_decode(p, x, cache, cfg):
    B, S, d = x.shape
    assert S == 1
    xr = (x @ p["wx"])[:, 0]                          # (B,w)
    gate = jax.nn.gelu((x @ p["wy"])[:, 0].astype(jnp.float32), approximate=True)
    window = jnp.concatenate([cache["conv"], xr[:, None]], axis=1)  # (B,4,w)
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x.dtype))
    new_conv = window[:, 1:]
    log_a, i = _gates(p, conv[:, None])
    log_a, i = log_a[:, 0], i[:, 0]
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * conv.astype(jnp.float32))
    h = a * cache["h"] + x_in
    y = (h * gate).astype(x.dtype)[:, None]
    return y @ p["out"], {"h": h, "conv": new_conv}
