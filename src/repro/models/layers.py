"""Common layers: norms, rotary embeddings (3 styles), MLP variants, embeddings.

Parameters are plain pytrees (dicts of jnp arrays); every init function takes an
rng key and returns the params dict. Sharding is attached later by path-based
logical-axis rules (``repro.sharding.rules``), so layers stay mesh-agnostic.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------------ norms
def init_norm(cfg, dtype=jnp.float32):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_norm_vec(x, scale, eps: float = 1e-6):
    """RMS norm over the last axis with an explicit scale vector (qk-norm etc.)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    rot_dim = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, *, theta: float, style: str = "standard",
               fraction: float = 1.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32.

    * ``standard`` — half-split rotation (llama/qwen/gemma convention);
    * ``partial2d`` — chatglm: rotary over ``fraction`` of the head dim in
      interleaved-pair form, the remainder left untouched;
    * ``none`` — no positional encoding (hubert's conv-positional stub).
    """
    if style == "none":
        return x
    head_dim = x.shape[-1]
    if style == "standard":
        inv, rot_dim = rope_freqs(head_dim, theta, 1.0)
        ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, rot/2)
        cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
        x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
        out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
        return out.astype(x.dtype)
    if style == "partial2d":
        inv, rot_dim = rope_freqs(head_dim, theta, fraction)
        xr, xp = x[..., :rot_dim], x[..., rot_dim:]
        ang = positions[..., :, None].astype(jnp.float32) * inv
        cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
        xr = xr.astype(jnp.float32).reshape(*xr.shape[:-1], rot_dim // 2, 2)
        r1, r2 = xr[..., 0], xr[..., 1]
        rot = jnp.stack([r1 * cos - r2 * sin, r2 * cos + r1 * sin], axis=-1)
        rot = rot.reshape(*rot.shape[:-2], rot_dim).astype(x.dtype)
        return jnp.concatenate([rot, xp], axis=-1)
    raise ValueError(f"unknown rope style {style}")


# -------------------------------------------------------------------------- mlp
def init_mlp(key, cfg, d_ff: int | None = None, dtype=jnp.float32):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wo": _dense_init(ks[2], (d_ff, cfg.d_model), dtype=dtype)}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["wi"] = _dense_init(ks[0], (cfg.d_model, d_ff), dtype=dtype)
        p["wg"] = _dense_init(ks[1], (cfg.d_model, d_ff), dtype=dtype)
    else:  # plain gelu
        p["wi"] = _dense_init(ks[0], (cfg.d_model, d_ff), dtype=dtype)
    return p


def apply_mlp(p, x, kind: str):
    h = x @ p["wi"]
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ p["wo"]


# -------------------------------------------------------------------- embedding
def init_embed(key, cfg, dtype=jnp.float32):
    p = {"embedding": _dense_init(key, (cfg.vocab_size, cfg.d_model),
                                  scale=1.0, dtype=dtype)}
    return p


def embed_tokens(p, tokens, dtype):
    return jnp.take(p["embedding"], tokens, axis=0).astype(dtype)


def unembed(p_unembed, p_embed, x, tie: bool, softcap: float = 0.0):
    """Logits in fp32 (loss numerics); optionally soft-capped (gemma)."""
    w = p_embed["embedding"].T if tie else p_unembed["kernel"]
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def init_unembed(key, cfg, dtype=jnp.float32):
    if cfg.tie_embeddings:
        return {}
    return {"kernel": _dense_init(key, (cfg.d_model, cfg.vocab_size), dtype=dtype)}


# ------------------------------------------------------------------------- loss
def softmax_cross_entropy(logits, labels, mask=None):
    """Mean next-token CE; logits fp32 (batch, seq, vocab), labels (batch, seq)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_cross_entropy(x, labels, unembed_fn, chunk: int):
    """CE without materialising the full (B,S,V) fp32 logits: scan over sequence
    chunks, computing logits → per-token NLL per chunk (recomputed in backward).
    ``unembed_fn(x_chunk) -> fp32 logits chunk``. The big-vocab archs (gemma3
    262k, qwen 152k) are memory-bound on the CE chain without this (§Perf)."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    valid = jnp.ones((B, S), jnp.float32)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    nchunks = x.shape[1] // chunk
    xc = x.reshape(B, nchunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nchunks, chunk).transpose(1, 0, 2)
    vc = valid.reshape(B, nchunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_nll(args):
        xch, lch, vch = args
        logits = unembed_fn(xch)                   # (B, chunk, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lch[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * vch)

    def body(acc, args):
        return acc + chunk_nll(args), None

    from .attention import INNER_UNROLL  # cost-exact unroll for dry-run variants

    total, _ = jax.lax.scan(body, jnp.float32(0), (xc, lc, vc),
                            unroll=True if INNER_UNROLL else 1)
    return total / (B * S)
