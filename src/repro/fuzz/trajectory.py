"""Trajectories: fully deterministic fault-injection scenarios.

A :class:`Trajectory` is the fuzzer's genome — one self-contained, seeded
description of a serving run plus every fault injected into it. It carries
*everything* the runner needs: the engine variant (which serving code path),
the synthetic request load (derived arithmetically from the counts, never
stored), and an ordered list of injection :class:`Op`\\ s with explicit
timing. Replay is therefore bit-for-bit: the same trajectory JSON produces
the same dispatches, the same injected words, the same recovery decisions and
the same token streams, on any machine (greedy decode + seeded injection =
no hidden entropy).

Op timing model (the injection surfaces of DESIGN.md §3.6):

* ``word``    — OR an :class:`~repro.core.errors.ErrorCode` word into the
  device error words of dispatch ``cycle`` at window step ``step``, slot
  ``slot`` (via ``Replica(fault_injector=...)``): the in-band mutation that
  reaches every soft-error lane of the recovery matrix, timed relative to
  window dispatch/retire, prefill chunks and speculative draft/verify
  boundaries (all of which are window steps).
* ``poison``  — NaN a real element of slot state / KV / page pool before
  drive-loop cycle ``cycle`` (``Replica.inject_state_fault``): the probe
  path, not just the word path.
* ``page_table`` — unmap a lane's device page-table row behind the allocator
  (``Replica.corrupt_page_table``): host-ledger/device-table divergence the
  in-band ``PAGE_FAULT`` probe must latch.
* ``preempt`` — pull a lane's request out mid-flight and requeue it
  (``Replica.preempt_slot``): the zero-drop preemption path.
* ``kill``    — hard-kill replica rank ``slot`` at serving round ``cycle``
  (ServeGroup engines only): ULFM shrink + ledger re-route.
* ``restart`` — stop the *whole fleet* at serving round ``cycle`` and replay
  it from the durable request ledger alone (``serve`` with ``crash_at=`` then
  ``serve_from_ledger``): the crash-restart zero-drop path. At most one per
  trajectory — the replayed incarnation is part of the same scenario.
* ``rejoin``  — summon a spare / previously-killed rank back into the group
  at round ``cycle`` (the ledger ``joins`` schedule): non-blocking join with
  background state transfer and epoch re-balance. Lands in the post-restart
  incarnation when a ``restart`` op rides the same trajectory.
* ``host_kill`` / ``host_stop`` — SIGKILL / SIGSTOP(+SIGCONT) worker
  *process* ``slot`` once ``cycle`` responses have been retired fleet-wide
  (multihost engine only): the heartbeat detector's suspect → evict ladder,
  WAL re-route across a real process boundary, and the SIGSTOP
  slow-but-alive false-positive guard.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field, replace
from typing import Iterable, Optional, Sequence

OP_KINDS = ("word", "poison", "page_table", "preempt", "kill", "restart",
            "rejoin", "host_kill", "host_stop")

#: Ops that only make sense on the multi-replica ULFM engine.
GROUP_OPS = frozenset({"kill", "restart", "rejoin"})

#: Ops that only make sense on the multihost (real OS process) engine —
#: they signal a worker *process*, there is no thread to signal elsewhere.
HOST_OPS = frozenset({"host_kill", "host_stop"})

#: Engine variants a trajectory can target. ``group`` is the multi-replica
#: ULFM engine; ``multihost`` is the real-process fault domain (subprocess
#: workers under the heartbeat supervisor); the rest are single-replica
#: serving code paths.
SINGLE_ENGINES = ("stepwise", "window", "overlap", "overlap_tp",
                  "overlap_paged", "spec", "spec_paged")
GROUP_ENGINE = "group"
MULTIHOST_ENGINE = "multihost"
ENGINES = SINGLE_ENGINES + (GROUP_ENGINE, MULTIHOST_ENGINE)

#: Tensor-parallel engine variants: their ``word`` ops may carry a ``shard``
#: target (the injection surface is per-shard — DESIGN §3.8).
TP_ENGINES = frozenset(e for e in SINGLE_ENGINES if e.endswith("_tp"))


@dataclass(frozen=True)
class Op:
    """One injection, fully timed. ``slot`` doubles as the target rank for
    ``kill``/``rejoin`` ops (``restart`` stops the whole fleet and ignores
    it); ``step``/``code`` are only meaningful for ``word`` ops. ``shard``
    targets one tensor-parallel shard of a ``word`` op on a TP engine (-1 =
    inject on every shard); the cross-shard OR-fold must make the two cases
    indistinguishable at retirement — that equivalence is exactly what
    shard-targeted trajectories probe."""

    op: str
    cycle: int
    slot: int = 0
    step: int = 0
    code: int = 0
    shard: int = -1

    def __post_init__(self):
        if self.op not in OP_KINDS:
            raise ValueError(f"unknown op {self.op!r} (known: {OP_KINDS})")
        if self.cycle < 0 or self.slot < 0 or self.step < 0:
            raise ValueError(f"negative timing/target in {self!r}")
        if self.shard < -1:
            raise ValueError(f"shard must be >= -1 in {self!r}")
        if self.op == "word" and self.code == 0:
            raise ValueError("word op needs a nonzero ErrorCode word")
        if self.shard >= 0 and self.op != "word":
            raise ValueError("shard targeting is only meaningful for word "
                             f"ops, got {self!r}")


@dataclass(frozen=True)
class Trajectory:
    """One deterministic fuzz scenario (see module docstring)."""

    seed: int
    engine: str
    n_requests: int = 3
    prompt_len: int = 5
    max_new: int = 8
    max_request_retries: int = 6
    ops: tuple = ()
    note: str = ""

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r} "
                             f"(known: {ENGINES})")
        if self.n_requests < 1 or self.prompt_len < 1 or self.max_new < 1:
            raise ValueError("degenerate request load")
        object.__setattr__(self, "ops", tuple(self.ops))
        for op in self.ops:
            if not isinstance(op, Op):
                raise TypeError(f"ops must be Op instances, got {op!r}")
            if op.op in HOST_OPS:
                if self.engine != MULTIHOST_ENGINE:
                    raise ValueError(
                        f"{op.op!r} op targets a worker process and is only "
                        "valid on the multihost engine")
            elif self.engine == MULTIHOST_ENGINE:
                raise ValueError(
                    f"{op.op!r} op is not valid on the multihost engine "
                    f"(host ops only: {sorted(HOST_OPS)})")
            elif (op.op in GROUP_OPS) != (self.engine == GROUP_ENGINE):
                raise ValueError(
                    f"{op.op!r} op is "
                    f"{'only' if op.op in GROUP_OPS else 'not'} "
                    "valid on the group engine")
            if op.shard >= 0 and self.engine not in TP_ENGINES:
                raise ValueError(
                    f"shard-targeted op {op!r} on non-TP engine "
                    f"{self.engine!r} (TP engines: {sorted(TP_ENGINES)})")
        if sum(1 for o in self.ops if o.op == "restart") > 1:
            raise ValueError("at most one restart op per trajectory: the "
                             "replayed incarnation is the same scenario")

    # ----------------------------------------------------------- derived load
    def prompts(self) -> list[tuple]:
        """The synthetic prompts, derived arithmetically (never stored): the
        same scheme the serving test suites use, parameterised by the
        trajectory so the reference cache can key on three small ints."""
        return [tuple(5 + i + j for j in range(self.prompt_len))
                for i in range(self.n_requests)]

    def ops_of(self, *kinds: str) -> list[Op]:
        return [o for o in self.ops if o.op in kinds]

    def with_ops(self, ops: Iterable[Op]) -> "Trajectory":
        return replace(self, ops=tuple(ops))

    @property
    def load_key(self) -> tuple:
        """Reference-cache key: everything that shapes the *clean* token
        streams (injections never do — that is the oracle)."""
        return (self.n_requests, self.prompt_len, self.max_new)

    # ------------------------------------------------------------------- JSON
    def to_json(self) -> dict:
        d = asdict(self)
        d["ops"] = [asdict(o) for o in self.ops]
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Trajectory":
        d = dict(d)
        d["ops"] = tuple(Op(**o) for o in d.get("ops", ()))
        return cls(**d)

    def dumps(self) -> str:
        return json.dumps(self.to_json(), sort_keys=True)

    @classmethod
    def loads(cls, s: str) -> "Trajectory":
        return cls.from_json(json.loads(s))
