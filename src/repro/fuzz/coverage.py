"""Coverage model: which recovery-matrix cells has the fuzzer exercised?

A **cell** is ``(error-code class, recovery action, engine)`` — one entry of
the fault-handling matrix the serving stack claims to implement. The
reachable universe is *derived*, not hand-written: for every injectable
single-bit :class:`~repro.core.errors.ErrorCode` we replay the real
:class:`~repro.core.recovery.RecoveryPolicy` against an escalating run of
repeats and collect the actions it actually routes to (so a policy change
automatically reshapes the target set), then cross that with every engine
variant, plus the engine-specific lanes the policy does not own (the paged
``page_reclaim`` ledger record, the group's shrink / re-route cells).

:class:`CoverageDB` persists hit counts as JSON. The mutator asks it for
uncovered cells and biases trajectory generation toward them — the
"coverage-guided" half of the fuzzer.
"""
from __future__ import annotations

import json
import os
from typing import Iterable, Optional

from ..core.errors import ErrorCode, PropagatedError, RankError
from ..core.faults import INJECTABLE_CODE_MASK
from ..core.recovery import RecoveryPolicy
from .trajectory import GROUP_ENGINE, MULTIHOST_ENGINE, SINGLE_ENGINES

#: (code_name, action, engine)
Cell = tuple[str, str, str]

#: Engines that run the paged-KV pool (and therefore the page_reclaim lane).
PAGED_ENGINES = frozenset(e for e in SINGLE_ENGINES if "paged" in e)

#: Injectable single-bit classes, as ErrorCode members (sorted by bit).
INJECTABLE_CLASSES: tuple[ErrorCode, ...] = tuple(
    ErrorCode(INJECTABLE_CODE_MASK).classes())


def action_ladder(code: ErrorCode, depth: int = 6) -> list[str]:
    """The action sequence a fresh policy takes for ``depth`` consecutive
    faults of ``code`` (one per step, all inside the escalation window) —
    the escalation ladder a targeted trajectory walks."""
    pol = RecoveryPolicy()
    exc = PropagatedError([RankError(rank=0, code=int(code))])
    return [pol.decide(exc, step).action.value
            for step in range(1, depth + 1)]


def reachable_cells() -> frozenset[Cell]:
    """The derived coverage universe (see module docstring)."""
    cells: set[Cell] = set()
    for code in INJECTABLE_CLASSES:
        actions = set(action_ladder(code))
        for engine in SINGLE_ENGINES:
            for action in actions:
                cells.add((code.name, action, engine))
    for engine in PAGED_ENGINES:
        # ledger-divergence repair is recorded as its own lane alongside the
        # policy's RESTORE_GOOD (replica._recover_window)
        cells.add((ErrorCode.PAGE_FAULT.name, "page_reclaim", engine))
    cells.add((ErrorCode.COMM_CORRUPTED.name, "shrink", GROUP_ENGINE))
    cells.add((ErrorCode.RANK_FAILED.name, "reroute", GROUP_ENGINE))
    # elastic recovery lanes: a full-fleet crash replayed from the durable
    # ledger, and a dead/spare rank re-admitted via the non-blocking join
    cells.add((ErrorCode.RANK_FAILED.name, "replay", GROUP_ENGINE))
    cells.add((ErrorCode.RANK_FAILED.name, "rejoin", GROUP_ENGINE))
    # multihost (real OS process) lanes: a SIGKILL'd worker detected by the
    # heartbeat detector and evicted (RANK_FAILED latched on the survivors),
    # and a SIGSTOP'd worker that resumes inside the timeout — suspicion
    # cleared, never evicted (the false-positive guard as a coverage target)
    cells.add((ErrorCode.RANK_FAILED.name, "evict", MULTIHOST_ENGINE))
    cells.add((ErrorCode.STRAGGLER.name, "resume", MULTIHOST_ENGINE))
    return frozenset(cells)


class CoverageDB:
    """Persisted hit counts per cell (JSON: ``{"CODE|action|engine": n}``)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.counts: dict[str, int] = {}
        if path is not None and os.path.exists(path):
            with open(path) as f:
                data = json.load(f)
            self.counts = {str(k): int(v)
                           for k, v in data.get("cells", {}).items()}

    # ------------------------------------------------------------------ keys
    @staticmethod
    def key(cell: Cell) -> str:
        return "|".join(cell)

    @staticmethod
    def unkey(key: str) -> Cell:
        code, action, engine = key.split("|")
        return (code, action, engine)

    # ------------------------------------------------------------- recording
    def record(self, cells: Iterable[Cell]) -> list[Cell]:
        """Count every cell; returns the ones never seen before."""
        new: list[Cell] = []
        for cell in cells:
            k = self.key(cell)
            if k not in self.counts:
                new.append(cell)
            self.counts[k] = self.counts.get(k, 0) + 1
        return new

    def covered(self, cell: Cell) -> bool:
        return self.key(cell) in self.counts

    def cells(self) -> set[Cell]:
        return {self.unkey(k) for k in self.counts}

    # --------------------------------------------------------------- queries
    def uncovered(self, universe: Iterable[Cell]) -> list[Cell]:
        return sorted(c for c in universe if not self.covered(c))

    def fraction(self, universe: Iterable[Cell]) -> float:
        universe = list(universe)
        if not universe:
            return 1.0
        hit = sum(1 for c in universe if self.covered(c))
        return hit / len(universe)

    def report(self, universe: Iterable[Cell]) -> dict:
        universe = sorted(universe)
        return {
            "universe": len(universe),
            "covered": sum(1 for c in universe if self.covered(c)),
            "fraction": self.fraction(universe),
            "uncovered": [self.key(c) for c in self.uncovered(universe)],
            "extra": sorted(self.key(c) for c in self.cells()
                            if c not in set(universe)),
        }

    # ------------------------------------------------------------ persistence
    def save(self, path: Optional[str] = None) -> None:
        path = path or self.path
        if path is None:
            return
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": 1, "cells": self.counts}, f, indent=1,
                      sort_keys=True)
