"""Coverage-guided fault-injection fuzzer for the serving stack.

Drives the real engines (stepwise / windowed / overlapped / paged /
speculative replicas, the ULFM ServeGroup, and the multihost real-process
fault domain) end to end with seeded, fully reproducible fault trajectories; measures coverage over the derived
(error code × recovery action × engine) matrix; judges every run against
the stack's own contracts (bit-exactness, zero drops, ledger invariants,
trace causality); and minimizes + promotes every counterexample into the
replayable regression corpus under ``tests/fuzz_corpus/``.

See DESIGN.md §3.6 for the architecture and ``scripts/fuzz.py`` for the CLI.
"""
from .campaign import CampaignReport, FuzzCampaign, load_entry, minimize, write_entry
from .coverage import Cell, CoverageDB, action_ladder, reachable_cells
from .mutator import FaultMutator
from .runner import RunResult, run_trajectory
from .trajectory import (
    ENGINES,
    GROUP_ENGINE,
    MULTIHOST_ENGINE,
    SINGLE_ENGINES,
    Op,
    Trajectory,
)

__all__ = [
    "CampaignReport", "FuzzCampaign", "load_entry", "minimize", "write_entry",
    "Cell", "CoverageDB", "action_ladder", "reachable_cells",
    "FaultMutator", "RunResult", "run_trajectory",
    "ENGINES", "GROUP_ENGINE", "MULTIHOST_ENGINE", "SINGLE_ENGINES", "Op",
    "Trajectory",
]
