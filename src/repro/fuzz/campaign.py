"""Campaign loop: propose → run → record coverage → minimize → promote.

Ties the fuzzer together: a :class:`FaultMutator` proposes seeded
trajectories (biased toward uncovered recovery-matrix cells), the runner
executes each against the real stack and applies the oracles, the
:class:`~repro.fuzz.coverage.CoverageDB` accumulates which cells fired, and
every failing trajectory is **minimized** (greedy op-dropping + load
shrinking while the failure still reproduces) and written to the corpus
directory as a self-contained JSON counterexample. Passing, coverage-novel
trajectories can be promoted as ``seed`` entries — the deterministic
regression tests ``tests/test_fuzz_corpus.py`` replays on every CI run.

Corpus entry statuses:

* ``seed`` / ``regression`` — must replay clean: zero violations and a
  bit-identical outcome digest.
* ``counterexample`` — must still *reproduce* its violations; once the bug
  is fixed, the replay test fails and the entry is flipped to
  ``regression`` (with a fresh digest) to pin the fix.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field, replace
from typing import Iterable, Optional

from .coverage import CoverageDB
from .mutator import FaultMutator
from .runner import RunResult, run_trajectory
from .trajectory import Trajectory

MINIMIZE_BUDGET = 24      # replays spent shrinking one counterexample


# ----------------------------------------------------------------- minimizer
def minimize(traj: Trajectory,
             budget: int = MINIMIZE_BUDGET) -> tuple[Trajectory, RunResult]:
    """Greedy delta-debugging: drop ops one at a time (then shrink the
    request load) while the trajectory still fails any oracle. Returns the
    smallest still-failing trajectory and its result."""
    best_res = run_trajectory(traj)
    if not best_res.failed:           # flaky caller — nothing to minimize
        return traj, best_res
    best = traj
    runs = 1

    def fails(cand: Trajectory):
        nonlocal runs
        runs += 1
        r = run_trajectory(cand)
        return r if r.failed else None

    changed = True
    while changed and runs < budget:
        changed = False
        for i in range(len(best.ops)):
            if runs >= budget:
                break
            r = fails(best.with_ops(best.ops[:i] + best.ops[i + 1:]))
            if r is not None:
                best, best_res, changed = best.with_ops(
                    best.ops[:i] + best.ops[i + 1:]), r, True
                break
        if changed:
            continue
        for cand in (replace(best, n_requests=2), replace(best, max_new=5),
                     replace(best, prompt_len=3)):
            if cand == best or runs >= budget:
                continue
            r = fails(cand)
            if r is not None:
                best, best_res, changed = cand, r, True
                break
    return best, best_res


# -------------------------------------------------------------------- corpus
def write_entry(corpus_dir: str, name: str, traj: Trajectory, *,
                status: str, digest: Optional[str] = None,
                violations: Iterable[str] = (),
                cells: Iterable = (), provenance: Optional[dict] = None
                ) -> str:
    os.makedirs(corpus_dir, exist_ok=True)
    path = os.path.join(corpus_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump({
            "version": 1,
            "status": status,
            "trajectory": traj.to_json(),
            "digest": digest,
            "violations": sorted(violations),
            "cells": sorted("|".join(c) for c in cells),
            "provenance": provenance or {},
        }, f, indent=1, sort_keys=True)
    return path


def load_entry(path: str) -> dict:
    with open(path) as f:
        entry = json.load(f)
    entry["trajectory"] = Trajectory.from_json(entry["trajectory"])
    return entry


# ------------------------------------------------------------------ campaign
@dataclass
class CampaignReport:
    seed: int
    budget: int
    ran: int = 0
    truncated: bool = False           # time box hit before the budget
    coverage: dict = field(default_factory=dict)
    new_cells: list = field(default_factory=list)
    counterexamples: list = field(default_factory=list)
    promoted: list = field(default_factory=list)
    wall_s: float = 0.0

    def to_json(self) -> dict:
        return {
            "seed": self.seed, "budget": self.budget, "ran": self.ran,
            "truncated": self.truncated, "coverage": self.coverage,
            "new_cells": sorted("|".join(c) for c in self.new_cells),
            "counterexamples": self.counterexamples,
            "promoted": self.promoted, "wall_s": round(self.wall_s, 1),
        }


class FuzzCampaign:
    def __init__(self, *, seed: int = 0, db: Optional[CoverageDB] = None,
                 corpus_dir: Optional[str] = None,
                 engines: Optional[Iterable[str]] = None,
                 time_budget_s: Optional[float] = None,
                 minimize_budget: int = MINIMIZE_BUDGET):
        self.seed = int(seed)
        self.db = db or CoverageDB()
        self.corpus_dir = corpus_dir
        self.mutator = FaultMutator(self.seed, self.db, engines)
        self.time_budget_s = time_budget_s
        self.minimize_budget = minimize_budget
        # coverage-novel passing runs: (trajectory, digest, cells) — the
        # mutation pool and the seed-promotion candidates
        self.pool: list[tuple[Trajectory, str, frozenset]] = []

    def run(self, budget: int) -> CampaignReport:
        t0 = time.monotonic()
        rep = CampaignReport(seed=self.seed, budget=budget)
        for index in range(budget):
            if (self.time_budget_s is not None
                    and time.monotonic() - t0 > self.time_budget_s):
                rep.truncated = True      # explicit, never a silent cap
                break
            traj = self.mutator.propose(
                index, pool=[t for t, _, _ in self.pool])
            res = run_trajectory(traj)
            new = self.db.record(res.cells)
            rep.ran += 1
            rep.new_cells.extend(new)
            if res.failed:
                self._counterexample(rep, index, traj)
            elif new:
                self.pool.append((traj, res.digest(), frozenset(res.cells)))
        rep.coverage = self.db.report(self.mutator.universe)
        self.db.save()
        rep.wall_s = time.monotonic() - t0
        return rep

    def _counterexample(self, rep: CampaignReport, index: int,
                        traj: Trajectory) -> None:
        small, res = minimize(traj, self.minimize_budget)
        if not res.failed:                # did not reproduce on replay
            small, res = traj, run_trajectory(traj)
            if not res.failed:
                rep.counterexamples.append(
                    {"index": index, "flaky": True,
                     "trajectory": traj.to_json()})
                return
        record = {"index": index, "flaky": False,
                  "violations": res.violations,
                  "trajectory": small.to_json()}
        if self.corpus_dir is not None:
            record["path"] = write_entry(
                self.corpus_dir, f"ce_{self.seed}_{index:04d}", small,
                status="counterexample", violations=res.violations,
                cells=res.cells,
                provenance={"campaign_seed": self.seed, "index": index})
        rep.counterexamples.append(record)

    def promote_seeds(self, k: int, corpus_dir: Optional[str] = None
                      ) -> list[str]:
        """Write up to ``k`` coverage-diverse passing trajectories as ``seed``
        corpus entries (greedy max-new-cell selection over the pool)."""
        corpus_dir = corpus_dir or self.corpus_dir
        if corpus_dir is None:
            return []
        chosen: list[tuple[Trajectory, str, frozenset]] = []
        covered: set = set()
        pool = list(self.pool)
        while pool and len(chosen) < k:
            pool.sort(key=lambda p: (len(p[2] - covered), len(p[2])),
                      reverse=True)
            best = pool.pop(0)
            if not (best[2] - covered) and chosen:
                break                     # nothing new left to pin
            chosen.append(best)
            covered |= best[2]
        paths = []
        for i, (traj, digest, cells) in enumerate(chosen):
            paths.append(write_entry(
                corpus_dir, f"seed_{traj.engine}_{self.seed}_{i:02d}", traj,
                status="seed", digest=digest, cells=cells,
                provenance={"campaign_seed": self.seed}))
        return paths
