"""Execute one trajectory against the *real* serving stack and judge it.

No mocks anywhere: a trajectory builds a live :class:`~repro.serve.replica.
Replica` (or :class:`~repro.serve.group.ServeGroup`) with the production
jitted step functions, drives it request-for-request, injects its faults
through the deterministic hooks, and then checks the run against the stack's
own stated contracts — the **oracles**:

1. **Completeness**: every accepted request is answered exactly once, with a
   terminal status in {OK, FAILED}; FAILED is legal only when the trajectory
   actually injected faults (legal degradation, DESIGN.md §3.4) — a clean run
   must answer everything OK.
2. **Bit-exactness**: every OK token stream equals the clean reference run of
   the same engine/load. Greedy LFLR recompute is deterministic, so injected
   faults on any lane must leave the final streams bit-identical — the
   recovery machinery runs for real, but it must be *invisible* in the
   output.
3. **Page-ledger invariants**: ``PageAllocator.check()`` holds at the end of
   every paged run (and, debug-guarded, at every preempt/requeue/reclaim site
   inside the replica).
4. **Trace causality**: the fault-causality tracer's post-mortem
   ``validate()`` finds no orphans — every traced request one terminal, every
   fault attributed, every recovery span closed.
5. **No wedge / no crash**: the drive loop reaches idle within a bounded
   cycle count and no exception escapes the stack.

Compiled engine state ("kits") is cached per engine variant, so a campaign
pays each jit compile once, like a :class:`~repro.serve.group.ServeGroup`
fleet does.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import shutil
import tempfile
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from ..configs import smoke_config
from ..core.errors import ErrorCode
from ..core.faults import FaultSchedule, FaultSpec
from ..launch.paging import PagedLayout
from ..launch.steps import (
    make_cache_prefill,
    make_decode_window,
    make_prefill_decode_window,
    make_slot_decode_step,
    make_speculative_decode_window,
)
from ..models import build_model
from ..obs import postmortem
from ..obs.trace import Tracer, merge_trace_dicts, merge_traces
from ..serve.config import EngineConfig
from ..serve.group import ServeGroup
from ..serve.queue import FAILED, OK, Request
from ..serve.replica import SERVE_PROBES, Replica
from ..serve.multihost import MultiHostSupervisor
from .coverage import Cell
from .trajectory import GROUP_ENGINE, MULTIHOST_ENGINE, Op, Trajectory

MODEL = "qwen3-1.7b"      # smoke config: tiny, full-attention → every engine
MAX_CYCLES = 400          # drive-loop bound: far past any legal run length
GROUP_RANKS = 3

# multihost lane timing: a short lease so the SIGKILL → evict → re-route
# round trip stays inside a fuzz run's seconds budget, and a stop pause at
# half the lease so the resumed worker is *provably* inside the no-evict
# guarantee (the false-positive guard is an oracle below, not just coverage)
MULTIHOST_SUSPECT_TIMEOUT = 0.6
MULTIHOST_STOP_PAUSE = 0.5 * MULTIHOST_SUSPECT_TIMEOUT


# --------------------------------------------------------------- engine kits
@dataclass(frozen=True)
class EngineSpec:
    """Replica-shape knobs for one engine variant (kept tiny: the fuzzer's
    job is path coverage, not throughput)."""

    window: int = 0
    overlap: bool = False
    paged: bool = False
    page_size: int = 8
    speculate: bool = False
    draft_len: int = 2
    draft_layers: int = 1
    max_len: int = 32     # spec engines use 64: verify-width page growth room
    num_slots: int = 2
    tp: int = 1           # tensor-parallel width ("model" mesh axis)

    def engine_config(self, max_request_retries: int) -> EngineConfig:
        """This variant's shape as the one validated EngineConfig surface."""
        return EngineConfig(
            num_slots=self.num_slots, max_len=self.max_len,
            max_request_retries=max_request_retries, window=self.window,
            overlap=self.overlap, paged=self.paged, page_size=self.page_size,
            speculate=self.speculate, draft_len=self.draft_len,
            draft_layers=self.draft_layers, tp=self.tp)


ENGINE_SPECS: dict[str, EngineSpec] = {
    "stepwise": EngineSpec(),
    "window": EngineSpec(window=4, overlap=False),
    "overlap": EngineSpec(window=4, overlap=True),
    # tp=2 on forced host devices (conftest / the fuzz CLI set XLA_FLAGS):
    # same window shape as "overlap", so any divergence between the two
    # engines' streams is the cross-shard machinery's fault, nothing else's
    "overlap_tp": EngineSpec(window=4, overlap=True, tp=2),
    "overlap_paged": EngineSpec(window=4, overlap=True, paged=True,
                                page_size=8),
    "spec": EngineSpec(window=4, overlap=True, speculate=True, max_len=64),
    "spec_paged": EngineSpec(window=4, overlap=True, speculate=True,
                             paged=True, page_size=16, max_len=64),
}


@dataclass(frozen=True)
class EngineKit:
    """Shared, compile-once state for one engine variant: the jitted step
    functions every Replica built from this kit reuses (same sharing contract
    as ServeGroup — make_* factories return fresh closures, so letting each
    Replica build its own would recompile per trajectory)."""

    engine: str
    spec: EngineSpec
    cfg: object
    params: object
    decode_fn: object
    prefill_fn: object
    window_fn: object
    layout: Optional[PagedLayout]


@functools.lru_cache(maxsize=None)
def _env():
    cfg = smoke_config(MODEL)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _tp_ctx(cfg, params, spec: EngineSpec):
    """The kit-shared TPContext (mesh + storage specs) for a TP variant —
    the same derivation ServeGroup/Replica perform, done once per kit.
    Raises early when the process lacks the devices (the fuzz CLI and the
    test conftest force host devices via XLA_FLAGS)."""
    from ..launch.steps import TPContext
    from ..sharding.rules import param_specs, tp_storage_specs
    ndev = len(jax.devices())
    if ndev < spec.tp:
        raise ValueError(
            f"tp={spec.tp} requires {spec.tp} devices, found {ndev} "
            "(force host devices with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={spec.tp})")
    mesh = jax.make_mesh((spec.tp,), ("model",))
    one = build_model(cfg).init_cache(1, spec.max_len)
    stacked = jax.tree_util.tree_map(
        lambda v: jax.ShapeDtypeStruct((spec.num_slots, *v.shape), v.dtype),
        one)
    return TPContext(mesh=mesh, param_specs=param_specs(params, mesh),
                     cache_specs=tp_storage_specs(stacked, mesh))


@functools.lru_cache(maxsize=None)
def get_kit(engine: str) -> EngineKit:
    cfg, params = _env()
    spec = ENGINE_SPECS[engine]
    layout = None
    if spec.paged:
        num_pages = spec.num_slots * (spec.max_len // spec.page_size)
        layout = PagedLayout(build_model(cfg).init_cache(1, spec.max_len),
                             spec.max_len, page_size=spec.page_size,
                             num_pages=num_pages)
    tp = _tp_ctx(cfg, params, spec) if spec.tp > 1 else None
    decode_fn = jax.jit(make_slot_decode_step(cfg, SERVE_PROBES))
    prefill_fn = make_cache_prefill(cfg, SERVE_PROBES,
                                    fused=bool(spec.window), paged=layout,
                                    donate=bool(spec.paged))
    if not spec.window:
        window_fn = None
    elif spec.speculate:
        window_fn = make_speculative_decode_window(
            cfg, SERVE_PROBES, window=spec.window, draft_len=spec.draft_len,
            draft_layers=spec.draft_layers, paged=layout, tp=tp)
    elif spec.overlap:
        window_fn = make_prefill_decode_window(cfg, SERVE_PROBES,
                                               window=spec.window,
                                               paged=layout, tp=tp)
    else:
        window_fn = make_decode_window(cfg, SERVE_PROBES, window=spec.window,
                                       paged=layout, tp=tp)
    return EngineKit(engine=engine, spec=spec, cfg=cfg, params=params,
                     decode_fn=decode_fn, prefill_fn=prefill_fn,
                     window_fn=window_fn, layout=layout)


@functools.lru_cache(maxsize=None)
def _group_kit(max_request_retries: int,
               max_ranks: int = GROUP_RANKS) -> ServeGroup:
    cfg, _ = _env()
    return ServeGroup(cfg, nranks=GROUP_RANKS, max_ranks=max_ranks,
                      config=EngineConfig(
                          num_slots=2, max_len=32, window=4, overlap=True,
                          eos_id=None,
                          max_request_retries=max_request_retries,
                          trace=True))


# ----------------------------------------------------------------- injection
class _ScheduledInjector:
    """The ``Replica(fault_injector=...)`` callable for one trajectory: a
    pure lookup from dispatch index to the uint32 word array to OR in — no
    state, no randomness, so replay is trivially bit-for-bit."""

    def __init__(self, word_ops):
        self._by_index: dict[int, list[Op]] = {}
        for op in word_ops:
            self._by_index.setdefault(op.cycle, []).append(op)

    def __call__(self, index: int, shape: tuple):
        ops = self._by_index.get(index)
        if not ops:
            return None
        w = np.zeros(shape, np.uint32)
        for op in ops:
            if len(shape) == 1:               # stepwise: (slots,)
                w[op.slot % shape[0]] |= np.uint32(op.code)
            elif len(shape) == 2:             # windowed: (K, slots)
                w[op.step % shape[0], op.slot % shape[1]] |= np.uint32(op.code)
            else:                             # TP windowed: (tp, K, slots)
                shard = (slice(None) if op.shard < 0
                         else op.shard % shape[0])
                w[shard, op.step % shape[1],
                  op.slot % shape[2]] |= np.uint32(op.code)
        return w


def _apply_host_op(rep: Replica, op: Op) -> bool:
    """Host-side mutations between drive cycles. The op's slot is a starting
    preference, not a hard target: we rotate over the lanes and hit the
    first one where the mutation actually bites (an op landing on an empty
    lane would be dead code). Returns False when nothing bit this cycle —
    the drive loop then retries the op next cycle (lanes go idle at wave
    boundaries; an op must not silently miss because it fell in a gap).
    Still fully deterministic — pure function of (op.slot, lane states)."""
    S = rep.sched.num_slots
    for k in range(S):
        slot = (op.slot + k) % S
        if op.op == "poison":
            if (rep.sched.slots[slot].active
                    and rep.inject_state_fault(slot) is not None):
                return True
        elif op.op == "page_table":
            if rep.corrupt_page_table(slot):
                return True
        elif op.op == "preempt":
            if rep.preempt_slot(slot):
                return True
        else:
            raise AssertionError(f"unexpected host op {op!r}")
    return False


# -------------------------------------------------------------------- result
@dataclass
class RunResult:
    trajectory: Trajectory
    responses: dict = field(default_factory=dict)   # id -> Response
    violations: list = field(default_factory=list)
    cells: set = field(default_factory=set)
    summary: dict = field(default_factory=dict)

    @property
    def failed(self) -> bool:
        return bool(self.violations)

    def digest(self) -> str:
        """Stable hash of the observable outcome (id, status, tokens): two
        replays of the same trajectory must produce the same digest."""
        blob = json.dumps(
            sorted((rid, r.status, list(r.tokens))
                   for rid, r in self.responses.items()))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _requests(traj: Trajectory) -> list[Request]:
    return [Request(id=i, prompt=p, max_new_tokens=traj.max_new)
            for i, p in enumerate(traj.prompts())]


# ------------------------------------------------------------------- oracles
def _check_outcomes(traj: Trajectory, responses: dict,
                    reference: dict, violations: list) -> None:
    injected = bool(traj.ops)
    for rid in range(traj.n_requests):
        resp = responses.get(rid)
        if resp is None:
            violations.append(f"dropped: request {rid} never answered")
            continue
        if resp.status == OK:
            if tuple(resp.tokens) != reference[rid]:
                violations.append(
                    f"token mismatch on request {rid}: got "
                    f"{list(resp.tokens)}, clean run gave "
                    f"{list(reference[rid])}")
        elif resp.status == FAILED:
            if not injected:
                violations.append(
                    f"request {rid} FAILED with no injected faults "
                    f"({resp.detail})")
        else:
            violations.append(
                f"illegal terminal status {resp.status!r} for request {rid} "
                f"({resp.detail})")


def _metrics_cells(metrics, engine: str) -> set[Cell]:
    cells: set[Cell] = set()
    for f in metrics.faults:
        for cls in ErrorCode(f.code).classes():
            cells.add((cls.name, f.action, engine))
    return cells


# ----------------------------------------------------------- reference cache
@functools.lru_cache(maxsize=None)
def reference_tokens(engine: str, n_requests: int, prompt_len: int,
                     max_new: int) -> dict:
    """Token streams of the clean (zero-op) run of ``engine`` under this
    load — the bit-exactness baseline. A non-OK response here is a harness
    bug, not a finding, and raises immediately."""
    traj = Trajectory(seed=0, engine=engine, n_requests=n_requests,
                      prompt_len=prompt_len, max_new=max_new)
    runner = {GROUP_ENGINE: _run_group,
              MULTIHOST_ENGINE: _run_multihost}.get(engine, _run_single)
    res = runner(traj, reference={}, check=False)
    if set(res.responses) != set(range(n_requests)):
        raise RuntimeError(f"clean {engine} run dropped requests: "
                           f"{sorted(res.responses)}")
    bad = [r for r in res.responses.values() if r.status != OK]
    if bad:
        raise RuntimeError(f"clean {engine} run not all OK: {bad}")
    return {rid: tuple(r.tokens) for rid, r in res.responses.items()}


# --------------------------------------------------------------------- drive
def _run_single(traj: Trajectory, *, reference: dict,
                check: bool = True) -> RunResult:
    kit = get_kit(traj.engine)
    spec = kit.spec
    tracer = Tracer(pid=0)
    rep = Replica(kit.cfg, params=kit.params,
                  config=spec.engine_config(traj.max_request_retries),
                  decode_fn=kit.decode_fn, prefill_fn=kit.prefill_fn,
                  window_fn=kit.window_fn, paged_layout=kit.layout,
                  tracer=tracer,
                  fault_injector=_ScheduledInjector(traj.ops_of("word")),
                  page_debug=True)
    res = RunResult(trajectory=traj)
    host_ops: dict[int, list[Op]] = {}
    for op in traj.ops_of("poison", "page_table", "preempt"):
        host_ops.setdefault(op.cycle, []).append(op)
    for req in _requests(traj):
        rej = rep.submit(req)
        if rej is not None:
            res.responses[rej.id] = rej
    try:
        cycle = 0
        pending: list[Op] = []       # host ops that found no lane to bite yet
        while not rep.idle() and cycle < MAX_CYCLES:
            pending.extend(host_ops.get(cycle, ()))
            pending = [op for op in pending if not _apply_host_op(rep, op)]
            for resp in rep.step():
                if resp.id in res.responses:
                    res.violations.append(
                        f"duplicate response for request {resp.id}")
                res.responses[resp.id] = resp
            cycle += 1
        if not rep.idle():
            res.violations.append(
                f"wedged: {len(rep.queue)} queued + "
                f"{rep.sched.in_flight()} in-flight after {MAX_CYCLES} "
                "cycles")
    except Exception as exc:                      # oracle 5: nothing escapes
        res.violations.append(f"crash: {type(exc).__name__}: {exc}")
    res.cells = _metrics_cells(rep.metrics, traj.engine)
    if rep.alloc is not None:
        try:
            rep.alloc.check()
        except AssertionError as exc:
            res.violations.append(f"page ledger corrupt at end of run: {exc}")
    if check:
        _check_outcomes(traj, res.responses, reference, res.violations)
        res.violations.extend(
            f"trace: {p}" for p in postmortem.validate(merge_traces(tracer)))
    res.summary = {"faults": rep.metrics.fault_counts(),
                   "statuses": rep.metrics.by_status()}
    return res


def _run_group(traj: Trajectory, *, reference: dict,
               check: bool = True) -> RunResult:
    kills = traj.ops_of("kill")
    rejoins = traj.ops_of("rejoin")
    restarts = traj.ops_of("restart")
    # a rejoin without a restart needs a spare rank beyond the initial fleet;
    # after a restart the previously killed rank itself is the spare
    max_ranks = GROUP_RANKS + (1 if rejoins and not restarts else 0)
    group = _group_kit(traj.max_request_retries, max_ranks)
    res = RunResult(trajectory=traj)
    faults = FaultSchedule(
        [FaultSpec(step=op.cycle, kind="kill", rank=op.slot % group.nranks)
         for op in kills], seed=traj.seed)
    crash_at = restarts[0].cycle if restarts else None
    joins = sorted(op.cycle for op in rejoins) or None
    tmp = tempfile.mkdtemp(prefix="fuzz-ledger-")
    ledger_path = os.path.join(tmp, "ledger.wal")
    outs = []
    traces = []
    try:
        # every group trajectory runs durable: the write-ahead log is part of
        # the production submit path, so the fuzzer must always exercise it
        out = group.serve(_requests(traj), faults=faults,
                          ledger_path=ledger_path, crash_at=crash_at,
                          joins=None if restarts else joins)
        outs.append(out)
        traces.append(out.trace())
        res.responses = dict(out.responses)
        if restarts:
            if out.crashed:
                out2 = group.serve_from_ledger(ledger_path, joins=joins)
                outs.append(out2)
                traces.append(out2.trace())
                res.responses.update(out2.responses)
                res.cells.add((ErrorCode.RANK_FAILED.name, "replay",
                               traj.engine))
            else:
                # the fleet drained before the crash round — legal, but the
                # mutator's timing search wants to know the op was dead code
                res.summary["restart_noop"] = True
    except Exception as exc:
        res.violations.append(f"crash: {type(exc).__name__}: {exc}")
        return res
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    for out in outs:
        for rr in out.reports:
            report = (rr.value if rr.exception is None and not rr.killed
                      else None)
            if report is None:
                continue
            if report.metrics is not None:
                res.cells |= _metrics_cells(report.metrics, traj.engine)
            if any(ev[0] == "shrink" for ev in report.events):
                res.cells.add((ErrorCode.COMM_CORRUPTED.name, "shrink",
                               traj.engine))
        if out.rerouted:
            res.cells.add((ErrorCode.RANK_FAILED.name, "reroute",
                           traj.engine))
        if out.joined:
            res.cells.add((ErrorCode.RANK_FAILED.name, "rejoin",
                           traj.engine))
    if kills and not any(out.rerouted for out in outs):
        # a kill with no re-route means the dead rank had already answered
        # everything — legal, but worth noting for the mutator's timing search
        res.summary["kill_noop"] = True
    if rejoins and not any(out.joined for out in outs):
        res.summary["rejoin_noop"] = True
    if check:
        _check_outcomes(traj, res.responses, reference, res.violations)
        # a crash-restart scenario is ONE causal story across two fleet
        # incarnations: submits from the first pair with terminals from the
        # second, so the oracle only holds on the merged trace
        res.violations.extend(
            f"trace: {p}" for p in postmortem.validate(
                merge_trace_dicts(*traces)))
    res.summary.setdefault("statuses", {})
    for r in res.responses.values():
        res.summary["statuses"][r.status] = (
            res.summary["statuses"].get(r.status, 0) + 1)
    return res


def _run_multihost(traj: Trajectory, *, reference: dict,
                   check: bool = True) -> RunResult:
    """Drive the real-process fault domain: 3 sim-backend subprocess workers
    under the heartbeat supervisor. ``host_kill`` ops SIGKILL a worker once
    ``cycle`` responses retired fleet-wide; ``host_stop`` ops SIGSTOP one for
    half the suspect timeout. Extra oracle beyond the shared ones: a stopped
    worker that was never also killed must NOT be evicted (the detector's
    slow-but-alive discrimination, asserted on every fuzzed trajectory)."""
    res = RunResult(trajectory=traj)
    specs = [FaultSpec(step=op.cycle, kind="host_kill",
                       rank=op.slot % GROUP_RANKS)
             for op in traj.ops_of("host_kill")]
    specs += [FaultSpec(step=op.cycle, kind="host_stop",
                        rank=op.slot % GROUP_RANKS,
                        magnitude=MULTIHOST_STOP_PAUSE)
              for op in traj.ops_of("host_stop")]
    sup = MultiHostSupervisor(
        GROUP_RANKS, backend="sim",
        suspect_timeout=MULTIHOST_SUSPECT_TIMEOUT,
        heartbeat_interval=0.05, trace=True, timeout=90.0,
        sim_tokens_per_step=2, sim_step_delay_s=0.01)
    try:
        out = sup.serve(_requests(traj),
                        faults=FaultSchedule(tuple(specs), seed=traj.seed))
    except Exception as exc:                      # oracle 5: nothing escapes
        res.violations.append(f"crash: {type(exc).__name__}: {exc}")
        return res
    res.responses = dict(out.responses)
    killed_ranks = {s.rank for s in specs if s.kind == "host_kill"}
    for rank in out.evicted:
        if rank not in killed_ranks:
            res.violations.append(
                f"false positive: host {rank} evicted but never SIGKILLed "
                f"(stopped={out.stopped}, detection={out.detection.get(rank)})")
    if out.evicted:
        res.cells.add((ErrorCode.RANK_FAILED.name, "evict", traj.engine))
    if out.resumed:
        res.cells.add((ErrorCode.STRAGGLER.name, "resume", traj.engine))
    if specs and killed_ranks and not out.evicted:
        # the kill fired after the drain (or never) — legal, but the
        # mutator's timing search wants to know the op was dead code
        res.summary["kill_noop"] = True
    if any(s.kind == "host_stop" for s in specs) and not out.stopped:
        res.summary["stop_noop"] = True
    if check:
        _check_outcomes(traj, res.responses, reference, res.violations)
        res.violations.extend(
            f"trace: {p}" for p in postmortem.validate(out.trace()))
    res.summary.setdefault("statuses", {})
    for r in res.responses.values():
        res.summary["statuses"][r.status] = (
            res.summary["statuses"].get(r.status, 0) + 1)
    return res


def run_trajectory(traj: Trajectory) -> RunResult:
    """Run one trajectory end to end and apply every oracle. Never raises on
    a stack failure — crashes become violations (counterexamples)."""
    reference = reference_tokens(traj.engine, *traj.load_key)
    runner = {GROUP_ENGINE: _run_group,
              MULTIHOST_ENGINE: _run_multihost}.get(traj.engine, _run_single)
    return runner(traj, reference=reference)
