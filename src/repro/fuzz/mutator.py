"""FaultMutator: seeded trajectory generation, biased toward uncovered cells.

Two modes, chosen per proposal from a per-index generator
(``default_rng((seed, index))`` — trajectory *i* of a campaign is a pure
function of the campaign seed, the index and the coverage state, never of
wall clock or global RNG state):

* **Targeted** (preferred while the universe has holes): pick an uncovered
  ``(code, action, engine)`` cell, look up the code's escalation ladder from
  the real :class:`~repro.core.recovery.RecoveryPolicy`, and emit one ``word``
  op per consecutive window up to the deepest uncovered rung — a single
  trajectory then sweeps every action on that code's ladder (skip →
  restore → rollback) in one run. One code per trajectory: the policy's
  repeat counter is shared across codes, so mixing codes would skew the
  ladder walk.
* **Random / mutate**: draw a fresh random trajectory (any engine, any mix
  of word/poison/page-table/preempt ops), or mutate a coverage-novel parent
  from the campaign pool (add/drop/retune one op, or reshape the load) —
  the classic fuzzing loop that finds the bugs the targeted mode's clean
  ladder walks never would.

Explicit caps (not silent): group trajectories carry exactly one ``kill``
op (sequential multi-kill shrink is out of scope for this corpus), at most
one ``restart`` and one ``rejoin`` op ride along with it (crash-replay and
elastic regrow lanes), multihost trajectories carry at most one
``host_kill`` plus at most one ``host_stop`` (one detection story per run —
the stop-then-kill interleaving is covered, concurrent multi-host loss is
not), and at most ``MAX_OPS`` ops ride any trajectory.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Iterable, Optional, Sequence

import numpy as np

from ..core.errors import ErrorCode
from .coverage import (
    INJECTABLE_CLASSES,
    PAGED_ENGINES,
    CoverageDB,
    action_ladder,
    reachable_cells,
)
from .runner import ENGINE_SPECS, GROUP_RANKS
from .trajectory import (
    ENGINES,
    GROUP_ENGINE,
    HOST_OPS,
    MULTIHOST_ENGINE,
    TP_ENGINES,
    Op,
    Trajectory,
)

MAX_OPS = 6
NUM_SLOTS = 2                       # every runner kit uses two lanes
N_REQUESTS = (2, 3, 4)
PROMPT_LENS = (3, 5, 7)
MAX_NEWS = (5, 8, 12)
RETRIES = (1, 2, 6)

_LADDERS = {code: action_ladder(code) for code in INJECTABLE_CLASSES}


def _pick(rng: np.random.Generator, seq: Sequence):
    return seq[int(rng.integers(len(seq)))]


class FaultMutator:
    """Seeded, coverage-guided trajectory source (see module docstring)."""

    def __init__(self, seed: int, db: CoverageDB,
                 engines: Optional[Iterable[str]] = None,
                 targeted_bias: float = 0.7):
        self.seed = int(seed)
        self.db = db
        self.engines = tuple(engines) if engines else ENGINES
        unknown = set(self.engines) - set(ENGINES)
        if unknown:
            raise ValueError(f"unknown engines: {sorted(unknown)}")
        self.universe = sorted(c for c in reachable_cells()
                               if c[2] in self.engines)
        self.targeted_bias = float(targeted_bias)

    # -------------------------------------------------------------- proposal
    def propose(self, index: int,
                pool: Sequence[Trajectory] = ()) -> Trajectory:
        rng = np.random.default_rng((self.seed, index))
        uncovered = self.db.uncovered(self.universe)
        if uncovered and rng.random() < self.targeted_bias:
            return self._targeted(rng, uncovered)
        if pool and rng.random() < 0.5:
            return self.mutate(_pick(rng, pool), rng)
        return self._random(rng)

    # -------------------------------------------------------------- targeted
    def _targeted(self, rng: np.random.Generator,
                  uncovered: Sequence) -> Trajectory:
        code_name, action, engine = _pick(rng, uncovered)
        if engine == GROUP_ENGINE:
            return self._group(rng, note=f"targeted:{code_name}:{action}",
                               want=action)
        if engine == MULTIHOST_ENGINE:
            return self._multihost(rng,
                                   note=f"targeted:{code_name}:{action}",
                                   want=action)
        base = Trajectory(seed=int(rng.integers(1 << 31)), engine=engine,
                          n_requests=_pick(rng, N_REQUESTS[1:]),
                          prompt_len=_pick(rng, PROMPT_LENS),
                          max_new=_pick(rng, MAX_NEWS[1:]),
                          max_request_retries=6,
                          note=f"targeted:{code_name}:{engine}")
        if code_name == ErrorCode.PAGE_FAULT.name and rng.random() < 0.3:
            # real ledger divergence, not just the word: unmap the device row
            return base.with_ops([Op("page_table",
                                     cycle=int(rng.integers(3, 7)),
                                     slot=int(rng.integers(NUM_SLOTS)))])
        code = ErrorCode[code_name]
        ladder = _LADDERS[code]
        # deepest still-uncovered rung for this (code, engine): one trajectory
        # sweeps the whole ladder prefix, covering every rung on the way down
        holes = {a for c, a, e in uncovered if c == code_name and e == engine}
        depth = max((i + 1 for i, a in enumerate(ladder) if a in holes),
                    default=1)
        start = int(rng.integers(1, 4))
        ops = [Op("word", cycle=start + k, slot=k % NUM_SLOTS,
                  step=int(rng.integers(4)), code=int(code))
               for k in range(min(depth, MAX_OPS))]
        return base.with_ops(ops)

    # ---------------------------------------------------------------- random
    def _random(self, rng: np.random.Generator) -> Trajectory:
        engine = _pick(rng, self.engines)
        if engine == GROUP_ENGINE:
            return self._group(rng, note="random")
        if engine == MULTIHOST_ENGINE:
            return self._multihost(rng, note="random")
        base = Trajectory(seed=int(rng.integers(1 << 31)), engine=engine,
                          n_requests=_pick(rng, N_REQUESTS),
                          prompt_len=_pick(rng, PROMPT_LENS),
                          max_new=_pick(rng, MAX_NEWS),
                          max_request_retries=_pick(rng, RETRIES),
                          note=f"random:{engine}")
        ops = [self._random_op(rng, engine)
               for _ in range(int(rng.integers(MAX_OPS + 1)))]
        return base.with_ops(ops)

    def _random_op(self, rng: np.random.Generator, engine: str) -> Op:
        kinds = ["word", "word", "word", "poison", "preempt"]
        if engine in PAGED_ENGINES:
            kinds.append("page_table")
        kind = _pick(rng, kinds)
        cycle = int(rng.integers(1, 10))
        slot = int(rng.integers(NUM_SLOTS))
        if kind != "word":
            return Op(kind, cycle=cycle, slot=slot)
        code = int(_pick(rng, INJECTABLE_CLASSES))
        if rng.random() < 0.25:       # multi-bit word: combined-code routing
            code |= int(_pick(rng, INJECTABLE_CLASSES))
        shard = -1
        if engine in TP_ENGINES and rng.random() < 0.5:
            # shard-targeted half of the TP corpus: the OR-fold must make a
            # one-shard injection indistinguishable from an all-shard one
            shard = int(rng.integers(ENGINE_SPECS[engine].tp))
        return Op("word", cycle=cycle, slot=slot,
                  step=int(rng.integers(4)), code=code, shard=shard)

    def _group(self, rng: np.random.Generator, *, note: str,
               want: Optional[str] = None) -> Trajectory:
        """One group scenario: a kill, optionally followed by a full-fleet
        ``restart`` (crash-replay from the ledger) and/or a ``rejoin``
        (elastic regrow). ``want`` forces the lane a targeted cell needs."""
        kill_cycle = int(rng.integers(1, 4))
        restart = want == "replay" or (want is None and rng.random() < 0.35)
        rejoin = want == "rejoin" or (want is None and rng.random() < 0.35)
        ops = [Op("kill", cycle=kill_cycle,
                  slot=int(rng.integers(GROUP_RANKS)))]
        if restart:
            # the crash must land before the survivors drain the backlog, so
            # keep it close behind the kill and carry a heavier load below
            ops.append(Op("restart",
                          cycle=kill_cycle + 3 + int(rng.integers(2))))
        if rejoin:
            ops.append(Op("rejoin", cycle=int(rng.integers(1, 3)),
                          slot=int(rng.integers(GROUP_RANKS))))
        heavy = restart or rejoin
        return Trajectory(
            seed=int(rng.integers(1 << 31)), engine=GROUP_ENGINE,
            n_requests=_pick(rng, (8, 10) if heavy else (4, 6)),
            prompt_len=_pick(rng, PROMPT_LENS),
            max_new=_pick(rng, (8, 12) if heavy else MAX_NEWS),
            ops=ops, note=f"{note}:group")

    def _multihost(self, rng: np.random.Generator, *, note: str,
                   want: Optional[str] = None) -> Trajectory:
        """One multihost scenario: a SIGKILL'd worker process (the evict
        lane), a SIGSTOP'd-then-resumed one (the false-positive guard as a
        coverage target), or both on one run. ``want`` forces the lane a
        targeted cell needs (``evict`` → host_kill, ``resume`` →
        host_stop)."""
        kill = want == "evict" or (want is None and rng.random() < 0.7)
        stop = want == "resume" or (want is None and rng.random() < 0.4)
        ops = []
        if kill:
            ops.append(Op("host_kill", cycle=int(rng.integers(1, 4)),
                          slot=int(rng.integers(GROUP_RANKS))))
        if stop or not ops:
            ops.append(Op("host_stop", cycle=int(rng.integers(1, 4)),
                          slot=int(rng.integers(GROUP_RANKS))))
        # heavy-ish load: the faults fire on retire counts, so the fleet
        # must still be mid-decode when the scheduled cycle is reached
        return Trajectory(
            seed=int(rng.integers(1 << 31)), engine=MULTIHOST_ENGINE,
            n_requests=_pick(rng, (8, 10, 12)),
            prompt_len=_pick(rng, PROMPT_LENS),
            max_new=_pick(rng, (8, 12)),
            ops=ops, note=f"{note}:multihost")

    # ---------------------------------------------------------------- mutate
    def mutate(self, parent: Trajectory,
               rng: np.random.Generator) -> Trajectory:
        """One structural edit of a coverage-novel parent."""
        traj = replace(parent, seed=int(rng.integers(1 << 31)),
                       note=f"mutant:{parent.note}")
        ops = list(traj.ops)
        moves = ["add", "load"]
        if ops:
            moves += ["drop", "tweak"]
        move = _pick(rng, moves)
        if move == "add" and traj.engine not in (GROUP_ENGINE,
                                                 MULTIHOST_ENGINE):
            if len(ops) < MAX_OPS:
                ops.append(self._random_op(rng, traj.engine))
        elif move == "drop":
            ops.pop(int(rng.integers(len(ops))))
        elif move == "tweak":
            i = int(rng.integers(len(ops)))
            op = ops[i]
            ops[i] = replace(op, cycle=max(1, op.cycle
                                           + int(rng.integers(-2, 3))),
                             slot=int(rng.integers(
                                 GROUP_RANKS
                                 if op.op == "kill" or op.op in HOST_OPS
                                 else NUM_SLOTS)))
        else:   # load reshape
            traj = replace(traj, n_requests=_pick(rng, N_REQUESTS),
                           prompt_len=_pick(rng, PROMPT_LENS),
                           max_new=_pick(rng, MAX_NEWS),
                           max_request_retries=_pick(rng, RETRIES)
                           if traj.engine != GROUP_ENGINE else 6)
        return traj.with_ops(ops)
