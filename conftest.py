"""Root conftest: make the src-layout package importable without installation,
so a bare ``python -m pytest -x -q`` works (no ``PYTHONPATH=src`` needed).

Also forces two simulated host devices (before any jax import — conftest is
loaded first) so the tensor-parallel serve tests (``tests/test_serve_tp.py``)
can build a 2-way "model" mesh in-process. Single-device suites are unaffected:
their arrays live on device 0 and the computations are identical. A caller who
already set ``XLA_FLAGS`` wins (the TP tests then skip if fewer than 2 devices
come up); the subprocess-based multi-device tests override it themselves."""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def count_syncs(monkeypatch, fn):
    """Run ``fn`` with jax.device_get / jax.block_until_ready instrumented;
    returns (number of host syncs, fn's result). Shared by the host-sync-
    budget tests (window / overlap / speculative suites) so the counting
    methodology cannot silently diverge between them."""
    import jax

    counts = {"n": 0}
    real_get, real_block = jax.device_get, jax.block_until_ready

    def counting_get(x):
        counts["n"] += 1
        return real_get(x)

    def counting_block(x):
        counts["n"] += 1
        return real_block(x)

    monkeypatch.setattr(jax, "device_get", counting_get)
    monkeypatch.setattr(jax, "block_until_ready", counting_block)
    try:
        result = fn()
    finally:
        monkeypatch.setattr(jax, "device_get", real_get)
        monkeypatch.setattr(jax, "block_until_ready", real_block)
    return counts["n"], result
