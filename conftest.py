"""Root conftest: make the src-layout package importable without installation,
so a bare ``python -m pytest -x -q`` works (no ``PYTHONPATH=src`` needed)."""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
