#!/usr/bin/env python
"""Coverage-guided fault-injection fuzz campaign over the serving stack.

Runs ``--budget`` seeded trajectories through the real engines, biased
toward uncovered (error code × recovery action × engine) cells, applies the
oracles (bit-exactness vs the clean run, zero drops, page-ledger invariants,
trace causality), minimizes every counterexample and writes it to
``--corpus``. Exit status is non-zero iff a (non-flaky) counterexample was
found — the CI smoke gates on that.

Usage:
  python scripts/fuzz.py --budget 200 --seed 0            # full sweep
  python scripts/fuzz.py --budget 8 --engines overlap,spec_paged \
      --time-box 240 --no-promote                         # CI smoke
  python scripts/fuzz.py --budget 200 --promote-seeds 10 \
      --corpus tests/fuzz_corpus                          # refresh corpus

The coverage DB (``--db``) persists across campaigns, so successive runs
keep pushing into the uncovered tail instead of re-proving the easy cells.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.fuzz import (  # noqa: E402
    CoverageDB,
    ENGINES,
    FuzzCampaign,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--budget", type=int, default=50,
                    help="trajectories to run (default 50)")
    ap.add_argument("--seed", type=int, default=0,
                    help="campaign seed: trajectories replay from it")
    ap.add_argument("--corpus", default="tests/fuzz_corpus",
                    help="directory for counterexample / seed entries")
    ap.add_argument("--db", default="artifacts/fuzz-out/coverage_db.json",
                    help="persisted coverage DB (JSON)")
    ap.add_argument("--report", default="artifacts/fuzz-out/report.json",
                    help="campaign report path (JSON)")
    ap.add_argument("--engines", default=None,
                    help=f"comma-separated subset of {','.join(ENGINES)}")
    ap.add_argument("--time-box", type=float, default=None,
                    help="wall-clock budget in seconds (truncates the run)")
    ap.add_argument("--promote-seeds", type=int, default=0, metavar="N",
                    help="promote up to N coverage-diverse passing "
                         "trajectories as seed corpus entries")
    ap.add_argument("--no-promote", action="store_true",
                    help="do not write anything to the corpus directory")
    args = ap.parse_args(argv)

    engines = args.engines.split(",") if args.engines else None
    campaign = FuzzCampaign(
        seed=args.seed, db=CoverageDB(args.db),
        corpus_dir=None if args.no_promote else args.corpus,
        engines=engines, time_budget_s=args.time_box)
    rep = campaign.run(args.budget)
    if args.promote_seeds and not args.no_promote:
        rep.promoted = campaign.promote_seeds(args.promote_seeds)

    cov = rep.coverage
    print(f"fuzz: ran {rep.ran}/{rep.budget} trajectories "
          f"({'time-boxed, ' if rep.truncated else ''}{rep.wall_s:.0f}s), "
          f"coverage {cov['covered']}/{cov['universe']} cells "
          f"({100 * cov['fraction']:.1f}%), "
          f"{len(rep.new_cells)} new this run")
    if cov["uncovered"]:
        print("uncovered:", ", ".join(cov["uncovered"][:12])
              + (" ..." if len(cov["uncovered"]) > 12 else ""))
    real = [c for c in rep.counterexamples if not c.get("flaky")]
    flaky = [c for c in rep.counterexamples if c.get("flaky")]
    for c in real:
        print(f"COUNTEREXAMPLE (index {c['index']}):")
        for v in c["violations"]:
            print(f"  - {v}")
        if "path" in c:
            print(f"  promoted: {c['path']}")
    if flaky:
        print(f"note: {len(flaky)} non-reproducing (flaky) failure(s) — "
              "recorded in the report, not promoted")
    for p in rep.promoted:
        print(f"seed entry: {p}")

    if args.report:
        d = os.path.dirname(args.report)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.report, "w") as f:
            json.dump(rep.to_json(), f, indent=1, sort_keys=True)
        print(f"report: {args.report}")
    return 1 if real else 0


if __name__ == "__main__":
    raise SystemExit(main())
