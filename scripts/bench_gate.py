#!/usr/bin/env python
"""Bench-regression tripwire over the BENCH_serving.json run history.

Compares the latest recorded serving run against the BEST of the last three
earlier runs for each engine × scenario cell — the tensor-parallel
``window8_tp2`` cells included, whenever the run carried them (a run on a
single-device box records ``tp_skipped`` and those cells simply drop out of
the comparison, loudly) — plus the paged-capacity, tracer-overhead and
elastic-group cells, when carried, and fails — exit 1 — if tokens/s dropped
by more than the threshold (default 15%). Comparing against the best-of-3 baseline (not just
the single previous run) means one noisy-but-green draw cannot ratchet the
baseline down: a slow-but-passing run N doesn't lower the bar run N+1 must
clear, because runs N-1 and N-2 still anchor it. With fewer than two runs in
the history the gate skips cleanly (exit 0): a fresh clone or a brand-new
benchmark has nothing to regress against.

This reads the *committed* history only — it runs in milliseconds, so it sits
in ``scripts/check.sh`` and CI as a tripwire: a PR that appends a regressed
run (``python -m benchmarks.run --json``, which itself refuses dirty-tree
runs) fails the gate before review ever sees it.

Usage:
  python scripts/bench_gate.py [--history BENCH_serving.json]
                               [--max-regress 0.15]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _cells(record: dict):
    """Flatten one run record into {cell_name: tokens_per_s}."""
    out = {}
    for engine, scens in (record.get("engines") or {}).items():
        if not isinstance(scens, dict):
            continue
        for scen, cell in scens.items():
            if isinstance(cell, dict) and isinstance(
                    cell.get("tokens_per_s"), (int, float)):
                out[f"{engine}/{scen}"] = float(cell["tokens_per_s"])
    paged = record.get("paged")
    if isinstance(paged, dict):
        for side in ("contiguous", "paged"):
            cell = paged.get(side)
            if isinstance(cell, dict) and isinstance(
                    cell.get("tokens_per_s"), (int, float)):
                out[f"paged_capacity/{side}"] = float(cell["tokens_per_s"])
        if isinstance(paged.get("slot_capacity_ratio"), (int, float)):
            out["paged_capacity/slot_ratio"] = float(
                paged["slot_capacity_ratio"])
    tracer = record.get("tracer")
    if isinstance(tracer, dict):
        for side in ("noop", "enabled"):
            cell = tracer.get(side)
            if isinstance(cell, dict) and isinstance(
                    cell.get("tokens_per_s"), (int, float)):
                out[f"tracer/{side}"] = float(cell["tokens_per_s"])
    elastic = record.get("elastic")
    if isinstance(elastic, dict):
        # steady + durable ride the tripwire; the join ratio is asserted
        # inside bench_elastic itself (its best-of reading quantizes on
        # window-retire bursts, too noisy for a 15% history gate)
        for side in ("steady", "durable"):
            cell = elastic.get(side)
            if isinstance(cell, dict) and isinstance(
                    cell.get("tokens_per_s"), (int, float)):
                out[f"elastic/{side}"] = float(cell["tokens_per_s"])
    return out


def gate(history_path: str, max_regress: float) -> int:
    if not os.path.exists(history_path):
        print(f"bench gate: no history at {history_path} — skipping")
        return 0
    try:
        with open(history_path) as f:
            data = json.load(f)
    except json.JSONDecodeError as e:
        print(f"bench gate: {history_path} is not valid JSON ({e})")
        return 1
    runs = data.get("runs") if isinstance(data, dict) else None
    if not isinstance(runs, list) or len(runs) < 2:
        n = len(runs) if isinstance(runs, list) else 0
        print(f"bench gate: history has {n} run(s), need 2 — skipping")
        return 0
    latest = runs[-1]
    latest_cells = _cells(latest)
    if not latest_cells:
        print("bench gate: latest run carries no comparable cells — skipping")
        return 0
    if latest.get("tp_skipped"):
        print("bench gate: latest run skipped the tensor-parallel cells "
              "(single-device box) — window8_tp2 is not being compared")
    # baseline = the 3 most recent earlier runs sharing at least one cell
    # with the latest; each cell is judged against its best value among them
    baseline_runs = []
    for cand in reversed(runs[:-1]):
        if set(_cells(cand)) & set(latest_cells):
            baseline_runs.append(cand)
        if len(baseline_runs) == 3:
            break
    if not baseline_runs:
        print("bench gate: no earlier run shares a cell with the latest — "
              "skipping")
        return 0
    baseline_cells: dict[str, float] = {}
    for cand in baseline_runs:
        for name, v in _cells(cand).items():
            baseline_cells[name] = max(baseline_cells.get(name, v), v)
    failures = []
    compared = 0
    for name in sorted(set(latest_cells) & set(baseline_cells)):
        old, new = baseline_cells[name], latest_cells[name]
        if old <= 0:
            continue
        compared += 1
        change = (new - old) / old
        status = "FAIL" if change < -max_regress else "ok"
        print(f"bench gate: {name:40s} {old:10.1f} -> {new:10.1f} "
              f"({change:+6.1%}) {status}")
        if change < -max_regress:
            failures.append((name, old, new, change))
    revs = ",".join(r.get("git_rev", "?") for r in baseline_runs)
    print(f"bench gate: compared {compared} cell(s), "
          f"{latest.get('git_rev', '?')} vs best of [{revs}]")
    if failures:
        for name, old, new, change in failures:
            print(f"bench gate: REGRESSION {name}: {old:.1f} -> {new:.1f} "
                  f"tok/s ({change:.1%} < -{max_regress:.0%})",
                  file=sys.stderr)
        return 1
    print("bench gate: OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--history", default="BENCH_serving.json",
                    help="run-history file (default: BENCH_serving.json)")
    ap.add_argument("--max-regress", type=float, default=0.15,
                    help="max fractional tokens/s drop (default 0.15)")
    args = ap.parse_args()
    raise SystemExit(gate(args.history, args.max_regress))


if __name__ == "__main__":
    main()
