#!/usr/bin/env python
"""Post-mortem CLI over a serving/training trace_event JSON dump.

Reads the trace a :class:`repro.obs.Tracer` exported (``dump_trace`` /
``GroupResult.trace``) and reconstructs the two things a human wants after a
faulted run:

* **per-request timelines** — every span of one request's life in wall order:
  submit → slot assignment → prefill chunks → decode windows → (faults →
  recovery lanes →) first/terminal token, across replicas if a kill re-routed
  it;
* **the fault-causality report** — one line per fault event joining the exact
  error word (bit-for-bit what ``DeviceFuture.fault_codes()`` read back) to
  the recovery action the policy chose and the recovery-complete span (or the
  terminal FAILED/EXPIRED answer that legally resolved it);
* **group chains** — replica kill → ULFM shrink → ledger re-routes → the
  re-routed requests' terminal statuses on the survivors.

``--check`` runs the same round-trip validation the CI trace smoke relies on
(every traced request reaches exactly one terminal span, every fault
resolves, every kill chains to a shrink, every host eviction was preceded by
detector suspicion and followed by an epoch that excludes the dead host) and
exits non-zero on any problem.

The CI smokes write their trace dumps under the gitignored ``artifacts/``
directory — e.g. ``artifacts/trace-smoke.json``,
``artifacts/multihost-smoke-trace.json``.

Usage:
  python scripts/trace_tool.py artifacts/trace-smoke.json  # report everything
  python scripts/trace_tool.py trace.json --request 7      # one timeline
  python scripts/trace_tool.py trace.json --faults         # fault report only
  python scripts/trace_tool.py trace.json --chains         # membership chains
  python scripts/trace_tool.py trace.json --check          # CI validation
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs import (  # noqa: E402
    format_fault_report,
    format_timeline,
    group_chains,
    load_trace,
    request_timelines,
    validate,
)


def _print_chains(trace: dict) -> None:
    chains = group_chains(trace)
    if not chains:
        print("no replica kills recorded")
        return
    for c in chains:
        shr = ", ".join(
            f"r{s['pid']}@{s['ts'] / 1e3:.1f}ms" for s in c["shrinks"])
        print(f"replica {c['dead_rank']} killed "
              f"@{c['kill']['ts'] / 1e3:.1f}ms -> shrink observed by "
              f"[{shr or 'NOBODY'}] -> {len(c['reroutes'])} request(s) "
              "re-routed:")
        for r in c["reroutes"]:
            a = r.get("args", {})
            tid = a.get("trace_id")
            if tid is None:
                tid = a.get("request")
            term = c["terminals"].get(tid)
            status = (term.get("args", {}).get("status")
                      if term is not None else "UNANSWERED")
            print(f"  request {a.get('request')} "
                  f"r{a.get('from_rank')} -> r{a.get('to_rank')}: {status}")
        for j in c.get("rejoins", ()):
            a = j.get("args", {})
            print(f"  rank {a.get('rank')} REJOINED "
                  f"@{j['ts'] / 1e3:.1f}ms epoch {a.get('epoch')} "
                  f"({a.get('reason')}, {j.get('dur', 0.0) / 1e3:.1f}ms "
                  "warm-up to first exchange)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="reconstruct causal timelines from a trace_event dump")
    ap.add_argument("trace", help="trace_event JSON file")
    ap.add_argument("--request", type=int, default=None,
                    help="print one request's timeline (by trace id)")
    ap.add_argument("--faults", action="store_true",
                    help="print only the fault-causality report")
    ap.add_argument("--chains", action="store_true",
                    help="print only the group membership chains "
                         "(kill -> shrink -> reroute -> rejoin)")
    ap.add_argument("--check", action="store_true",
                    help="validate the trace round-trip; exit 1 on problems")
    args = ap.parse_args(argv)

    trace = load_trace(args.trace)
    n = len(trace.get("traceEvents", []))

    if args.check:
        problems = validate(trace)
        if problems:
            print(f"{args.trace}: {len(problems)} problem(s) in {n} events:")
            for p in problems:
                print(f"  FAIL {p}")
            return 1
        timelines = request_timelines(trace)
        print(f"{args.trace}: OK — {n} events, {len(timelines)} traced "
              "request(s), every fault resolved, every request answered")
        return 0

    if args.request is not None:
        print(format_timeline(trace, args.request))
        return 0

    if args.faults:
        print(format_fault_report(trace))
        return 0

    if args.chains:
        _print_chains(trace)
        return 0

    timelines = request_timelines(trace)
    print(f"{args.trace}: {n} events, {len(timelines)} traced request(s)")
    print()
    for tid in sorted(timelines, key=lambda t: (t is None, t)):
        print(format_timeline(trace, tid))
        print()
    print(format_fault_report(trace))
    print()
    _print_chains(trace)
    return 0


if __name__ == "__main__":
    sys.exit(main())
