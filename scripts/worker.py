#!/usr/bin/env python
"""Standalone multi-host worker entrypoint.

One of these runs per "host" under the :class:`repro.serve.multihost`
supervisor. It is a thin shim: make ``src/`` importable when launched from a
checkout, then hand over to :func:`repro.serve.multihost.worker_main`, which
implements the whole worker protocol (hello → heartbeats → work/exchange →
retire → trace/bye).

Usage (normally the supervisor launches this for you)::

    python scripts/worker.py --spec '<json worker spec>'
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.serve.multihost import worker_main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(worker_main())
