"""Assemble EXPERIMENTS.md from dry-run artifacts + perf runs + bench output."""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.roofline_table import table  # noqa: E402

ART = Path("artifacts")


def dryrun_summary() -> str:
    recs = [json.loads(p.read_text()) for p in (ART / "dryrun").glob("*.json")]
    out = []
    for mesh in ("16x16", "2x16x16"):
        ms = [r for r in recs if r["mesh"] == mesh]
        ok = [r for r in ms if r.get("ok") and not r.get("skipped")]
        skip = [r for r in ms if r.get("skipped")]
        fail = [r for r in ms if not r.get("ok")]
        chips = 256 if mesh == "16x16" else 512
        out.append(f"* **{mesh}** ({chips} chips): {len(ok)} cells compiled, "
                   f"{len(skip)} documented skips, {len(fail)} failures.")
        if ok:
            worst = max(ok, key=lambda r: r["memory"]["peak_live_bytes"])
            out.append(f"  - largest per-device footprint: {worst['arch']} × "
                       f"{worst['shape']} = "
                       f"{worst['memory']['peak_live_bytes']/2**30:.1f} GiB "
                       "(see §Perf: microbatching brings the over-HBM train "
                       "cells under 16 GiB)")
            slow = max(ok, key=lambda r: r.get("compile_s", 0))
            out.append(f"  - slowest compile: {slow['arch']} × {slow['shape']} "
                       f"= {slow['compile_s']:.0f}s (scan-over-periods keeps "
                       "HLO size depth-independent)")
    return "\n".join(out)


def perf_rows() -> str:
    rows = ["| cell | variant | compute_s | memory_s | collective_s | dom | "
            "frac | mem GiB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    base = {}
    for p in sorted((ART / "dryrun").glob("*__16x16.json")):
        r = json.loads(p.read_text())
        if r.get("ok") and not r.get("skipped"):
            base[(r["arch"], r["shape"])] = r
    wanted = [("qwen3-moe-30b-a3b", "train_4k"), ("qwen3-1.7b", "decode_32k"),
              ("qwen3-1.7b", "train_4k")]
    for arch, shape in wanted:
        b = base.get((arch, shape))
        if b:
            r = b["roofline"]
            rows.append(
                f"| {arch} × {shape} | **baseline (paper-faithful)** | "
                f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                f"{r['collective_s']:.3f} | {r['dominant'][:4]} | "
                f"{r['roofline_fraction']:.4f} | "
                f"{b['memory']['peak_live_bytes']/2**30:.1f} |")
        for p in sorted((ART / "perf").glob(f"{arch}__{shape}__*.json")):
            rec = json.loads(p.read_text())
            if not rec.get("ok"):
                rows.append(f"| {arch} × {shape} | {p.stem.split('__')[-1]} | "
                            f"FAIL | | | | | |")
                continue
            r = rec["roofline"]
            rows.append(
                f"| {arch} × {shape} | {rec.get('perf') or 'base (re-measured, final methodology)'} | "
                f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                f"{r['collective_s']:.3f} | {r['dominant'][:4]} | "
                f"{r['roofline_fraction']:.4f} | "
                f"{rec['memory']['peak_live_bytes']/2**30:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run summary\n")
        print(dryrun_summary())
    if which in ("all", "roofline"):
        print("\n### Roofline table (single-pod 16×16, per-step seconds)\n")
        print(table("16x16"))
    if which in ("all", "perf"):
        print("\n### Perf variants\n")
        print(perf_rows())
