#!/usr/bin/env bash
# Local CI gate: tier-1 tests + serving smokes + bench-regression tripwire.
#
#   scripts/check.sh                 # full suite + all smokes + bench gate
#   scripts/check.sh --fast          # tier-1 tests only (quick pre-push loop)
#   scripts/check.sh -k serve        # pass pytest args through
#   JUNIT_XML=out.xml scripts/check.sh   # also write pytest junit XML (CI)
#
# Runs every stage even if an earlier one fails, prints per-stage wall-clock
# timing, then exits nonzero if any stage did.
set -uo pipefail
cd "$(dirname "$0")/.."

status=0
FAST=0
PYTEST_ARGS=()
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        *) PYTEST_ARGS+=("$arg") ;;
    esac
done

# stage NAME CMD...: run CMD, report [stage] NAME: OK|FAILED (12.3s)
stage() {
    local name="$1"; shift
    local t0 t1
    t0=$(date +%s.%N)
    if "$@"; then
        local rc=0
    else
        local rc=1
        status=1
    fi
    t1=$(date +%s.%N)
    local dt
    dt=$(awk -v a="$t0" -v b="$t1" 'BEGIN { printf "%.1f", b - a }')
    if [ "$rc" -eq 0 ]; then
        echo "[stage] $name: OK (${dt}s)"
    else
        echo "[stage] $name: FAILED (${dt}s)"
    fi
    echo
}

run_pytest() {
    local junit=()
    if [ -n "${JUNIT_XML:-}" ]; then
        junit=(--junitxml "$JUNIT_XML")
    fi
    python -m pytest -q ${junit[@]+"${junit[@]}"} \
        ${PYTEST_ARGS[@]+"${PYTEST_ARGS[@]}"}
}

run_example() { python examples/serve_with_faults.py > /dev/null; }

run_bench_smoke() {
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.serving --smoke "$@"
}

echo "== tier-1: python -m pytest -q ${PYTEST_ARGS[*]:-} =="
stage "tier-1 tests" run_pytest

# the speculative smoke is cheap enough to keep in the --fast loop: it is
# the only end-to-end guard on draft/verify bit-exactness
echo "== spec smoke: benchmarks.serving --smoke --spec =="
stage "spec smoke" run_bench_smoke --spec

if [ "$FAST" -eq 1 ]; then
    echo "(--fast: skipping remaining smokes + bench gate)"
    exit $status
fi

echo "== serve smoke: examples/serve_with_faults.py =="
stage "serve smoke" run_example

echo "== decode-hotpath smoke: benchmarks.serving --smoke =="
stage "decode-hotpath smoke" run_bench_smoke

echo "== overlap smoke: benchmarks.serving --smoke --overlap =="
stage "overlap smoke" run_bench_smoke --overlap

echo "== paged smoke: benchmarks.serving --smoke --paged =="
stage "paged smoke" run_bench_smoke --paged

# every smoke writes its file artifacts (traces, WALs, fuzz state) under
# this gitignored directory — CI uploads it wholesale, the repo root stays
# clean (benchmarks.serving honours the same default)
ARTIFACTS="${REPRO_ARTIFACTS:-artifacts}"

# trace smoke writes artifacts/trace-smoke.json; the post-mortem CLI then
# re-validates it from disk — the artifact CI uploads is the one that
# passed the check
run_trace_smoke() {
    run_bench_smoke --trace \
        && python scripts/trace_tool.py "$ARTIFACTS/trace-smoke.json" --check
}
echo "== trace smoke: benchmarks.serving --smoke --trace + trace_tool =="
stage "trace smoke" run_trace_smoke

# elastic smoke: kill a rank, crash the WHOLE fleet mid-flight, restart from
# the write-ahead ledger alone, regrow via the non-blocking join — zero
# drops, bit-exact streams, and the merged two-incarnation trace passes the
# same post-mortem check; the ledger + trace CI uploads are the artifacts
# that passed
run_elastic_smoke() {
    run_bench_smoke --elastic \
        && python scripts/trace_tool.py \
            "$ARTIFACTS/elastic-smoke-trace.json" --check
}
echo "== elastic smoke: benchmarks.serving --smoke --elastic + trace_tool =="
stage "elastic smoke" run_elastic_smoke

# tensor-parallel smoke: tp=2 on forced host devices — token-bit-exact vs
# the single-device engine (steady + one-shard injection), shard loss in a
# group shrinks with zero drops, and the dumped trace re-validates from disk
run_tp_smoke() {
    XLA_FLAGS="--xla_force_host_platform_device_count=2${XLA_FLAGS:+ $XLA_FLAGS}" \
        run_bench_smoke --tp \
        && python scripts/trace_tool.py "$ARTIFACTS/tp-smoke-trace.json" --check
}
echo "== tp smoke: benchmarks.serving --smoke --tp + trace_tool =="
stage "tp smoke" run_tp_smoke

# multi-host smoke: 3 real worker processes under the heartbeat supervisor;
# SIGKILL one mid-decode (detect -> evict -> WAL re-route, zero drops,
# bit-exact) and SIGSTOP another inside the suspect timeout (suspected,
# cleared, never evicted); the merged trace re-validates from disk.
# Time-boxed: a hung worker/supervisor must fail the stage, not wedge CI.
run_multihost_smoke() {
    timeout 300 env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.serving --smoke --multihost \
        && python scripts/trace_tool.py \
            "$ARTIFACTS/multihost-smoke-trace.json" --check
}
echo "== multihost smoke: benchmarks.serving --smoke --multihost + trace_tool =="
stage "multihost smoke" run_multihost_smoke

# time-boxed coverage-guided fuzz sweep over two representative engines; a
# nonzero exit means a reproducible counterexample was found (and written to
# tests/fuzz_corpus by a full run — the smoke uses --no-promote so CI never
# commits corpus entries, it only fails loudly and uploads artifacts/fuzz-out/)
run_fuzz_smoke() {
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python scripts/fuzz.py --budget 8 --seed 0 \
        --engines overlap,overlap_paged --time-box 300 --no-promote \
        --db "$ARTIFACTS/fuzz-out/coverage_db.json" \
        --report "$ARTIFACTS/fuzz-out/report.json"
}
echo "== fuzz smoke: scripts/fuzz.py --budget 8 --time-box 300 =="
stage "fuzz smoke" run_fuzz_smoke

echo "== bench-regression gate: scripts/bench_gate.py =="
stage "bench gate" python scripts/bench_gate.py

if [ "${#PYTEST_ARGS[@]}" -gt 0 ]; then
    # tier-1 was filtered by pass-through args: still guarantee the serving
    # suites ran (an unfiltered tier-1 run already collects them)
    echo "== serve tests: tests/test_serve_{overlap,paged,spec}.py =="
    stage "serve tests" python -m pytest -q tests/test_serve_overlap.py \
        tests/test_serve_paged.py tests/test_page_allocator.py \
        tests/test_serve_spec.py
fi

exit $status
