#!/usr/bin/env bash
# Local CI gate: tier-1 tests + serving smoke.
#
#   scripts/check.sh             # full suite + smoke
#   scripts/check.sh -k serve    # pass pytest args through
#
# Runs both stages even if the first fails, then exits nonzero if either did.
set -uo pipefail
cd "$(dirname "$0")/.."

status=0

echo "== tier-1: python -m pytest -q $* =="
python -m pytest -q "$@" || status=1

echo
echo "== serve smoke: examples/serve_with_faults.py =="
if python examples/serve_with_faults.py > /dev/null; then
    echo "serve smoke: OK"
else
    echo "serve smoke: FAILED"
    status=1
fi

echo
echo "== decode-hotpath smoke: benchmarks.serving --smoke =="
if PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.serving --smoke; then
    echo "decode-hotpath smoke: OK"
else
    echo "decode-hotpath smoke: FAILED"
    status=1
fi

echo
echo "== overlap smoke: benchmarks.serving --smoke --overlap =="
if PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.serving --smoke --overlap; then
    echo "overlap smoke: OK"
else
    echo "overlap smoke: FAILED"
    status=1
fi

if [ "$#" -gt 0 ]; then
    # tier-1 was filtered by pass-through args: still guarantee the overlap
    # suite ran (an unfiltered tier-1 run already collects it)
    echo
    echo "== overlap tests: tests/test_serve_overlap.py =="
    if python -m pytest -q tests/test_serve_overlap.py; then
        echo "overlap tests: OK"
    else
        echo "overlap tests: FAILED"
        status=1
    fi
fi

exit $status
