import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf hillclimb driver: compile perf variants of the three chosen cells and
record roofline terms to artifacts/perf/."""
import json, sys
from pathlib import Path
sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell
from repro.launch.steps import PerfOptions

OUT = Path("artifacts/perf"); OUT.mkdir(parents=True, exist_ok=True)

RUNS = [
    # re-measured baselines (same methodology as the variants)
    ("qwen3-moe-30b-a3b", "train_4k", "base", ""),
    ("qwen3-1.7b", "decode_32k", "base", ""),
    ("qwen3-1.7b", "train_4k", "base", ""),
    # cell A: worst train roofline fraction + over-memory (MoE)
    ("qwen3-moe-30b-a3b", "train_4k", "mb8",          "mb=8"),
    ("qwen3-moe-30b-a3b", "train_4k", "mb8_ep",       "mb=8,ep=1"),
    ("qwen3-moe-30b-a3b", "train_4k", "mb8_ep_ce",    "mb=8,ep=1,ce=2048"),
    ("qwen3-moe-30b-a3b", "train_4k", "mb8_ep_ce_sp", "mb=8,ep=1,ce=2048,sp=1"),
    # cell B: most collective-bound (decode)
    ("qwen3-1.7b", "decode_32k", "cacheseq",        "cacheseq=1"),
    # cell C: paper-representative (the in-band channel rides this step)
    ("qwen3-1.7b", "train_4k", "noprobe",           "probes=0"),
    ("qwen3-1.7b", "train_4k", "ce",                "ce=2048"),
    ("qwen3-1.7b", "train_4k", "ce_sp",             "ce=2048,sp=1"),
    ("qwen3-1.7b", "train_4k", "ce_sp_mb",          "ce=2048,sp=1,mb=4"),
]

for arch, shape, tag, spec in RUNS:
    perf = PerfOptions.parse(spec)
    rec = run_cell(arch, shape, multi_pod=False, perf=perf)
    rec["perf"] = spec
    (OUT / f"{arch}__{shape}__{tag}.json").write_text(json.dumps(rec, indent=1))
    if rec["ok"]:
        r = rec["roofline"]
        print(f"{arch:22s} {shape:12s} {tag:10s} [{spec:22s}] "
              f"comp={r['compute_s']:.3f} mem={r['memory_s']:.3f} "
              f"coll={r['collective_s']:.3f} dom={r['dominant'][:4]} "
              f"frac={r['roofline_fraction']:.3f} "
              f"hbm/dev={rec['memory']['peak_live_bytes']/2**30:.1f}GiB",
              flush=True)
    else:
        print(f"{arch} {shape} {tag} FAILED: {rec['error']}", flush=True)
