"""Elastic shrink demo: hard fault → ULFM shrink → LFLR restore → keep training.

    PYTHONPATH=src python examples/elastic_shrink.py

Runs the paper's full multi-controller choreography on the simulated cluster:
6 data-parallel hosts train a shared model through Comm/Future (every gradient
all-reduce is a Future whose wait() can raise the paper's exceptions). At step
10, host 2 dies (simulated node loss). The ULFM failure detector turns the
survivors' waits into CommCorruptedError; they agree, shrink 6→5, restore from
the buddy store, re-partition the batch stream, and finish all 30 steps.
"""
import sys

sys.path.insert(0, "src")

from repro.core.faults import FaultSchedule, FaultSpec  # noqa: E402
from repro.launch.elastic import elastic_train  # noqa: E402


def main():
    faults = FaultSchedule([
        FaultSpec(step=10, kind="kill", rank=2),
        FaultSpec(step=20, kind="nan_grad", rank=4),
    ])
    print("elastic training: 6 hosts, kill rank 2 @ step 10, "
          "NaN-grad on rank 4 @ step 20\n")
    results = elastic_train(6, steps=30, lr=0.2, faults=faults)
    for r in results:
        if r.killed:
            print(f"rank {r.rank}: DIED (hard fault)")
            continue
        if r.exception is not None:
            print(f"rank {r.rank}: EXCEPTION {r.exception!r}")
            continue
        v = r.value
        evs = "; ".join(f"{k}@{s}" + (f"→world={w}" if k == "shrink" else
                                      f" from ranks {w}")
                        for k, s, w in v.events)
        print(f"rank {r.rank}: steps={v.steps_done} "
              f"world {v.world_sizes[0]}→{v.world_sizes[-1]} "
              f"loss={v.final_loss:.2e} [{evs}]")
    survivors = [r.value for r in results if not r.killed and r.exception is None]
    assert all(v.world_sizes[-1] == 5 for v in survivors)
    print("\nall survivors finished on the shrunk (5-host) communicator; "
          "final losses < 5e-2 show training recovered.")


if __name__ == "__main__":
    main()
