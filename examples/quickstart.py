"""Quickstart: resilient training end-to-end in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py [--steps 60] [--arch gemma3-1b]

Trains a reduced-config model on the deterministic synthetic stream through the
ResilientExecutor (detection + recovery always on), injecting one NaN-gradient
soft fault midway to show the propagate→skip path, and prints the loss curve.

Scale note: the same `make_train_step` is what the multi-pod dry-run lowers at
(16,16) / (2,16,16) mesh scale — see `repro.launch.dryrun`. For a ~100M-param
run use: --arch qwen3-1.7b --layers 8 --d-model 512 --steps 300 (slower).
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402

from repro.configs import smoke_config  # noqa: E402
from repro.core import (  # noqa: E402
    ExecutorConfig,
    FaultSchedule,
    FaultSpec,
    ResilientExecutor,
)
from repro.core.recovery import RecoveryPolicy  # noqa: E402
from repro.launch.steps import make_reset_opt_fn  # noqa: E402
from repro.launch.train import build_train_setup  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model)
    print(f"arch={cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model}) "
          f"batch={args.batch} seq={args.seq}")

    model, step_fn, state, pipe, _ = build_train_setup(
        cfg, batch_size=args.batch, seq_len=args.seq, total_steps=args.steps,
        lr=1e-3)
    executor = ResilientExecutor(
        step_fn, policy=RecoveryPolicy(can_shrink=False),
        config=ExecutorConfig(good_state_interval=10),
        reset_opt_fn=make_reset_opt_fn(cfg))

    faults = FaultSchedule([FaultSpec(step=args.steps // 2, kind="nan_grad")])

    probe_batch = next(iter(pipe))
    (_, m0) = executor.dispatch(state, probe_batch).wait()
    loss0 = float(m0["loss"])

    state, log = executor.run(state, iter(pipe), args.steps, faults=faults)
    ok = [e for e in log.events if e.kind == "ok"]
    fl = log.faults()
    print(f"\ncompleted {len(ok)} steps, {len(fl)} fault(s) handled:")
    for e in fl:
        print(f"  step {e.step}: code={e.code:#x} -> {e.action} ({e.detail})")
    print(f"final step counter: {int(state['step'])}")
    (_, metrics) = executor.dispatch(state, probe_batch).wait()
    loss1 = float(metrics["loss"])
    print(f"loss on probe batch: {loss0:.3f} -> {loss1:.3f} "
          f"(uniform ≈ {float(jnp.log(cfg.vocab_size)):.2f})")
    assert loss1 < loss0, "training did not descend"


if __name__ == "__main__":
    main()
