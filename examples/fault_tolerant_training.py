"""Fault-tolerant training demo: the paper's full exception taxonomy in one run.

    PYTHONPATH=src python examples/fault_tolerant_training.py

Injects, in one training run: a NaN gradient (skip), a corrupted batch (skip),
a loss spike (optimizer reset + lr decay — paper use case 2 'hierarchical
escalation'), a repeated-NaN burst (LFLR restore, then rollback from the async
disk checkpoint — use cases 1 and 3), and a straggler (watchdog). Prints the
event log: one line per exception → decision → recovery action.
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import smoke_config  # noqa: E402
from repro.checkpoint import Checkpointer  # noqa: E402
from repro.core import (  # noqa: E402
    ExecutorConfig,
    FaultSchedule,
    FaultSpec,
    ResilientExecutor,
)
from repro.core.recovery import RecoveryPolicy  # noqa: E402
from repro.launch.steps import make_reset_opt_fn  # noqa: E402
from repro.launch.train import build_train_setup  # noqa: E402


def main():
    cfg = smoke_config("qwen3-1.7b")
    model, step_fn, state, pipe, _ = build_train_setup(
        cfg, batch_size=4, seq_len=32, total_steps=60)

    faults = FaultSchedule([
        FaultSpec(step=8, kind="nan_grad"),
        FaultSpec(step=14, kind="bad_data"),
        FaultSpec(step=20, kind="spike_loss"),
        FaultSpec(step=30, kind="nan_loss"),
        FaultSpec(step=31, kind="nan_loss"),
        FaultSpec(step=32, kind="nan_loss"),
        FaultSpec(step=33, kind="nan_loss"),
        FaultSpec(step=34, kind="nan_loss"),
        FaultSpec(step=45, kind="straggle", magnitude=0.6),
    ])

    with tempfile.TemporaryDirectory() as d:
        executor = ResilientExecutor(
            step_fn,
            policy=RecoveryPolicy(can_shrink=False, max_soft_retries=3,
                                  escalate_window=10),
            config=ExecutorConfig(good_state_interval=5,
                                  checkpoint_interval=10),
            checkpointer=Checkpointer(d),
            reset_opt_fn=make_reset_opt_fn(cfg))
        state, log = executor.run(state, iter(pipe), 55, faults=faults)
        executor.checkpointer.wait()

        print(f"\n=== event log ({cfg.name}, 55 steps) ===")
        for e in log.events:
            if e.kind == "ok":
                continue
            print(f"step {e.step:3d} | {e.kind:10s} | code={e.code:#010x} | "
                  f"action={e.action or '-':16s} | {e.detail}")
        n_ok = sum(1 for e in log.events if e.kind == "ok")
        print(f"\n{n_ok} clean steps; survived "
              f"{len(log.faults())} faults + 1 straggler; "
              f"final step={int(state['step'])}")


if __name__ == "__main__":
    main()
