"""Serving demo: batched decode with the in-band channel guarding generation.

    PYTHONPATH=src python examples/serve_with_faults.py

Prefills a small batch of prompts on a reduced recurrentgemma (hybrid RG-LRU +
local attention — O(1) state per token), then decodes with the jitted
serve step. Midway we corrupt the recurrent state (a simulated SDC bit-flip in
the SSM-state — the paper's soft-fault class); the DeviceFuture raises
PropagatedError(STATE_FAULT), and the serving loop recovers by re-prefilling
the affected sequences (LFLR for inference: recompute, don't restart).
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import smoke_config  # noqa: E402
from repro.core import DeviceFuture, PropagatedError  # noqa: E402
from repro.launch.steps import make_decode_step  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    cfg = smoke_config("recurrentgemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, prompt_len, gen_len = 4, 8, 12
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len), 0,
                                 cfg.vocab_size)

    decode = jax.jit(make_decode_step(cfg))

    def prefill_via_decode():
        cache = model.init_cache(B, 64)
        tok = prompts[:, :1]
        for pos in range(prompt_len):
            logits, cache, word = decode(params, cache, prompts[:, pos:pos+1],
                                         jnp.int32(pos))
        return cache, logits

    cache, logits = prefill_via_decode()
    print(f"prefilled {B} prompts of {prompt_len} tokens ({cfg.name})")

    generated = []
    pos = prompt_len
    steps = 0
    injected = False
    while steps < gen_len:
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if steps == 5 and not injected:
            injected = True
            # SDC injection: NaN the RG-LRU hidden state of one sequence (once)
            def poison(path, leaf):
                keys = [getattr(k, "key", None) for k in path]
                if "h" in keys and leaf.ndim >= 2:
                    return leaf.at[(0,) * (leaf.ndim - 1) + (0,)].set(jnp.nan)
                return leaf
            cache = jax.tree_util.tree_map_with_path(poison, cache)
            print("step 5: injected NaN into recurrent state (simulated SDC)")
        logits_new, cache_new, word = decode(params, cache, tok, jnp.int32(pos))
        fut = DeviceFuture(outputs=(logits_new, cache_new), word=word)
        try:
            logits, cache = fut.wait()
            generated.append(int(tok[0, 0]))
            pos += 1
            steps += 1
        except PropagatedError as e:
            print(f"step {steps}: caught {e} -> LFLR: re-prefill (recompute "
                  "state from the prompt + generated tokens)")
            cache, logits = prefill_via_decode()
            # replay already-generated tokens to rebuild state
            pos = prompt_len
            for t in generated:
                tokr = jnp.full((B, 1), t, jnp.int32)
                logits, cache, _ = decode(params, cache, tokr, jnp.int32(pos))
                pos += 1
    print(f"generated {steps} tokens/seq after recovery; "
          f"first sequence: {generated}")


if __name__ == "__main__":
    main()
