"""Serving demo on the ``repro.serve`` subsystem: continuous batching with the
paper's fault machinery fused in.

    PYTHONPATH=src python examples/serve_with_faults.py

Act 1 — one replica, a soft fault. A :class:`Replica` continuously batches
requests through the **stall-free decode window** engine (``window=4``,
overlapped admission): four greedy steps run fused on device per dispatch,
fault detection deferred to the window boundary, and every admission rides
the windows as a background prefill lane — chunked prompt tokens fed inside
the same scan, so the host never blocks on a prefill (reduced
recurrentgemma: hybrid RG-LRU + local attention, O(1) state per token).
Midway we flip a bit of one sequence's recurrent state (a simulated SDC —
the paper's soft-fault class). The ``DeviceFuture`` raises
``PropagatedError`` at the *window* wait; the ``(K, slots)`` word history
names the poisoned ``(step, slot)``, the clean prefix commits, and the
replica re-queues just that sequence as a fresh lane (LFLR: recompute,
don't restart) while its batch-mates keep decoding — recovery overlaps
progress, the paper's asynchrony applied end to end.

Act 2 — a replica fleet, a hard fault. A :class:`ServeGroup` of three
replicas serves a request stream; we kill one replica mid-flight. Survivors'
next health exchange raises (ULFM revoke → agree), they shrink 3 → 2 and
re-route the dead replica's unanswered requests — every accepted request is
answered, nothing deadlocks, nothing aborts.

Act 3 — the fleet itself dies, and comes back. The same group serves with a
durable write-ahead ledger (every submit / route / retirement a checksummed,
fsync'd record); we kill one replica mid-flight, then stop the *whole fleet*
two rounds later — the SIGKILL analogue, only the log survives. A new
incarnation restarts from the ledger alone (``serve_from_ledger``): answered
requests return bit-exact from their retire records, outstanding ones replay
onto the survivors, and the killed rank re-enters through the non-blocking
join (warm-up + state transfer as a background lane, then one widened epoch
— survivors never stall). Zero requests dropped across the crash, every
token stream bit-exact vs a clean run.

All acts run with fault-causality tracing on (``repro.obs``, DESIGN
§3.5/§3.7): every request's life is a span chain, every fault event carries
the exact device error word, and the merged traces — kill → shrink →
re-route → fleet stop → ledger replay → rejoin included — are dumped to
``artifacts/serve-trace.json`` / ``artifacts/serve-crash-trace.json`` (open
them in Perfetto, or run ``python scripts/trace_tool.py <file> --chains``)
and pretty-printed here.
"""
import json
import os
import sys

sys.path.insert(0, "src")

ARTIFACTS = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def _artifact(name):
    os.makedirs(ARTIFACTS, exist_ok=True)
    return os.path.join(ARTIFACTS, name)

from repro.configs import smoke_config  # noqa: E402
from repro.core.faults import FaultSchedule, FaultSpec  # noqa: E402
from repro.obs import (  # noqa: E402
    Tracer,
    dump_trace,
    format_fault_report,
    format_timeline,
    group_chains,
    merge_trace_dicts,
    merge_traces,
    validate,
)
from repro.serve import EngineConfig, Replica, Request, ServeGroup  # noqa: E402


def act1_soft_fault(cfg):
    print("=== Act 1: decode windows + per-sequence LFLR on one replica ===")
    tracer = Tracer()
    replica = Replica(cfg, config=EngineConfig(num_slots=4, max_len=48,
                                               window=4), tracer=tracer)
    for i in range(6):      # 6 requests onto 4 slots: backfill is exercised
        rej = replica.submit(Request(id=i, prompt=(11 + i, 22 + i, 33 + i),
                                     max_new_tokens=12))
        assert rej is None, rej
    responses, steps = [], 0
    while not replica.idle():
        if steps == 1:
            slot = replica.inject_state_fault()
            print(f"window 1: injected NaN into slot {slot}'s recurrent "
                  "state (simulated SDC)")
        responses.extend(replica.step())
        steps += 1
    for r in sorted(responses, key=lambda r: r.id):
        print(f"  request {r.id}: {r.status}, tokens={list(r.tokens)}, "
              f"retries={r.retries}")
    s = replica.metrics.summary()
    print(f"  faults seen: {s['faults']}  |  {s['windows']} windows, "
          f"{s['discarded_tokens']} trailing tokens discarded  |  "
          f"{s['tokens_per_s']:.0f} tok/s, "
          f"p50 latency {s['latency_p50_s'] * 1e3:.0f} ms")
    print(f"  stall-free: {s['prefill_chunks']} prompt chunks fused into "
          f"windows ({s['prefill_chunk_tokens']} tokens), "
          f"{s['host_stalls']} blocking prefills, "
          f"TTFT p50 {s['ttft_p50_s'] * 1e3:.0f} ms")
    assert s["host_stalls"] == 0, "overlapped engine must never block"
    # the post-mortem view of the same run: the fault event carries the exact
    # device error word, joined to the recovery lane that resolved it
    trace = merge_traces(tracer)
    problems = validate(trace)
    assert not problems, problems
    print("  fault causality (repro.obs):")
    for line in format_fault_report(trace).splitlines():
        print(f"  {line}")
    faulted = [r for r in responses if r.retries]
    if faulted:
        print("  timeline of the faulted request:")
        for line in format_timeline(trace, faulted[0].trace_id).splitlines():
            print(f"  {line}")
    print()


def act2_hard_fault(cfg):
    print("=== Act 2: replica kill -> shrink + re-route on a ServeGroup ===")
    group = ServeGroup(cfg, 3, config=EngineConfig(num_slots=2, max_len=48,
                                                   trace=True))
    requests = [Request(id=i, prompt=(5 + i, 6 + i, 7 + i), max_new_tokens=6)
                for i in range(9)]
    result = group.serve(requests, faults=FaultSchedule(
        [FaultSpec(step=2, kind="kill", rank=1)]))
    print(f"  killed replicas: {[r.rank for r in result.reports if r.killed]}")
    print(f"  re-routed requests: {list(result.rerouted)}")
    for rank in (0, 2):
        report = result.report(rank)
        print(f"  rank {rank} events: {report.events}")
    answered = {i: r.status for i, r in sorted(result.responses.items())}
    by_replica = {}
    for r in result.responses.values():
        by_replica.setdefault(r.replica, 0)
        by_replica[r.replica] += 1
    print(f"  statuses: {answered}")
    print(f"  answered per replica: {by_replica}")
    assert all(r.ok for r in result.responses.values())
    print("  all accepted requests answered despite the kill — no deadlock, "
          "no abort")
    # the merged trace stitches all three ranks — the dead one included —
    # into one causal object: kill -> ulfm shrink -> ledger re-route ->
    # terminal answers on the survivors
    trace_path = _artifact("serve-trace.json")
    trace = dump_trace(trace_path, *(result.tracers[r]
                                     for r in sorted(result.tracers)))
    problems = validate(trace)
    assert not problems, problems
    n = len(trace["traceEvents"])
    print(f"  trace: {n} events from 3 replicas -> {trace_path} "
          "(perfetto/chrome://tracing, or scripts/trace_tool.py)")
    for c in group_chains(trace):
        routed = ", ".join(
            f"req {(r.get('args') or {}).get('request')}"
            f"->r{(r.get('args') or {}).get('to_rank')}"
            for r in c["reroutes"])
        print(f"  chain: replica {c['dead_rank']} killed -> shrink seen by "
              f"{sorted({s['pid'] for s in c['shrinks']})} -> [{routed}]")
    summary = result.summary()
    print(f"  fleet summary (merged): {summary['requests']} requests, "
          f"{summary['replicas']} replicas ({summary['survivors']} "
          f"survivors), {summary['rerouted']} re-routed, "
          f"p99 latency {summary['latency_p99_s'] * 1e3:.0f} ms")


def act3_crash_replay_regrow(cfg):
    print("=== Act 3: fleet crash -> ledger replay -> elastic regrow ===")
    ledger_path = _artifact("serve-ledger.wal")
    if os.path.exists(ledger_path):
        os.remove(ledger_path)      # a stale log must not replay into this run
    group = ServeGroup(cfg, 3, max_ranks=3,
                       config=EngineConfig(num_slots=2, max_len=48,
                                           trace=True))
    mk = lambda: [Request(id=i, prompt=(5 + i, 6 + i, 7 + i),
                          max_new_tokens=6) for i in range(12)]
    clean = group.serve(mk())

    # incarnation 1: rank 2 dies at round 2, the WHOLE fleet stops at round 4
    # — every rank is gone, only the fsync'd write-ahead ledger survives
    r1 = group.serve(mk(), faults=FaultSchedule(
        [FaultSpec(step=2, kind="kill", rank=2)]),
        ledger_path=ledger_path, crash_at=4)
    assert r1.crashed
    print(f"  incarnation 1: killed rank 2, then the whole fleet stopped — "
          f"{len(r1.responses)}/12 answered, "
          f"{os.path.getsize(ledger_path)} bytes of ledger survive")

    # incarnation 2: restart from the log alone, replay the outstanding set,
    # and re-admit the killed rank through the non-blocking join
    r2 = group.serve_from_ledger(ledger_path, joins=[1])
    merged = {**r1.responses, **r2.responses}
    assert sorted(merged) == list(range(12)), "requests dropped in the crash"
    assert all(r.ok for r in merged.values())
    for rid, resp in merged.items():
        assert tuple(resp.tokens) == tuple(clean.responses[rid].tokens)
    print(f"  incarnation 2: {len(r2.replayed)} requests replayed from the "
          f"ledger, rank 2 rejoined via non-blocking join (epoch {r2.epoch})")
    print("  zero drops across the crash; every stream bit-exact vs the "
          "clean run")

    # one causal story across both incarnations: kill -> shrink -> fleet
    # stop -> ledger replay -> state transfer -> rejoin, in a single trace
    trace = merge_trace_dicts(r1.trace(), r2.trace())
    problems = validate(trace)
    assert not problems, problems
    crash_path = _artifact("serve-crash-trace.json")
    with open(crash_path, "w") as f:
        json.dump(trace, f)
    names = [e["name"] for e in trace["traceEvents"] if e.get("cat") == "group"]
    story = [n for n in ("replica_kill", "ulfm_shrink", "fleet_stop",
                         "ledger_replay", "state_transfer", "replica_join")
             if n in names]
    print(f"  merged trace: {len(trace['traceEvents'])} events, group story "
          f"{' -> '.join(story)} -> {crash_path}")
    for c in group_chains(trace):
        if c["rejoins"]:
            a = c["rejoins"][0].get("args") or {}
            print(f"  chain: replica {c['dead_rank']} killed -> "
                  f"{len(c['reroutes'])} re-routes -> rejoined at epoch "
                  f"{a.get('epoch')} ({a.get('reason')})")


def main():
    cfg = smoke_config("recurrentgemma-2b")
    print(f"serving a reduced {cfg.name} ({cfg.num_layers} layers)\n")
    act1_soft_fault(cfg)
    act2_hard_fault(cfg)
    print()
    act3_crash_replay_regrow(cfg)


if __name__ == "__main__":
    main()
