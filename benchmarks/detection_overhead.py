"""Beyond-paper: cost of the always-on in-band device channel.

Measures per-step wall time of the jitted train step with the full probe set
(loss + whole-grad stream + data + router) vs a probe-free variant, on the
reduced gemma3 config. The paper's black channel idles at one pre-posted recv;
our device channel idles at one fused reduction — this benchmark quantifies it.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.detect import ProbeConfig
from repro.launch.train import build_train_setup
from repro.launch.steps import make_train_step
from repro.optim import AdamWConfig


def _time_steps(step_fn, state, batch, iters=30) -> float:
    inject = jnp.uint32(0)
    new_state, m, w = step_fn(state, batch, inject)   # compile + warmup
    jax.block_until_ready(w)
    t0 = time.monotonic()
    for _ in range(iters):
        new_state, m, w = step_fn(new_state, batch, inject)
    jax.block_until_ready(w)
    return (time.monotonic() - t0) / iters * 1e6


def run(iters=30):
    cfg = smoke_config("gemma3-1b")
    model, step_fn, state, pipe, opt_cfg = build_train_setup(
        cfg, batch_size=4, seq_len=64, total_steps=100)
    batch = next(pipe)

    with_probes = jax.jit(make_train_step(cfg, AdamWConfig()))
    us_on = _time_steps(with_probes, state, batch, iters)

    # probe-free variant: same step, word forced to constant
    base = make_train_step(cfg, AdamWConfig())

    def no_probe(state, batch, inject):
        new_state, metrics, _ = base(state, batch, inject)
        return new_state, metrics, jnp.uint32(0)

    us_off = _time_steps(jax.jit(no_probe), state, batch, iters)
    return [
        ("detection_on_us_per_step", 0, us_on),
        ("detection_off_us_per_step", 0, us_off),
        ("detection_overhead_pct", 0,
         (us_on - us_off) / max(us_off, 1e-9) * 100.0),
    ]
