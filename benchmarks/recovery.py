"""Recovery-cost comparison: the paper's three use cases, measured.

1. LFLR (in-memory known-good restore)     — use case 1/2 scale
2. optimizer reset (hierarchical escalate) — use case 2
3. global rollback (disk checkpoint)       — use case 3

Plus buddy-store push/recover (the peer-redundancy LFLR substrate).
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import BuddyStore, Checkpointer
from repro.configs import smoke_config
from repro.core.resilient import snapshot
from repro.launch.steps import make_reset_opt_fn
from repro.launch.train import build_train_setup


def run():
    cfg = smoke_config("qwen3-1.7b")
    model, step_fn, state, pipe, _ = build_train_setup(
        cfg, batch_size=2, seq_len=32, total_steps=10)
    rows = []

    # LFLR: snapshot + restore (device copy)
    t0 = time.monotonic()
    good = snapshot(state)
    jax.block_until_ready(good)
    t_snap = (time.monotonic() - t0) * 1e6
    t0 = time.monotonic()
    restored = snapshot(good)
    jax.block_until_ready(restored)
    t_restore = (time.monotonic() - t0) * 1e6
    rows += [("lflr_snapshot_us", 0, t_snap), ("lflr_restore_us", 0, t_restore)]

    # optimizer reset
    reset = make_reset_opt_fn(cfg)
    t0 = time.monotonic()
    st = reset(state, jnp.float32(0.5))
    jax.block_until_ready(st)
    t_reset = (time.monotonic() - t0) * 1e6
    rows.append(("optimizer_reset_us", 0, t_reset))

    # global rollback: blocking save + restore from disk
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        t0 = time.monotonic()
        ck.save(1, state, blocking=True)
        t_save = (time.monotonic() - t0) * 1e6
        t0 = time.monotonic()
        got = ck.restore_latest(like=state)
        assert got is not None
        t_roll = (time.monotonic() - t0) * 1e6
    rows += [("rollback_save_us", 0, t_save), ("rollback_restore_us", 0, t_roll)]

    # buddy store
    buddies = BuddyStore(8)
    t0 = time.monotonic()
    buddies.push(3, 100, state["params"])
    t_push = (time.monotonic() - t0) * 1e6
    t0 = time.monotonic()
    got = buddies.recover(3)
    assert got is not None
    t_rec = (time.monotonic() - t0) * 1e6
    rows += [("buddy_push_us", 0, t_push), ("buddy_recover_us", 0, t_rec)]
    return rows
