"""Paper Table I analogue: transport barrier latency.

The paper compares MPI implementations (IntelMPI / OpenMPI / OpenMPI-ULFM) with
``osu_barrier``. Our runtime substitutes the thread transport for MPI, so the
comparable measurement is barrier latency vs rank count in plain (MPI-3.0-like)
and ULFM-enabled modes — the ULFM failure detector adds per-operation liveness
checks, which is the analogue of the paper's observation that the ULFM stack is
slower than the tuned production stacks.
"""
from __future__ import annotations

import time

from repro.core import run_ranks


def barrier_latency(nranks: int, iters: int = 200, *, ulfm: bool) -> float:
    """Mean per-barrier latency in µs (osu_barrier-style loop)."""
    out = {}

    def fn(ctx):
        # warmup
        for _ in range(10):
            ctx.barrier(ctx.world)
        t0 = time.monotonic()
        for _ in range(iters):
            ctx.barrier(ctx.world)
        dt = time.monotonic() - t0
        if ctx.rank == 0:
            out["us"] = dt / iters * 1e6
        return None

    run_ranks(nranks, fn, ulfm=ulfm)
    return out["us"]


def run(ranks=(2, 4, 8, 16), iters=200):
    rows = []
    for n in ranks:
        plain = barrier_latency(n, iters, ulfm=False)
        ulfm = barrier_latency(n, iters, ulfm=True)
        rows.append(("table1_barrier_plain", n, plain))
        rows.append(("table1_barrier_ulfm", n, ulfm))
    return rows
