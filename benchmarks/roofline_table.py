"""Render the §Roofline table from dry-run artifacts (artifacts/dryrun/*.json)."""
from __future__ import annotations

import json
from pathlib import Path

ART = Path("artifacts/dryrun")


def load_records(mesh: str = "16x16") -> list[dict]:
    recs = []
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_row(rec: dict) -> str:
    if rec.get("skipped"):
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | — | skip | — | — | "
                f"{rec['skipped']} |")
    if not rec.get("ok"):
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | — | FAIL | — | — | "
                f"{rec.get('error','?')[:60]} |")
    r = rec["roofline"]
    mem_gib = rec["memory"]["peak_live_bytes"] / 2**30
    return ("| {arch} | {shape} | {c:.4f} | {m:.4f} | {k:.4f} | {dom} | "
            "{ratio:.2f} | {frac:.3f} | {mem:.1f} | |".format(
                arch=rec["arch"], shape=rec["shape"], c=r["compute_s"],
                m=r["memory_s"], k=r["collective_s"], dom=r["dominant"][:4],
                ratio=r["model_flops_ratio"], frac=r["roofline_fraction"],
                mem=mem_gib))


HEADER = ("| arch | shape | compute_s | memory_s | collective_s | dom | "
          "6ND/HLO | roofline_frac | mem GiB/dev | note |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def table(mesh: str = "16x16") -> str:
    rows = [HEADER]
    for rec in load_records(mesh):
        rows.append(fmt_row(rec))
    return "\n".join(rows)


def run():
    recs = load_records()
    ok = [r for r in recs if r.get("ok") and not r.get("skipped")]
    rows = []
    for r in ok:
        rows.append((f"roofline_bound_s:{r['arch']}:{r['shape']}", 0,
                     max(r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                         r["roofline"]["collective_s"])))
    return rows


if __name__ == "__main__":
    print(table())
