"""Paper Figure 2 analogue: error-propagation duration vs rank count.

The paper measures (on the root rank) the time to duplicate comm_world,
propagate an exception from rank 0 to all ranks, and clean up — black channel
vs ULFM. We reproduce the same experiment on the simulated runtime: all
non-root ranks are blocked in ``Future.wait`` on a receive that will never be
matched; rank 0 calls ``signal_error``; the measured span on the root covers
the full epoch (signal → everyone agreed → (rank, code) table delivered →
exception raised), plus the communicator setup, exactly like the paper's
"duplicate + propagate + clean up" protocol.
"""
from __future__ import annotations

import statistics
import time

import pytest

from repro.core import Comm, PropagatedError, initialize, run_ranks


def propagation_duration(nranks: int, *, ulfm: bool, reps: int = 5) -> dict:
    """Median/percentile durations (ms) measured on the root rank."""
    durations = []

    def fn(ctx):
        inst = initialize(ctx, default_timeout=60.0)
        for _ in range(reps):
            t0 = time.monotonic()
            comm = Comm(ctx, ctx.dup(ctx.world), default_timeout=60.0)
            if comm.rank == 0:
                try:
                    comm.signal_error(42)
                except PropagatedError:
                    pass
                durations.append((time.monotonic() - t0) * 1e3)
            else:
                try:
                    comm.recv(src=0).wait()
                except PropagatedError:
                    pass
            comm.close()
        return None

    run_ranks(nranks, fn, ulfm=ulfm, join_timeout=120.0)
    return {
        "median_ms": statistics.median(durations),
        "min_ms": min(durations),
        "max_ms": max(durations),
    }


def run(ranks=(4, 8, 16, 32, 64), reps=5):
    rows = []
    for n in ranks:
        bc = propagation_duration(n, ulfm=False, reps=reps)
        ul = propagation_duration(n, ulfm=True, reps=reps)
        rows.append(("fig2_blackchannel", n, bc["median_ms"] * 1e3))
        rows.append(("fig2_ulfm", n, ul["median_ms"] * 1e3))
    return rows
