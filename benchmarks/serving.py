"""Serving benchmark: steady-state throughput + request latency percentiles,
with and without injected soft faults, for both decode engines:

  * ``stepwise``  — PR-1 per-token decode (one dispatch + host sync per token);
  * ``window8``   — zero-sync decode windows (``Replica(window=8)``): K greedy
    steps fused on device, deferred fault detection, double-buffered commit.

Rows (name, derived, us):
  * serve_{engine}_steady_*  — fault-free continuous batching;
  * serve_{engine}_faulted_* — one injected recurrent-state SDC per
    ``FAULT_EVERY`` completed requests, so the number shows what LFLR
    recompute costs the steady state;
  * serve_window_speedup     — windowed vs stepwise steady tokens/s.

``python -m benchmarks.run --json`` additionally writes ``BENCH_serving.json``
(machine-readable trajectory tracking); ``python -m benchmarks.serving
--smoke`` is the CI decode-hotpath gate (asserts windowed ≥ stepwise).
"""
from __future__ import annotations

import time

from repro.configs import smoke_config
from repro.serve import Replica, Request

N_REQUESTS = 8
MAX_NEW = 48        # long generations: steady-state decode dominates
NUM_SLOTS = 4
MAX_LEN = 64
WINDOW = 8
FAULT_EVERY = 3     # 1 injected fault per FAULT_EVERY completed requests
N_TRIALS = 3        # best-of-N per cell: shields the tracked trajectory
                    # (BENCH_serving.json) from OS scheduling noise


def _serve_once(window: int = 0, fault_every: int = 0,
                n_requests: int = N_REQUESTS, max_new: int = MAX_NEW,
                num_slots: int = NUM_SLOTS, max_len: int = MAX_LEN):
    cfg = smoke_config("recurrentgemma-2b")
    rep = Replica(cfg, num_slots=num_slots, max_len=max_len, window=window)
    # every compile (decode path + LFLR prefill buckets) outside the timed
    # region, and fresh metrics so warm-up never pollutes the percentiles
    rep.warmup(max_new=max_new)
    for i in range(n_requests):
        rej = rep.submit(Request(id=i, prompt=(3 + i, 5 + i, 7 + i),
                                 max_new_tokens=max_new))
        assert rej is None, rej
    t0 = time.monotonic()
    done = 0
    injected = 0
    while not rep.idle():
        out = rep.step()
        done += len(out)
        if fault_every and done // fault_every > injected:
            if rep.inject_state_fault() is not None:
                injected += 1
    wall = time.monotonic() - t0
    summary = rep.metrics.summary()
    assert summary["statuses"].get("ok") == n_requests, summary["statuses"]
    summary["timed_tokens"] = summary["decode_tokens"]
    summary["wall_s"] = wall
    summary["tokens_per_s_timed"] = (summary["timed_tokens"] / wall
                                     if wall > 0 else 0.0)
    summary["faults_injected"] = injected
    return summary


def bench_all():
    """Run all four cells; returns (csv_rows, json_record)."""
    rows = []
    record = {
        "benchmark": "serving",
        "config": {"arch": "recurrentgemma-2b(smoke)",
                   "n_requests": N_REQUESTS, "max_new": MAX_NEW,
                   "num_slots": NUM_SLOTS, "max_len": MAX_LEN,
                   "window": WINDOW, "fault_every": FAULT_EVERY},
        "engines": {},
    }
    for engine, window in (("stepwise", 0), (f"window{WINDOW}", WINDOW)):
        record["engines"][engine] = {}
        for label, fault_every in (("steady", 0), ("faulted", FAULT_EVERY)):
            s = max((_serve_once(window=window, fault_every=fault_every)
                     for _ in range(N_TRIALS)),
                    key=lambda r: r["tokens_per_s_timed"])
            tps = s["tokens_per_s_timed"]
            us_per_tok = (s["wall_s"] * 1e6 / max(s["timed_tokens"], 1))
            note = (f"{s['faults_injected']}_faults_recovered" if fault_every
                    else f"{N_REQUESTS}req_x_{MAX_NEW}tok")
            rows.append((f"serve_{engine}_{label}_tokens_per_s",
                         f"{tps:.0f}tok/s {note}", us_per_tok))
            for p in ("p50", "p99"):
                lat = s[f"latency_{p}_s"]
                rows.append((f"serve_{engine}_{label}_latency_{p}",
                             f"{lat * 1e3:.1f}ms", lat * 1e6))
            record["engines"][engine][label] = {
                "tokens_per_s": tps,
                "latency_p50_s": s["latency_p50_s"],
                "latency_p99_s": s["latency_p99_s"],
                "wall_s": s["wall_s"],
                "timed_tokens": s["timed_tokens"],
                "faults_injected": s["faults_injected"],
                "windows": s["windows"],
                "discarded_tokens": s["discarded_tokens"],
                "retries": s["retries"],
            }
    eng = record["engines"]
    for label in ("steady", "faulted"):
        base = eng["stepwise"][label]["tokens_per_s"]
        win = eng[f"window{WINDOW}"][label]["tokens_per_s"]
        speedup = win / base if base > 0 else 0.0
        record[f"speedup_{label}"] = speedup
        if label == "steady":
            rows.append(("serve_window_speedup", f"{speedup:.2f}x_steady", 0.0))
    return rows, record


def run():
    rows, _ = bench_all()
    return rows


def smoke(window: int = WINDOW) -> None:
    """CI decode-hotpath gate: windowed must not be slower than stepwise.

    Tiny workload (compile time excluded by the warm request); asserts the
    window engine's steady tokens/s ≥ the per-token baseline so the gate
    fails if the zero-sync path regresses to per-token host round trips.
    """
    base = _serve_once(window=0, n_requests=4, max_new=32)
    win = _serve_once(window=window, n_requests=4, max_new=32)
    b, w = base["tokens_per_s_timed"], win["tokens_per_s_timed"]
    print(f"decode-hotpath smoke: stepwise {b:.0f} tok/s, "
          f"window{window} {w:.0f} tok/s ({w / max(b, 1e-9):.2f}x)")
    # small tolerance: the real win is ≥2x, but a single OS preemption on a
    # loaded CI box must not read as a regression
    assert w >= 0.9 * b, (
        f"windowed decode ({w:.0f} tok/s) slower than stepwise ({b:.0f} "
        "tok/s) — the zero-sync window path has regressed")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        smoke()
    else:
        for name, derived, us in run():
            print(f"{name},{us:.2f},{derived}")
