"""Serving benchmark: steady-state throughput, request latency and TTFT
percentiles, with and without injected soft faults, for three decode engines:

  * ``stepwise``         — PR-1 per-token decode (one dispatch + host sync per
    token);
  * ``window8_blocking`` — zero-sync decode windows (``Replica(window=8,
    overlap=False)``): K greedy steps fused on device, deferred fault
    detection, double-buffered commit — but admission/LFLR still a blocking
    full-prompt prefill between windows;
  * ``window8_overlap``  — stall-free serving (``overlap=True``): chunked
    prefill fused into the decode windows, admission and LFLR recovery as
    background lanes, zero host stalls.

Requests carry a non-trivial prompt (``PROMPT_LEN``) and outnumber the slots
3×, so admission churn is continuous — the traffic pattern where blocking
prefill stalls dominate. Rows (name, derived, us):
  * serve_{engine}_{steady|faulted}_tokens_per_s / _latency_p* / _ttft_p*;
  * serve_window_speedup   — windowed (blocking) vs stepwise, steady;
  * serve_overlap_speedup  — overlapped vs blocking windows, faulted (the
    stall-free acceptance number: ISSUE 3 targets ≥ 1.5×).

``python -m benchmarks.run --json`` appends the record to the run history in
``BENCH_serving.json`` (perf trajectory across PRs); ``python -m
benchmarks.serving --smoke`` is the CI decode-hotpath gate and ``--smoke
--overlap`` the CI overlap gate (overlapped ≥ blocking on faulted traffic).
"""
from __future__ import annotations

import time

from repro.configs import smoke_config
from repro.serve import Replica, Request

N_REQUESTS = 12
PROMPT_LEN = 16     # long prompts: admission/recovery prefill is real work
MAX_NEW = 32        # long generations: steady-state decode still dominates
NUM_SLOTS = 4
MAX_LEN = 64
WINDOW = 8
FAULT_EVERY = 2     # 1 injected fault per FAULT_EVERY completed requests
N_TRIALS = 3        # best-of-N per cell: shields the tracked trajectory
                    # (BENCH_serving.json) from OS scheduling noise

ENGINES = (
    ("stepwise", dict(window=0)),
    (f"window{WINDOW}_blocking", dict(window=WINDOW, overlap=False)),
    (f"window{WINDOW}_overlap", dict(window=WINDOW, overlap=True)),
)


def _serve_once(engine_kw: dict, fault_every: int = 0,
                n_requests: int = N_REQUESTS, max_new: int = MAX_NEW,
                num_slots: int = NUM_SLOTS, max_len: int = MAX_LEN,
                prompt_len: int = PROMPT_LEN):
    cfg = smoke_config("recurrentgemma-2b")
    # generous retry budget: the bench measures recovery *throughput*, and a
    # round-robin injection stream must not exhaust one request's retries
    rep = Replica(cfg, num_slots=num_slots, max_len=max_len,
                  max_request_retries=6, **engine_kw)
    # every compile (decode path + LFLR prefill buckets) outside the timed
    # region, and fresh metrics so warm-up never pollutes the percentiles
    rep.warmup(max_new=max_new)
    for i in range(n_requests):
        rej = rep.submit(Request(
            id=i, prompt=tuple(3 + i + j for j in range(prompt_len)),
            max_new_tokens=max_new))
        assert rej is None, rej
    t0 = time.monotonic()
    done = 0
    injected = 0
    while not rep.idle():
        out = rep.step()
        done += len(out)
        if fault_every and done // fault_every > injected:
            # rotate the poisoned slot so injections spread across requests —
            # but only slots whose state a window will actually consume: a
            # lane that has not started its first chunk gets a fresh-cache
            # reset at dispatch, which would silently wipe the injection and
            # bias the overlap-vs-blocking faulted comparison
            eligible = [i for i in rep.sched.active_slots()
                        if not (rep.sched.slots[i].pending is not None
                                and rep.sched.slots[i].prefill_pos == 0)]
            if eligible and rep.inject_state_fault(
                    eligible[injected % len(eligible)]) is not None:
                injected += 1
    wall = time.monotonic() - t0
    summary = rep.metrics.summary()
    assert summary["statuses"].get("ok") == n_requests, summary["statuses"]
    summary["timed_tokens"] = summary["decode_tokens"]
    summary["wall_s"] = wall
    summary["tokens_per_s_timed"] = (summary["timed_tokens"] / wall
                                     if wall > 0 else 0.0)
    summary["faults_injected"] = injected
    return summary


def bench_all():
    """Run all engine × traffic cells; returns (csv_rows, json_record)."""
    rows = []
    record = {
        "benchmark": "serving",
        "config": {"arch": "recurrentgemma-2b(smoke)",
                   "n_requests": N_REQUESTS, "prompt_len": PROMPT_LEN,
                   "max_new": MAX_NEW, "num_slots": NUM_SLOTS,
                   "max_len": MAX_LEN, "window": WINDOW,
                   "fault_every": FAULT_EVERY},
        "engines": {},
    }
    for engine, engine_kw in ENGINES:
        record["engines"][engine] = {}
        for label, fault_every in (("steady", 0), ("faulted", FAULT_EVERY)):
            s = max((_serve_once(engine_kw, fault_every=fault_every)
                     for _ in range(N_TRIALS)),
                    key=lambda r: r["tokens_per_s_timed"])
            tps = s["tokens_per_s_timed"]
            us_per_tok = (s["wall_s"] * 1e6 / max(s["timed_tokens"], 1))
            note = (f"{s['faults_injected']}_faults_recovered" if fault_every
                    else f"{N_REQUESTS}req_x_{MAX_NEW}tok")
            rows.append((f"serve_{engine}_{label}_tokens_per_s",
                         f"{tps:.0f}tok/s {note}", us_per_tok))
            for metric in ("latency", "ttft"):
                for p in ("p50", "p99"):
                    v = s[f"{metric}_{p}_s"]
                    rows.append((f"serve_{engine}_{label}_{metric}_{p}",
                                 f"{v * 1e3:.1f}ms", v * 1e6))
            record["engines"][engine][label] = {
                "tokens_per_s": tps,
                "latency_p50_s": s["latency_p50_s"],
                "latency_p99_s": s["latency_p99_s"],
                "ttft_p50_s": s["ttft_p50_s"],
                "ttft_p99_s": s["ttft_p99_s"],
                "wall_s": s["wall_s"],
                "timed_tokens": s["timed_tokens"],
                "faults_injected": s["faults_injected"],
                "windows": s["windows"],
                "discarded_tokens": s["discarded_tokens"],
                "prefills": s["prefills"],
                "prefill_chunks": s["prefill_chunks"],
                "prefill_chunk_tokens": s["prefill_chunk_tokens"],
                "host_stalls": s["host_stalls"],
                "host_stall_s": s["host_stall_s"],
                "retries": s["retries"],
            }
    eng = record["engines"]
    blocking, overlap = f"window{WINDOW}_blocking", f"window{WINDOW}_overlap"
    for label in ("steady", "faulted"):
        base = eng["stepwise"][label]["tokens_per_s"]
        blk = eng[blocking][label]["tokens_per_s"]
        ovl = eng[overlap][label]["tokens_per_s"]
        record[f"speedup_{label}"] = blk / base if base > 0 else 0.0
        record[f"overlap_speedup_{label}"] = ovl / blk if blk > 0 else 0.0
        record[f"overlap_ttft_p99_ratio_{label}"] = (
            eng[overlap][label]["ttft_p99_s"] /
            eng[blocking][label]["ttft_p99_s"]
            if eng[blocking][label]["ttft_p99_s"] > 0 else 0.0)
    rows.append(("serve_window_speedup",
                 f"{record['speedup_steady']:.2f}x_steady", 0.0))
    rows.append(("serve_overlap_speedup",
                 f"{record['overlap_speedup_faulted']:.2f}x_faulted", 0.0))
    return rows, record


def run():
    rows, _ = bench_all()
    return rows


def smoke(window: int = WINDOW) -> None:
    """CI decode-hotpath gate: windowed must not be slower than stepwise.

    Tiny workload (compile time excluded by the warm request); asserts the
    window engine's steady tokens/s ≥ the per-token baseline so the gate
    fails if the zero-sync path regresses to per-token host round trips.
    """
    base = _serve_once(dict(window=0), n_requests=4, max_new=32, prompt_len=3)
    win = _serve_once(dict(window=window, overlap=False), n_requests=4,
                      max_new=32, prompt_len=3)
    b, w = base["tokens_per_s_timed"], win["tokens_per_s_timed"]
    print(f"decode-hotpath smoke: stepwise {b:.0f} tok/s, "
          f"window{window} {w:.0f} tok/s ({w / max(b, 1e-9):.2f}x)")
    # small tolerance: the real win is ≥2x, but a single OS preemption on a
    # loaded CI box must not read as a regression
    assert w >= 0.9 * b, (
        f"windowed decode ({w:.0f} tok/s) slower than stepwise ({b:.0f} "
        "tok/s) — the zero-sync window path has regressed")


def smoke_overlap(window: int = WINDOW) -> None:
    """CI overlap gate: on faulted admission-heavy traffic the overlapped
    engine must not be slower than the blocking-window engine — fails if the
    stall-free path regresses to blocking prefills between windows."""
    kw = dict(n_requests=8, max_new=24, prompt_len=PROMPT_LEN,
              fault_every=FAULT_EVERY)
    blk = _serve_once(dict(window=window, overlap=False), **kw)
    ovl = _serve_once(dict(window=window, overlap=True), **kw)
    b, o = blk["tokens_per_s_timed"], ovl["tokens_per_s_timed"]
    print(f"overlap smoke (faulted): blocking {b:.0f} tok/s "
          f"({blk['host_stalls']} stalls, {blk['host_stall_s'] * 1e3:.0f}ms "
          f"stalled), overlapped {o:.0f} tok/s ({ovl['host_stalls']} stalls) "
          f"— {o / max(b, 1e-9):.2f}x")
    assert ovl["host_stalls"] == 0, "overlapped engine blocked on a prefill"
    # same noise tolerance as the decode-hotpath gate
    assert o >= 0.9 * b, (
        f"overlapped serving ({o:.0f} tok/s) slower than blocking windows "
        f"({b:.0f} tok/s) — chunked-prefill fusion has regressed")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        if "--overlap" in sys.argv:
            smoke_overlap()
        else:
            smoke()
    else:
        for name, derived, us in run():
            print(f"{name},{us:.2f},{derived}")
