"""Serving benchmark: steady-state throughput + request latency percentiles,
with and without injected soft faults.

Rows (name, derived, us):
  * serve_steady_*  — fault-free continuous batching;
  * serve_faulted_* — one injected recurrent-state SDC per ``FAULT_EVERY``
    completed requests (scaled-down stand-in for a per-100-requests rate at
    production traffic), so the number shows what LFLR recompute costs the
    steady state.
"""
from __future__ import annotations

import time

from repro.configs import smoke_config
from repro.serve import Replica, Request

N_REQUESTS = 20
MAX_NEW = 8
NUM_SLOTS = 4
FAULT_EVERY = 5     # 1 injected fault per FAULT_EVERY completed requests


def _serve_once(fault_every: int = 0):
    cfg = smoke_config("recurrentgemma-2b")
    rep = Replica(cfg, num_slots=NUM_SLOTS, max_len=48)
    for i in range(N_REQUESTS):
        rej = rep.submit(Request(id=i, prompt=(3 + i, 5 + i, 7 + i),
                                 max_new_tokens=MAX_NEW))
        assert rej is None, rej
    # warm the compiles outside the timed region: first step prefills + decodes
    rep.step()
    warm_tokens = rep.metrics.decode_tokens
    t0 = time.monotonic()
    done = 0
    injected = 0
    while not rep.idle():
        out = rep.step()
        done += len(out)
        if fault_every and done // fault_every > injected:
            if rep.inject_state_fault() is not None:
                injected += 1
    wall = time.monotonic() - t0
    summary = rep.metrics.summary()
    assert summary["statuses"].get("ok") == N_REQUESTS, summary["statuses"]
    summary["timed_tokens"] = summary["decode_tokens"] - warm_tokens
    return summary, wall, injected


def run():
    rows = []
    for label, fault_every in (("steady", 0), ("faulted", FAULT_EVERY)):
        s, wall, injected = _serve_once(fault_every)
        tps = s["timed_tokens"] / wall if wall > 0 else 0.0
        us_per_tok = wall * 1e6 / max(s["timed_tokens"], 1)
        note = (f"{injected}_faults_recovered" if fault_every
                else f"{N_REQUESTS}req_x_{MAX_NEW}tok")
        rows.append((f"serve_{label}_tokens_per_s", f"{tps:.0f}tok/s {note}",
                     us_per_tok))
        for p in ("p50", "p99"):
            lat = s[f"latency_{p}_s"]
            rows.append((f"serve_{label}_latency_{p}",
                         f"{lat * 1e3:.1f}ms", lat * 1e6))
    return rows
