"""Serving benchmark: steady-state throughput, request latency and TTFT
percentiles, with and without injected soft faults, for three decode engines:

  * ``stepwise``         — PR-1 per-token decode (one dispatch + host sync per
    token);
  * ``window8_blocking`` — zero-sync decode windows (``Replica(window=8,
    overlap=False)``): K greedy steps fused on device, deferred fault
    detection, double-buffered commit — but admission/LFLR still a blocking
    full-prompt prefill between windows;
  * ``window8_overlap``  — stall-free serving (``overlap=True``): chunked
    prefill fused into the decode windows, admission and LFLR recovery as
    background lanes, zero host stalls.

Requests carry a non-trivial prompt (``PROMPT_LEN``) and outnumber the slots
3×, so admission churn is continuous — the traffic pattern where blocking
prefill stalls dominate. Rows (name, derived, us):
  * serve_{engine}_{steady|faulted}_tokens_per_s / _latency_p* / _ttft_p*;
  * serve_window_speedup   — windowed (blocking) vs stepwise, steady;
  * serve_overlap_speedup  — overlapped vs blocking windows, faulted (the
    stall-free acceptance number: ISSUE 3 targets ≥ 1.5×);
  * serve_paged_*          — paged-KV capacity cell (ISSUE 4): on a
    mixed-length workload (prompt lens 16–1024, full-attention arch) the
    paged pool serves ≥ 2× the concurrent slots of the contiguous layout at
    an equal HBM budget, token-bit-exact, zero dropped requests;
  * serve_window8_spec_* / serve_spec_speedup — speculative decode windows
    (ISSUE 5): draft-and-verify inside the fused window on the qwen3-1.7b
    smoke config, vs the overlap engine on the same config
    (``window8_overlap_qwen3`` cells) — targets ≥ 1.4× steady tok/s at equal
    (bit-exact) output tokens;
  * serve_tracer_overhead — fault-causality tracing cell (DESIGN §3.5): an
    enabled ``repro.obs.Tracer`` on the overlap engine must cost ≤ 2% steady
    tok/s vs the no-op default (asserted; ``record["tracer"]``);
  * serve_elastic_* — elastic serve-group cells (ISSUE 8, DESIGN §3.7):
    survivor tok/s *during* a non-blocking replica join must stay ≥ 0.9× the
    survivors' steady rate (asserted — the join is a background lane, not a
    stall), plus the fleet tok/s with the fsync'd write-ahead ledger on
    (``record["elastic"]``, all guarded by ``bench_gate.py``);
  * serve_window8_tp2_* — tensor-parallel replica cells (ISSUE 9, DESIGN
    §3.8): the ``tp=2`` engine (storage sharded over the "model" mesh axis,
    per-shard error words OR-folded at retirement) on the qwen3 smoke config,
    steady + faulted, skipped when fewer than 2 devices are visible (CI
    forces them with ``XLA_FLAGS=--xla_force_host_platform_device_count=2``).

Every ``Replica``/``ServeGroup`` here is built through one validated
:class:`repro.serve.EngineConfig` — the single construction path the bench
shares with the tests and the fuzzer.

``python -m benchmarks.run --json`` appends the record to the run history in
``BENCH_serving.json`` (perf trajectory across PRs); ``python -m
benchmarks.serving --smoke`` is the CI decode-hotpath gate, ``--smoke
--overlap`` the CI overlap gate (overlapped ≥ blocking on faulted traffic),
``--smoke --paged`` the CI paged gate (bit-exact + 2× slot capacity),
``--smoke --spec`` the CI speculative gate (bit-exact steady+faulted +
non-zero draft acceptance), ``--smoke --trace`` the CI trace gate (traced
faulted traffic is token-bit-exact vs untraced, the dumped trace round-trips
through ``scripts/trace_tool.py --check``), ``--smoke --elastic`` the CI
elastic gate (kill a rank, crash the whole fleet mid-flight, restart from
the write-ahead ledger alone, regrow via the non-blocking join — zero
drops, bit-exact streams, merged two-incarnation trace validates),
``--smoke --tp`` the CI tensor-parallel gate (tp=2 token-bit-exact vs the
single-device engine steady AND under a one-shard injection, shard loss
inside a group shrinks with zero drops, dumped trace validates) and
``--smoke --multihost`` the CI multi-host gate (3 real worker *processes*
under the heartbeat supervisor; one is SIGKILL'd mid-decode — detected,
evicted within 2× the suspect timeout, outstanding requests re-routed from
the WAL with zero drops and bit-exact streams vs an in-process reference;
one is SIGSTOP'd for less than the suspect timeout — suspected but never
evicted; the merged trace passes ``trace_tool.py --check``).

All file artifacts the smokes write (traces, WALs) land under the
gitignored ``artifacts/`` directory (override with ``REPRO_ARTIFACTS``).
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax

from repro.configs import smoke_config
from repro.serve import EngineConfig, Replica, Request

#: Every smoke/bench file artifact (traces, WALs) lands under this gitignored
#: directory — CI uploads it wholesale, the repo root stays clean.
ARTIFACTS_DIR = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def _artifact(name: str) -> str:
    os.makedirs(ARTIFACTS_DIR, exist_ok=True)
    return os.path.join(ARTIFACTS_DIR, name)


N_REQUESTS = 12
PROMPT_LEN = 16     # long prompts: admission/recovery prefill is real work
MAX_NEW = 32        # long generations: steady-state decode still dominates
NUM_SLOTS = 4
MAX_LEN = 64
WINDOW = 8
FAULT_EVERY = 2     # 1 injected fault per FAULT_EVERY completed requests
N_TRIALS = 5        # best-of-N per cell: shields the tracked trajectory
                    # (BENCH_serving.json) from OS scheduling noise. Trials
                    # are interleaved round-robin across cells (not run
                    # consecutively per cell) so a multi-minute slow window
                    # on a shared box cannot swallow any one cell's whole
                    # best-of and hand the bench-regression gate a bad draw
N_TRIALS_FAULTED = 7  # faulted cells swing ~2× on top of that (fault
                      # timing decides how much recovery work a run pays)
                      # — they get a deeper best-of. Best-of-N measures
                      # near-peak capability (the luckiest fault draw), so
                      # the faulted-vs-steady gap it reports is a lower
                      # bound on typical recovery cost; that bias is the
                      # price of a statistic stable enough to gate on, and
                      # the trial counts ride in record["config"] so runs
                      # stay comparable-by-construction

ENGINES = (
    ("stepwise", dict(window=0)),
    (f"window{WINDOW}_blocking", dict(window=WINDOW, overlap=False)),
    (f"window{WINDOW}_overlap", dict(window=WINDOW, overlap=True)),
)

# --- speculative decode cells (full-attention arch: verify needs positional,
# idempotent cache writes) — window8_spec vs the overlap engine on the SAME
# qwen3 smoke config, steady + faulted, interleaved best-of like every cell.
# ISSUE-5 acceptance: spec steady tok/s >= 1.4x overlap at equal output tokens.
#
# The smoke reduction keeps only 2 layers, which makes a "shallow-exit"
# drafter structurally impossible (1 of 2 layers is 60% of the model once the
# exit is counted); the spec cells therefore deepen the qwen3 smoke config to
# 8 layers so draft_layers=1 is a 1/8-depth drafter — the same depth fraction
# a 4-layer drafter has on the real 28-layer qwen3-1.7b. Both engines run the
# identical deepened config, and the workload leans on steady decode
# (max_new >> prompt_len) because that is the regime the cell measures.
SPEC_ARCH = "qwen3-1.7b"
SPEC_NUM_LAYERS = 8
SPEC_DRAFT_LEN = 5
SPEC_DRAFT_LAYERS = 1
SPEC_N_REQUESTS = 8
SPEC_MAX_NEW = 64
SPEC_MAX_LEN = 96
SPEC_RUN_KW = dict(arch=SPEC_ARCH, num_layers=SPEC_NUM_LAYERS,
                   n_requests=SPEC_N_REQUESTS, max_new=SPEC_MAX_NEW,
                   max_len=SPEC_MAX_LEN)
SPEC_ENGINES = (
    (f"window{WINDOW}_overlap_qwen3", dict(window=WINDOW, overlap=True)),
    (f"window{WINDOW}_spec", dict(window=WINDOW, overlap=True,
                                  speculate=True, draft_len=SPEC_DRAFT_LEN,
                                  draft_layers=SPEC_DRAFT_LAYERS)),
)

# --- elastic serve-group cells (ISSUE 8): survivor throughput while a spare
# joins as a background lane, and the fsync'd write-ahead-ledger cost ---
ELASTIC_RANKS = 2
ELASTIC_MAX_RANKS = 3
ELASTIC_N_REQUESTS = 96       # deep backlog: the serve must outlast the
                              # spare's warm-up + the stretched transfer so
                              # the whole join window falls in the busy
                              # phase, preceded by an equally busy baseline
                              # window
ELASTIC_PROMPT_LEN = 8
ELASTIC_MAX_NEW = 48
ELASTIC_JOIN_ROUND = 2
ELASTIC_TRANSFER_CHUNKS = 75  # stretch the join-time state transfer to
                              # ~150 ms so the join window spans many decode
                              # rounds — window retires land in bursts, and a
                              # measurement window narrower than a burst
                              # period reads pure scheduling noise
N_TRIALS_ELASTIC = 3          # group runs are whole-fleet thread harnesses —
                              # fewer, heavier trials than the replica cells

# --- paged-KV capacity cell (full-attention arch: every KV byte is pageable) --
PAGED_ARCH = "qwen3-1.7b"
PAGED_PAGE = 64
PAGED_MAX_LEN = 1088          # 17 pages: covers a 1024-token prompt + decode
PAGED_CONTIG_SLOTS = 2        # contiguous baseline → the HBM budget
PAGED_SLOTS = 4               # paged engine: 2× the slots, same pool bytes
PAGED_MIXED_PROMPTS = (16, 1024, 32, 48, 64, 128, 16, 256, 32, 512, 24, 96)
PAGED_MAX_NEW = 16

# --- tensor-parallel cells (ISSUE 9): the tp=2 engine on the qwen3 smoke
# config (the arch the TP test suite shards), steady + faulted. Skipped —
# loudly, in the record — when fewer than TP devices are visible; CI forces
# host devices so the cells always ride the tracked history there.
TP = 2
TP_ARCH = "qwen3-1.7b"
TP_ENGINE = (f"window{WINDOW}_tp{TP}",
             dict(window=WINDOW, overlap=True, tp=TP))
TP_RUN_KW = dict(arch=TP_ARCH)


def _serve_once(engine_kw: dict, fault_every: int = 0,
                n_requests: int = N_REQUESTS, max_new: int = MAX_NEW,
                num_slots: int = NUM_SLOTS, max_len: int = MAX_LEN,
                prompt_len: int = PROMPT_LEN,
                arch: str = "recurrentgemma-2b", num_layers: int = 0,
                tracer=None):
    cfg = smoke_config(arch)
    if num_layers:
        cfg = cfg.replace(num_layers=num_layers)
    # generous retry budget: the bench measures recovery *throughput*, and a
    # round-robin injection stream must not exhaust one request's retries
    rep = Replica(cfg, config=EngineConfig(num_slots=num_slots,
                                           max_len=max_len,
                                           max_request_retries=6,
                                           **engine_kw),
                  tracer=tracer)
    # every compile (decode path + LFLR prefill buckets) outside the timed
    # region, and fresh metrics so warm-up never pollutes the percentiles
    rep.warmup(max_new=max_new)
    for i in range(n_requests):
        rej = rep.submit(Request(
            id=i, prompt=tuple(3 + i + j for j in range(prompt_len)),
            max_new_tokens=max_new))
        assert rej is None, rej
    t0 = time.monotonic()
    done = 0
    injected = 0
    while not rep.idle():
        out = rep.step()
        done += len(out)
        if fault_every and done // fault_every > injected:
            # rotate the poisoned slot so injections spread across requests —
            # but only slots whose state a window will actually consume: a
            # lane that has not started its first chunk gets a fresh-cache
            # reset at dispatch, which would silently wipe the injection and
            # bias the overlap-vs-blocking faulted comparison
            eligible = [i for i in rep.sched.active_slots()
                        if not (rep.sched.slots[i].pending is not None
                                and rep.sched.slots[i].prefill_pos == 0)]
            if eligible and rep.inject_state_fault(
                    eligible[injected % len(eligible)]) is not None:
                injected += 1
    wall = time.monotonic() - t0
    summary = rep.metrics.summary()
    assert summary["statuses"].get("ok") == n_requests, summary["statuses"]
    summary["timed_tokens"] = summary["decode_tokens"]
    summary["wall_s"] = wall
    summary["tokens_per_s_timed"] = (summary["timed_tokens"] / wall
                                     if wall > 0 else 0.0)
    summary["faults_injected"] = injected
    return summary


def _serve_mixed(prompts, *, paged: bool, num_slots: int, max_len: int,
                 page_budget=None, max_new: int = PAGED_MAX_NEW):
    """Serve a mixed-length workload on the full-attention arch; returns the
    metrics summary. ``paged=False`` is the contiguous HBM-budget baseline;
    ``paged=True`` shares the same pool bytes across more slots. (Faulted
    paged traffic is gated by ``--smoke --paged`` and tests — this cell
    measures capacity.)"""
    cfg = smoke_config(PAGED_ARCH)
    rep = Replica(cfg, config=EngineConfig(
        num_slots=num_slots, max_len=max_len, window=WINDOW, overlap=True,
        max_request_retries=6, paged=paged, page_size=PAGED_PAGE,
        page_budget=page_budget))
    rep.warmup(max_new=max_new)
    for i, plen in enumerate(prompts):
        rej = rep.submit(Request(
            id=i, prompt=tuple(3 + (i + j) % 200 for j in range(plen)),
            max_new_tokens=max_new))
        assert rej is None, rej
    t0 = time.monotonic()
    n_ok = 0
    while not rep.idle():
        n_ok += sum(r.status == "ok" for r in rep.step())
    wall = time.monotonic() - t0
    s = rep.metrics.summary()
    assert n_ok == len(prompts), s["statuses"]
    s["wall_s"] = wall
    s["tokens_per_s_timed"] = s["decode_tokens"] / wall if wall > 0 else 0.0
    if paged:
        rep.alloc.check()
        s["hbm_cache_bytes"] = rep.layout.pool_bytes()
    else:
        # contiguous: every slot owns a full-capacity block
        from repro.launch.paging import PagedLayout
        from repro.models import build_model
        layout = PagedLayout(build_model(cfg).init_cache(1, max_len), max_len,
                             page_size=PAGED_PAGE, num_pages=1)
        s["hbm_cache_bytes"] = (num_slots
                               * layout.contiguous_paged_bytes_per_slot())
    return s


def bench_paged_capacity():
    """ISSUE-4 acceptance cell: mixed prompt lengths 16–1024 on a pure
    full-attention arch. The contiguous layout fits ``PAGED_CONTIG_SLOTS``
    slots in the HBM budget; the paged pool serves ``PAGED_SLOTS`` (2×)
    concurrent slots on the *same* bytes, zero dropped requests."""
    budget_pages = PAGED_CONTIG_SLOTS * (PAGED_MAX_LEN // PAGED_PAGE)
    contig = _serve_mixed(PAGED_MIXED_PROMPTS, paged=False,
                          num_slots=PAGED_CONTIG_SLOTS,
                          max_len=PAGED_MAX_LEN)
    paged = _serve_mixed(PAGED_MIXED_PROMPTS, paged=True,
                         num_slots=PAGED_SLOTS, max_len=PAGED_MAX_LEN,
                         page_budget=budget_pages)
    assert paged["hbm_cache_bytes"] <= contig["hbm_cache_bytes"], (
        "paged pool exceeds the contiguous HBM budget")
    ratio = paged["peak_active_slots"] / max(contig["peak_active_slots"], 1)
    assert ratio >= 2.0, (
        f"paged engine sustained only {paged['peak_active_slots']} concurrent "
        f"slots vs {contig['peak_active_slots']} contiguous — "
        "the capacity win has regressed")
    record = {
        "arch": f"{PAGED_ARCH}(smoke)",
        "page_size": PAGED_PAGE,
        "max_len": PAGED_MAX_LEN,
        "pool_pages": budget_pages,
        "hbm_budget_bytes": contig["hbm_cache_bytes"],
        "prompt_lens": list(PAGED_MIXED_PROMPTS),
        "slot_capacity_ratio": ratio,
        "contiguous": {
            "num_slots": PAGED_CONTIG_SLOTS,
            "tokens_per_s": contig["tokens_per_s_timed"],
            "peak_active_slots": contig["peak_active_slots"],
            "latency_p99_s": contig["latency_p99_s"],
        },
        "paged": {
            "num_slots": PAGED_SLOTS,
            "tokens_per_s": paged["tokens_per_s_timed"],
            "peak_active_slots": paged["peak_active_slots"],
            "latency_p99_s": paged["latency_p99_s"],
            "page_evictions": paged["page_evictions"],
            "peak_pages_in_use": paged["peak_pages_in_use"],
        },
    }
    rows = [
        ("serve_paged_capacity_ratio",
         f"{ratio:.1f}x_slots_at_equal_hbm", 0.0),
        ("serve_paged_mixed_tokens_per_s",
         f"{paged['tokens_per_s_timed']:.0f}tok/s_"
         f"{paged['peak_active_slots']}slots", 0.0),
        ("serve_contig_mixed_tokens_per_s",
         f"{contig['tokens_per_s_timed']:.0f}tok/s_"
         f"{contig['peak_active_slots']}slots", 0.0),
    ]
    return rows, record


def bench_tracer_overhead():
    """Tracer acceptance cell: an enabled :class:`repro.obs.Tracer` must cost
    ≤ 2% steady tok/s on the overlap engine vs the no-op default. Interleaved
    best-of-N like every other cell — per-trial noise on a shared box dwarfs
    the effect being measured, so the gate compares near-peak capability of
    the two configurations."""
    from repro.obs import Tracer

    engine_kw = dict(window=WINDOW, overlap=True)
    best: dict[str, float] = {}
    events = 0
    for _ in range(N_TRIALS):
        s = _serve_once(engine_kw)
        best["noop"] = max(best.get("noop", 0.0), s["tokens_per_s_timed"])
        tr = Tracer()
        s = _serve_once(engine_kw, tracer=tr)
        if s["tokens_per_s_timed"] > best.get("enabled", 0.0):
            best["enabled"] = s["tokens_per_s_timed"]
            events = tr.num_events
    overhead = (1.0 - best["enabled"] / best["noop"]
                if best["noop"] > 0 else 0.0)
    assert best["enabled"] >= 0.98 * best["noop"], (
        f"enabled tracer costs {overhead * 100:.1f}% tok/s "
        f"({best['enabled']:.0f} vs {best['noop']:.0f} no-op) — "
        "the hot-path span emission has regressed past the 2% budget")
    record = {
        "noop": {"tokens_per_s": best["noop"]},
        "enabled": {"tokens_per_s": best["enabled"], "events": events},
        "overhead_frac": overhead,
    }
    rows = [("serve_tracer_overhead",
             f"{overhead * 100:+.1f}%_tok/s_{events}events", 0.0)]
    return rows, record


def _elastic_requests():
    return [Request(id=i,
                    prompt=tuple(5 + i + j for j in range(ELASTIC_PROMPT_LEN)),
                    max_new_tokens=ELASTIC_MAX_NEW)
            for i in range(ELASTIC_N_REQUESTS)]


def _overlap_tokens(decode, lo: float, hi: float) -> float:
    """Committed tokens attributed to ``[lo, hi]`` (trace µs), each decode
    span's tokens spread uniformly over its duration — overlap-weighted
    attribution, so the bursty retire *points* don't alias the estimate."""
    tok = 0.0
    for e in decode:
        k = (e.get("args") or {}).get("committed", 0)
        if not k:
            continue
        d = e.get("dur", 0.0)
        if d <= 0:
            tok += k if lo <= e["ts"] <= hi else 0
            continue
        ov = min(e["ts"] + d, hi) - max(e["ts"], lo)
        if ov > 0:
            tok += k * ov / d
    return tok


def _survivor_rates(trace: dict, *, joined: int, survivors) -> tuple:
    """(tok/s during the join window, tok/s over the equal-length window just
    *before* it) for the pre-join members. The ``replica_join`` span is the
    summons-to-first-exchange window; comparing against the adjacent earlier
    window keeps both measurements in the same traffic phase (deep backlog)
    with the same member count, so the ratio isolates what the join itself
    cost the survivors — the admission ramp, the drain tail, and the
    post-join CPU contention from the third replica never enter either
    side."""
    survivors = set(survivors)
    evs = trace["traceEvents"]
    joins = [e for e in evs
             if e.get("name") == "replica_join" and e.get("pid") == joined]
    assert joins, "the summoned replica never joined"
    j = joins[0]
    t0, t1 = j["ts"], j["ts"] + j.get("dur", 0.0)
    assert t1 > t0, "empty join window"
    decode = [e for e in evs
              if e.get("name") == "decode" and e.get("pid") in survivors]
    assert decode, "survivors committed no decode windows"
    span_s = (t1 - t0) / 1e6
    during = _overlap_tokens(decode, t0, t1) / span_s
    steady = _overlap_tokens(decode, t0 - (t1 - t0), t0) / span_s
    return during, steady


def bench_elastic():
    """ISSUE-8 acceptance cells. (1) *Non-blocking join*: a 2-rank group
    serves a continuous backlog while a spare is summoned at round
    ``ELASTIC_JOIN_ROUND``; the survivors' tok/s during the join window
    (warm-up + chunked state transfer + epoch agreement) must stay ≥ 0.9×
    their steady rate — the join is a background lane, never a stall.
    (2) *Durable ledger*: the same workload with every submit/route/retire
    fsync'd to the write-ahead log — the durability cost rides the tracked
    history so a WAL hot-path regression trips the bench gate.

    The ratio is taken best-of-N and quantizes on window-retire bursts, so
    readings above 1 are normal; only a collapse toward 0 across every trial
    (a join that blocks the survivors) can fail the assertion. The gated
    history cells are the steady/durable tok/s — the ratio's burst noise
    stays out of the regression tripwire."""
    import tempfile

    from repro.serve import ServeGroup

    group = ServeGroup(smoke_config("recurrentgemma-2b"), ELASTIC_RANKS,
                       config=EngineConfig(num_slots=NUM_SLOTS,
                                           max_len=MAX_LEN, window=WINDOW,
                                           overlap=True,
                                           max_request_retries=6, trace=True),
                       max_ranks=ELASTIC_MAX_RANKS,
                       transfer_chunks=ELASTIC_TRANSFER_CHUNKS)
    best = {"ratio": 0.0, "during": 0.0, "steady": 0.0, "durable": 0.0}
    wal_stats: dict = {}
    for _ in range(N_TRIALS_ELASTIC):
        res = group.serve(_elastic_requests(), joins=[ELASTIC_JOIN_ROUND])
        assert len(res.responses) == ELASTIC_N_REQUESTS
        assert all(r.ok for r in res.responses.values())
        assert ELASTIC_RANKS in res.joined, "scheduled join never landed"
        during, steady = _survivor_rates(
            res.trace(), joined=ELASTIC_RANKS, survivors=range(ELASTIC_RANKS))
        ratio = during / steady if steady > 0 else 0.0
        if ratio > best["ratio"]:
            best.update(ratio=ratio, during=during, steady=steady)
        tmp = tempfile.mkdtemp(prefix="bench-elastic-")
        path = os.path.join(tmp, "ledger.wal")
        try:
            t0 = time.monotonic()
            dur = group.serve(_elastic_requests(), ledger_path=path)
            wall = time.monotonic() - t0
            assert len(dur.responses) == ELASTIC_N_REQUESTS
            assert all(r.ok for r in dur.responses.values())
            tps = dur.summary()["decode_tokens"] / wall if wall > 0 else 0.0
            if tps > best["durable"]:
                best["durable"] = tps
                wal_stats = {"records": sum(1 for _ in open(path)),
                             "bytes": os.path.getsize(path)}
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    assert best["ratio"] >= 0.9, (
        f"survivor throughput dropped to {best['ratio']:.2f}x steady during "
        f"the replica join ({best['during']:.0f} vs {best['steady']:.0f} "
        "tok/s) — the non-blocking join has regressed into a stall")
    record = {
        "config": {"ranks": ELASTIC_RANKS, "max_ranks": ELASTIC_MAX_RANKS,
                   "n_requests": ELASTIC_N_REQUESTS,
                   "prompt_len": ELASTIC_PROMPT_LEN,
                   "max_new": ELASTIC_MAX_NEW,
                   "join_round": ELASTIC_JOIN_ROUND,
                   "transfer_chunks": ELASTIC_TRANSFER_CHUNKS,
                   "n_trials": N_TRIALS_ELASTIC},
        "steady": {"tokens_per_s": best["steady"]},
        "during_join": {"tokens_per_s": best["during"]},
        "durable": {"tokens_per_s": best["durable"], **wal_stats},
        "join_ratio": best["ratio"],
    }
    rows = [
        ("serve_elastic_join_ratio",
         f"{best['ratio']:.2f}x_survivor_tok/s_during_join", 0.0),
        ("serve_elastic_steady_tokens_per_s",
         f"{best['steady']:.0f}tok/s_{ELASTIC_RANKS}ranks", 0.0),
        ("serve_elastic_join_tokens_per_s",
         f"{best['during']:.0f}tok/s_during_join", 0.0),
        ("serve_elastic_durable_tokens_per_s",
         f"{best['durable']:.0f}tok/s_"
         f"{wal_stats.get('records', 0)}wal_records", 0.0),
    ]
    return rows, record


def bench_all():
    """Run all engine × traffic cells; returns (csv_rows, json_record)."""
    rows = []
    record = {
        "benchmark": "serving",
        "config": {"arch": "recurrentgemma-2b(smoke)",
                   "n_requests": N_REQUESTS, "prompt_len": PROMPT_LEN,
                   "max_new": MAX_NEW, "num_slots": NUM_SLOTS,
                   "max_len": MAX_LEN, "window": WINDOW,
                   "fault_every": FAULT_EVERY,
                   "n_trials": N_TRIALS,
                   "n_trials_faulted": N_TRIALS_FAULTED,
                   "spec_arch": f"{SPEC_ARCH}(smoke,{SPEC_NUM_LAYERS}L)",
                   "spec_draft_len": SPEC_DRAFT_LEN,
                   "spec_draft_layers": SPEC_DRAFT_LAYERS,
                   "spec_n_requests": SPEC_N_REQUESTS,
                   "spec_max_new": SPEC_MAX_NEW,
                   "spec_max_len": SPEC_MAX_LEN,
                   "tp": TP, "tp_arch": f"{TP_ARCH}(smoke)"},
        "engines": {},
    }
    cells = [(engine, engine_kw, label, fault_every, {})
             for engine, engine_kw in ENGINES
             for label, fault_every in (("steady", 0),
                                        ("faulted", FAULT_EVERY))]
    cells += [(engine, engine_kw, label, fault_every, SPEC_RUN_KW)
              for engine, engine_kw in SPEC_ENGINES
              for label, fault_every in (("steady", 0),
                                         ("faulted", FAULT_EVERY))]
    tp_ok = len(jax.devices()) >= TP
    record["tp_skipped"] = not tp_ok
    if tp_ok:
        cells += [(TP_ENGINE[0], TP_ENGINE[1], label, fault_every, TP_RUN_KW)
                  for label, fault_every in (("steady", 0),
                                             ("faulted", FAULT_EVERY))]
    else:
        print(f"# tp cells skipped: {len(jax.devices())} device(s) < tp={TP} "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{TP})")
    best: dict[str, dict] = {}
    for trial in range(max(N_TRIALS, N_TRIALS_FAULTED)):
        for engine, engine_kw, label, fault_every, run_kw in cells:
            if trial >= (N_TRIALS_FAULTED if fault_every else N_TRIALS):
                continue
            s = _serve_once(engine_kw, fault_every=fault_every, **run_kw)
            key = f"{engine}/{label}"
            if (key not in best or s["tokens_per_s_timed"]
                    > best[key]["tokens_per_s_timed"]):
                best[key] = s
    for engine, engine_kw, label, fault_every, run_kw in cells:
        record["engines"].setdefault(engine, {})
        s = best[f"{engine}/{label}"]
        tps = s["tokens_per_s_timed"]
        us_per_tok = (s["wall_s"] * 1e6 / max(s["timed_tokens"], 1))
        note = (f"{s['faults_injected']}_faults_recovered" if fault_every
                else f"{N_REQUESTS}req_x_{MAX_NEW}tok")
        rows.append((f"serve_{engine}_{label}_tokens_per_s",
                     f"{tps:.0f}tok/s {note}", us_per_tok))
        for metric in ("latency", "ttft"):
            for p in ("p50", "p99"):
                v = s[f"{metric}_{p}_s"]
                rows.append((f"serve_{engine}_{label}_{metric}_{p}",
                             f"{v * 1e3:.1f}ms", v * 1e6))
        arch = run_kw.get("arch", "recurrentgemma-2b")
        nl = run_kw.get("num_layers")
        record["engines"][engine][label] = {
            "arch": f"{arch}(smoke{f',{nl}L' if nl else ''})",
            "tokens_per_s": tps,
            "latency_p50_s": s["latency_p50_s"],
            "latency_p99_s": s["latency_p99_s"],
            "ttft_p50_s": s["ttft_p50_s"],
            "ttft_p99_s": s["ttft_p99_s"],
            "wall_s": s["wall_s"],
            "timed_tokens": s["timed_tokens"],
            "faults_injected": s["faults_injected"],
            "windows": s["windows"],
            "discarded_tokens": s["discarded_tokens"],
            "prefills": s["prefills"],
            "prefill_chunks": s["prefill_chunks"],
            "prefill_chunk_tokens": s["prefill_chunk_tokens"],
            "host_stalls": s["host_stalls"],
            "host_stall_s": s["host_stall_s"],
            "retries": s["retries"],
            "acceptance_rate": s.get("acceptance_rate", 0.0),
            "tokens_per_step": s.get("tokens_per_step", 0.0),
            "draft_tokens": s.get("draft_tokens", 0),
            "rejected_draft_tokens": s.get("rejected_draft_tokens", 0),
        }
    eng = record["engines"]
    blocking, overlap = f"window{WINDOW}_blocking", f"window{WINDOW}_overlap"
    for label in ("steady", "faulted"):
        base = eng["stepwise"][label]["tokens_per_s"]
        blk = eng[blocking][label]["tokens_per_s"]
        ovl = eng[overlap][label]["tokens_per_s"]
        record[f"speedup_{label}"] = blk / base if base > 0 else 0.0
        record[f"overlap_speedup_{label}"] = ovl / blk if blk > 0 else 0.0
        record[f"overlap_ttft_p99_ratio_{label}"] = (
            eng[overlap][label]["ttft_p99_s"] /
            eng[blocking][label]["ttft_p99_s"]
            if eng[blocking][label]["ttft_p99_s"] > 0 else 0.0)
    rows.append(("serve_window_speedup",
                 f"{record['speedup_steady']:.2f}x_steady", 0.0))
    rows.append(("serve_overlap_speedup",
                 f"{record['overlap_speedup_faulted']:.2f}x_faulted", 0.0))
    spec, spec_base = f"window{WINDOW}_spec", f"window{WINDOW}_overlap_qwen3"
    for label in ("steady", "faulted"):
        base = eng[spec_base][label]["tokens_per_s"]
        record[f"spec_speedup_{label}"] = (
            eng[spec][label]["tokens_per_s"] / base if base > 0 else 0.0)
    rows.append(("serve_spec_speedup",
                 f"{record['spec_speedup_steady']:.2f}x_steady_"
                 f"acc{eng[spec]['steady']['acceptance_rate']:.2f}", 0.0))
    paged_rows, paged_record = bench_paged_capacity()
    rows.extend(paged_rows)
    record["paged"] = paged_record
    tracer_rows, tracer_record = bench_tracer_overhead()
    rows.extend(tracer_rows)
    record["tracer"] = tracer_record
    elastic_rows, elastic_record = bench_elastic()
    rows.extend(elastic_rows)
    record["elastic"] = elastic_record
    return rows, record


def run():
    rows, _ = bench_all()
    return rows


def smoke(window: int = WINDOW) -> None:
    """CI decode-hotpath gate: windowed must not be slower than stepwise.

    Tiny workload (compile time excluded by the warm request); asserts the
    window engine's steady tokens/s ≥ the per-token baseline so the gate
    fails if the zero-sync path regresses to per-token host round trips.
    """
    base = _serve_once(dict(window=0), n_requests=4, max_new=32, prompt_len=3)
    win = _serve_once(dict(window=window, overlap=False), n_requests=4,
                      max_new=32, prompt_len=3)
    b, w = base["tokens_per_s_timed"], win["tokens_per_s_timed"]
    print(f"decode-hotpath smoke: stepwise {b:.0f} tok/s, "
          f"window{window} {w:.0f} tok/s ({w / max(b, 1e-9):.2f}x)")
    # small tolerance: the real win is ≥2x, but a single OS preemption on a
    # loaded CI box must not read as a regression
    assert w >= 0.9 * b, (
        f"windowed decode ({w:.0f} tok/s) slower than stepwise ({b:.0f} "
        "tok/s) — the zero-sync window path has regressed")


def smoke_overlap(window: int = WINDOW) -> None:
    """CI overlap gate: on faulted admission-heavy traffic the overlapped
    engine must not be slower than the blocking-window engine — fails if the
    stall-free path regresses to blocking prefills between windows."""
    kw = dict(n_requests=8, max_new=24, prompt_len=PROMPT_LEN,
              fault_every=FAULT_EVERY)
    blk = _serve_once(dict(window=window, overlap=False), **kw)
    ovl = _serve_once(dict(window=window, overlap=True), **kw)
    b, o = blk["tokens_per_s_timed"], ovl["tokens_per_s_timed"]
    print(f"overlap smoke (faulted): blocking {b:.0f} tok/s "
          f"({blk['host_stalls']} stalls, {blk['host_stall_s'] * 1e3:.0f}ms "
          f"stalled), overlapped {o:.0f} tok/s ({ovl['host_stalls']} stalls) "
          f"— {o / max(b, 1e-9):.2f}x")
    assert ovl["host_stalls"] == 0, "overlapped engine blocked on a prefill"
    # same noise tolerance as the decode-hotpath gate
    assert o >= 0.9 * b, (
        f"overlapped serving ({o:.0f} tok/s) slower than blocking windows "
        f"({b:.0f} tok/s) — chunked-prefill fusion has regressed")


def smoke_paged(window: int = WINDOW) -> None:
    """CI paged gate: the paged engine must be token-bit-exact vs the
    contiguous overlap engine on identical (steady *and* faulted) traffic,
    never stall the host, and sustain ≥ 2× the contiguous slot count on a
    mixed-length workload at an equal HBM budget — small-scale versions of
    the ISSUE-4 acceptance criteria."""
    cfg = smoke_config(PAGED_ARCH)
    max_len, page = 64, 16

    def serve(paged, inject_at=None):
        rep = Replica(cfg, config=EngineConfig(
            num_slots=2, max_len=max_len, window=window, overlap=True,
            max_request_retries=6, paged=paged, page_size=page))
        reqs = [Request(id=i, prompt=tuple(5 + i + j for j in range(9)),
                        max_new_tokens=16) for i in range(5)]
        for r in reqs:
            assert rep.submit(r) is None
        out, steps = {}, 0
        while not rep.idle():
            if steps == inject_at:
                # poison a decoding lane both engines will actually consume
                eligible = [i for i in rep.sched.active_slots()
                            if rep.sched.slots[i].pending is None]
                if eligible:
                    rep.inject_state_fault(eligible[0])
            for resp in rep.step():
                out[resp.id] = resp
            steps += 1
            assert steps < 2000
        assert all(r.status == "ok" for r in out.values())
        if paged:
            rep.alloc.check()
        return rep, out

    for label, inject_at in (("steady", None), ("faulted", 8)):
        _, base = serve(False, inject_at)
        rep, got = serve(True, inject_at)
        assert sorted(got) == sorted(base)
        for i in base:
            assert got[i].tokens == base[i].tokens, (
                f"paged engine diverged from contiguous on {label} traffic "
                f"(request {i})")
        assert rep.metrics.host_stalls == 0, "paged engine stalled the host"
        print(f"paged smoke ({label}): bit-exact over {len(base)} requests")

    # capacity: mixed lens, 2× slots on the contiguous pool byte budget
    budget_pages = 2 * (max_len // page)
    prompts = (4, 40, 8, 12, 6, 32, 10, 8)

    def mixed(paged, slots):
        rep = Replica(cfg, config=EngineConfig(
            num_slots=slots, max_len=max_len, window=window, overlap=True,
            paged=paged, page_size=page,
            page_budget=budget_pages if paged else None))
        for i, plen in enumerate(prompts):
            assert rep.submit(Request(
                id=i, prompt=tuple(3 + i + j for j in range(plen)),
                max_new_tokens=8)) is None
        steps = 0
        n_ok = 0
        while not rep.idle():
            n_ok += sum(r.status == "ok" for r in rep.step())
            steps += 1
            assert steps < 4000
        assert n_ok == len(prompts), "dropped requests under paging pressure"
        return rep.metrics.peak_active_slots

    contig_peak = mixed(False, 2)
    paged_peak = mixed(True, 4)
    print(f"paged smoke (capacity): {paged_peak} concurrent slots paged vs "
          f"{contig_peak} contiguous at equal HBM budget")
    assert paged_peak >= 2 * contig_peak, (
        f"paged engine sustained {paged_peak} slots vs {contig_peak} "
        "contiguous — the capacity win has regressed")


def smoke_spec(window: int = WINDOW) -> None:
    """CI speculative gate: the spec engine must emit token-bit-exact output
    vs the overlap engine on identical steady AND faulted traffic (every
    emitted token is a full-model argmax, so draft-and-verify must be
    invisible in the stream), accept a non-zero fraction of drafts, and never
    stall the host — small-scale ISSUE-5 acceptance criteria."""
    cfg = smoke_config(SPEC_ARCH)

    def serve(speculate, inject):
        rep = Replica(cfg, config=EngineConfig(
            num_slots=2, max_len=MAX_LEN, window=window, overlap=True,
            max_request_retries=6, speculate=speculate,
            draft_len=SPEC_DRAFT_LEN, draft_layers=SPEC_DRAFT_LAYERS), seed=0)
        reqs = [Request(id=i, prompt=tuple(5 + i + j for j in range(9)),
                        max_new_tokens=16) for i in range(5)]
        for r in reqs:
            assert rep.submit(r) is None
        out, steps, injected = {}, 0, 0
        while not rep.idle():
            if inject and not injected:
                # poison a decoding lane both engines will actually consume
                eligible = [i for i in rep.sched.active_slots()
                            if rep.sched.slots[i].pending is None]
                if eligible and rep.inject_state_fault(
                        eligible[0]) is not None:
                    injected += 1
            for resp in rep.step():
                out[resp.id] = resp
            steps += 1
            assert steps < 2000
        assert all(r.status == "ok" for r in out.values())
        assert not inject or injected == 1
        return rep, out

    for label, inject in (("steady", False), ("faulted", True)):
        _, base = serve(False, inject)
        rep, got = serve(True, inject)
        assert sorted(got) == sorted(base)
        for i in base:
            assert got[i].tokens == base[i].tokens, (
                f"speculative engine diverged from overlap on {label} "
                f"traffic (request {i})")
        acc = rep.metrics.acceptance_rate()
        assert acc > 0, "speculation accepted no drafts"
        assert rep.metrics.host_stalls == 0, "spec engine stalled the host"
        print(f"spec smoke ({label}): bit-exact over {len(base)} requests, "
              f"acceptance {acc:.2f}, "
              f"{rep.metrics.tokens_per_step():.2f} tok/step")


def smoke_trace(window: int = WINDOW,
                out_path: str | None = None) -> None:
    """CI trace gate: on identical faulted overlap traffic, a replica with an
    enabled tracer must emit a token-bit-exact stream vs the no-op default
    (tracing is pure observation), the default must record zero events, and
    the dumped trace must pass the full post-mortem round-trip — every traced
    request reaches exactly one terminal span, every fault event resolves to
    a recovery lane or a terminal answer (``trace_tool.py --check`` runs the
    same validation on the artifact this gate writes)."""
    out_path = out_path or _artifact("trace-smoke.json")
    from repro.obs import Tracer, dump_trace, request_timelines, validate

    cfg = smoke_config("recurrentgemma-2b")
    n_requests = 6

    def serve(tracer):
        rep = Replica(cfg, config=EngineConfig(
            num_slots=2, max_len=MAX_LEN, window=window, overlap=True,
            max_request_retries=6), tracer=tracer)
        reqs = [Request(id=i, prompt=tuple(5 + i + j for j in range(9)),
                        max_new_tokens=16) for i in range(n_requests)]
        for r in reqs:
            assert rep.submit(r) is None
        out, steps, injected = {}, 0, 0
        while not rep.idle():
            if steps >= 4 and not injected:
                # poison a decoding lane the next window will consume
                eligible = [i for i in rep.sched.active_slots()
                            if rep.sched.slots[i].pending is None]
                if eligible and rep.inject_state_fault(
                        eligible[0]) is not None:
                    injected += 1
            for resp in rep.step():
                out[resp.id] = resp
            steps += 1
            assert steps < 2000
        assert injected == 1, "fault injection never landed"
        assert all(r.status == "ok" for r in out.values())
        return rep, out

    tr = Tracer()
    _, traced = serve(tr)
    rep_plain, plain = serve(None)
    assert sorted(traced) == sorted(plain)
    for i in plain:
        assert traced[i].tokens == plain[i].tokens, (
            f"tracing changed the token stream (request {i}) — "
            "observation must be pure")
    assert rep_plain.trace.num_events == 0, (
        "the no-op tracer recorded events")
    trace = dump_trace(out_path, tr)
    n = len(trace["traceEvents"])
    assert n > 0
    assert any(e["cat"] == "fault" for e in trace["traceEvents"]), (
        "injected fault left no fault span in the trace")
    problems = validate(trace)
    assert not problems, problems
    timelines = request_timelines(trace)
    assert len(timelines) == n_requests, (
        f"expected {n_requests} traced requests, got {len(timelines)}")
    print(f"trace smoke: bit-exact over {len(plain)} requests, {n} events "
          f"-> {out_path}, validate OK")


def smoke_elastic(window: int = WINDOW,
                  out_path: str | None = None,
                  ledger_path: str | None = None) -> None:
    """CI elastic gate: the ISSUE-8 acceptance story at smoke scale. A 3-rank
    group serves 24 requests with the durable ledger on; rank 2 is killed at
    round 2 (ULFM shrink + re-route), then the WHOLE fleet stops at round 4 —
    only the fsync'd write-ahead log survives. A new incarnation restarts
    from the log alone, replays the outstanding set onto the survivors, and
    regrows to 3 ranks by re-admitting the killed rank through the
    non-blocking join. Zero drops, every stream bit-exact vs a clean run,
    and the merged two-incarnation trace passes the post-mortem check
    (``trace_tool.py --check`` re-validates the artifacts this gate writes —
    the ledger and trace CI uploads are the ones that passed)."""
    out_path = out_path or _artifact("elastic-smoke-trace.json")
    ledger_path = ledger_path or _artifact("elastic-smoke.wal")
    from repro.core.faults import FaultSchedule, FaultSpec
    from repro.obs import validate
    from repro.obs.trace import merge_trace_dicts
    from repro.serve import ServeGroup
    from repro.serve.ledger import replay as replay_ledger

    for stale in (out_path, ledger_path):
        if os.path.exists(stale):
            os.remove(stale)     # a prior run's WAL must not replay into ours
    cfg = smoke_config("recurrentgemma-2b")
    group = ServeGroup(cfg, 3, max_ranks=3,
                       config=EngineConfig(num_slots=2, max_len=MAX_LEN,
                                           window=window, overlap=True,
                                           max_request_retries=6, trace=True))
    n = 24
    mk = lambda: [Request(id=i, prompt=tuple(5 + i + j for j in range(8)),
                          max_new_tokens=12) for i in range(n)]
    clean = group.serve(mk())
    assert all(r.ok for r in clean.responses.values())
    r1 = group.serve(mk(), faults=FaultSchedule(
        [FaultSpec(step=2, kind="kill", rank=2)]),
        ledger_path=ledger_path, crash_at=4)
    assert r1.crashed, "the fleet stop never fired"
    assert len(r1.responses) < n, "nothing was outstanding at the crash"
    r2 = group.serve_from_ledger(ledger_path, joins=[1])
    merged = {**r1.responses, **r2.responses}
    assert sorted(merged) == list(range(n)), "dropped requests across the crash"
    assert all(r.ok for r in merged.values())
    assert 2 in r2.joined, "the killed rank never rejoined"
    assert r2.replayed, "no requests were replayed from the ledger"
    for rid, resp in merged.items():
        assert tuple(resp.tokens) == tuple(clean.responses[rid].tokens), (
            f"request {rid} diverged from the clean run — the crash/replay/"
            "regrow leaked into the token stream")
    trace = merge_trace_dicts(r1.trace(), r2.trace())
    problems = validate(trace)
    assert not problems, problems
    with open(out_path, "w") as f:
        json.dump(trace, f)
    rep = replay_ledger(ledger_path)
    print(f"elastic smoke: {len(merged)}/{n} answered across the fleet crash "
          f"(bit-exact), {len(r2.replayed)} replayed from {rep.records} WAL "
          f"records, rank 2 rejoined (epoch {r2.epoch}) "
          f"-> {out_path}, {ledger_path}")


def smoke_tp(window: int = WINDOW,
             out_path: str | None = None) -> None:
    """CI tensor-parallel gate: the ISSUE-9 acceptance story at smoke scale.

    (1) *Bit-exactness*: the ``tp=2`` engine (storage sharded over the
    "model" mesh axis, compute replicated inside the shard_mapped window,
    per-shard error words OR-folded at retirement) must emit token-bit-exact
    streams vs the single-device window engine on identical traffic — steady,
    AND with a ``STATE_FAULT`` word injected on *one shard only* (the fold
    must latch it on every shard and LFLR must recover to the clean streams).
    (2) *Shard loss*: inside a 2-rank ServeGroup, losing one shard of rank 1
    is a hard fault of the whole replica — RANK_FAILED → ULFM shrink →
    re-route, zero dropped requests — and the dumped group trace passes the
    post-mortem check, shard-fanout rules included (``trace_tool.py --check``
    re-validates the artifact this gate writes)."""
    out_path = out_path or _artifact("tp-smoke-trace.json")
    import numpy as np

    from repro.core.errors import ErrorCode
    from repro.core.faults import FaultSchedule, FaultSpec
    from repro.obs import validate
    from repro.serve import ServeGroup

    ndev = len(jax.devices())
    assert ndev >= TP, (
        f"tp={TP} smoke needs {TP} devices, found {ndev} — run with "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={TP}")
    cfg = smoke_config(TP_ARCH)
    n_requests = 4

    def shard_injector(shard, code, at=3):
        # one-shard word injection at dispatch `at`, window step 1, slot 0:
        # the OR-fold must make it indistinguishable from an all-shard fault
        def inject(index, shape):
            if index != at or len(shape) != 3:
                return None
            w = np.zeros(shape, np.uint32)
            w[shard, 1, 0] = np.uint32(code)
            return w
        return inject

    def serve(tp, injector=None):
        rep = Replica(cfg, config=EngineConfig(
            num_slots=2, max_len=MAX_LEN, window=window, overlap=True,
            max_request_retries=6, tp=tp), fault_injector=injector)
        reqs = [Request(id=i, prompt=tuple(5 + i + j for j in range(9)),
                        max_new_tokens=16) for i in range(n_requests)]
        for r in reqs:
            assert rep.submit(r) is None
        out, steps = {}, 0
        while not rep.idle():
            for resp in rep.step():
                out[resp.id] = resp
            steps += 1
            assert steps < 2000
        assert all(r.status == "ok" for r in out.values())
        return rep, out

    _, base = serve(1)
    for label, injector in (
            ("steady", None),
            ("faulted", shard_injector(0, int(ErrorCode.STATE_FAULT)))):
        rep, got = serve(TP, injector)
        assert sorted(got) == sorted(base)
        for i in base:
            assert got[i].tokens == base[i].tokens, (
                f"tp={TP} engine diverged from single-device on {label} "
                f"traffic (request {i})")
        counts = rep.metrics.fault_counts()
        if injector is None:
            assert not counts, f"steady tp run recorded faults: {counts}"
        else:
            assert counts.get("STATE_FAULT") == 1, (
                f"one-shard injection did not latch exactly once: {counts}")
        print(f"tp smoke ({label}): bit-exact over {len(base)} requests, "
              f"tp={TP}")

    # shard loss inside a group: RANK_FAILED -> shrink -> re-route, no drops
    group = ServeGroup(cfg, 2, config=EngineConfig(
        num_slots=2, max_len=48, window=window, overlap=True,
        max_request_retries=6, tp=TP, trace=True))
    reqs = [Request(id=i, prompt=tuple(5 + i + j for j in range(8)),
                    max_new_tokens=12) for i in range(6)]
    res = group.serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=1, kind="shard_kill", rank=1, shard=1)]))
    assert sorted(res.responses) == list(range(len(reqs))), (
        "dropped requests across the shard loss")
    assert all(r.ok for r in res.responses.values())
    assert res.rerouted, "no requests were re-routed off the dead replica"
    trace = res.trace()
    names = {e.get("name") for e in trace["traceEvents"]}
    assert {"shard_loss", "replica_kill", "ulfm_shrink", "reroute"} <= names, (
        f"shard-loss causality chain incomplete: {sorted(names)}")
    problems = validate(trace)
    assert not problems, problems
    with open(out_path, "w") as f:
        json.dump(trace, f)
    print(f"tp smoke (shard loss): {len(res.responses)}/{len(reqs)} answered "
          f"after losing shard 1 of rank 1 ({len(res.rerouted)} re-routed) "
          f"-> {out_path}, validate OK")


def smoke_multihost(out_path: str | None = None,
                    ledger_path: str | None = None) -> None:
    """CI multi-host gate: the ISSUE-10 acceptance story at smoke scale.

    (1) *SIGKILL leg* (real engine): 3 worker **processes**, each owning one
    real :class:`Replica` (params rebuilt per process from the shared
    PRNGKey), serve 9 requests under the heartbeat supervisor with the
    durable WAL on; worker 1 is SIGKILL'd once 2 responses have been retired
    fleet-wide. The dead process must be *detected* by missed heartbeats
    (suspect → evict, never by the socket EOF shortcut), *mapped*
    (``RANK_FAILED`` latched into the surviving group word) and *repaired*
    (epoch shrink agreed over the socket transport, outstanding requests
    re-routed from the WAL) — zero drops, every stream token-bit-exact vs an
    in-process single-replica reference, detection-to-evict within
    ``2 × suspect_timeout``, and at least one survivor retirement lands
    *inside* the detection window (survivors never block on the dead peer).
    (2) *SIGSTOP leg* (sim backend): a worker stopped for half the suspect
    timeout and resumed must be suspected and then **cleared — never
    evicted** (the slow-but-alive false-positive guard), still zero drops
    and bit-exact. The merged two-leg trace passes the post-mortem check,
    host-eviction rules included (``trace_tool.py --check`` re-validates
    the artifact this gate writes)."""
    out_path = out_path or _artifact("multihost-smoke-trace.json")
    ledger_path = ledger_path or _artifact("multihost-smoke.wal")
    from repro.core.faults import FaultSchedule, FaultSpec
    from repro.obs import validate
    from repro.obs.trace import merge_trace_dicts
    from repro.serve import MultiHostSupervisor, sim_tokens

    if os.path.exists(ledger_path):
        os.remove(ledger_path)   # a prior run's WAL must not replay into ours
    arch = "qwen3-1.7b"
    suspect_timeout = 0.8
    n = 9
    mk = lambda: [Request(id=i, prompt=tuple(5 + i + j for j in range(8)),
                          max_new_tokens=12) for i in range(n)]
    engine = EngineConfig(num_slots=2, max_len=32)

    # in-process reference: same arch/seed/engine as every worker process
    from repro.models import build_model
    cfg = smoke_config(arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    ref_rep = Replica(cfg, params=params, config=engine)
    ref, steps = {}, 0
    for r in mk():
        assert ref_rep.submit(r) is None
    while not ref_rep.idle():
        for resp in ref_rep.step():
            ref[resp.id] = resp
        steps += 1
        assert steps < 2000
    assert sorted(ref) == list(range(n))

    # --- SIGKILL leg: real replicas across real process boundaries
    sup = MultiHostSupervisor(3, backend="replica", arch=arch, config=engine,
                              suspect_timeout=suspect_timeout,
                              heartbeat_interval=0.05, trace=True,
                              ledger_path=ledger_path, timeout=180.0)
    res = sup.serve(mk(), faults=FaultSchedule(
        [FaultSpec(step=2, kind="host_kill", rank=1)]))
    assert sorted(res.responses) == list(range(n)), (
        "dropped requests across the host loss")
    assert all(r.ok for r in res.responses.values())
    for i, resp in res.responses.items():
        assert tuple(resp.tokens) == tuple(ref[i].tokens), (
            f"request {i} diverged from the in-process reference — the "
            "process boundary / eviction / re-route leaked into the stream")
    assert res.evicted == (1,), f"expected worker 1 evicted, got {res.evicted}"
    assert res.rerouted, "no requests were re-routed off the dead worker"
    det = res.detection[1]
    lat = det["evict_ts"] - det["kill_ts"]
    assert lat <= 2 * suspect_timeout, (
        f"detection-to-evict {lat:.3f}s exceeds 2x suspect_timeout")
    mid = [rid for (ts, rank, rid) in res.retires
           if det["kill_ts"] < ts < det["evict_ts"] and rank != 1]
    assert mid, ("no survivor retired a response inside the detection "
                 "window — survivors blocked on the dead peer")

    # --- SIGSTOP leg: paused-then-resumed worker must NOT be evicted
    sup2 = MultiHostSupervisor(3, backend="sim",
                               suspect_timeout=suspect_timeout,
                               heartbeat_interval=0.05, trace=True,
                               sim_tokens_per_step=2, sim_step_delay_s=0.01,
                               timeout=120.0)
    # distinct ids: the merged two-leg trace must keep one terminal span
    # per traced request
    reqs2 = [Request(id=100 + i, prompt=tuple(5 + i + j for j in range(8)),
                     max_new_tokens=12) for i in range(n)]
    res2 = sup2.serve(reqs2, faults=FaultSchedule(
        [FaultSpec(step=1, kind="host_stop", rank=2,
                   magnitude=0.5 * suspect_timeout)]))
    assert sorted(res2.responses) == [100 + i for i in range(n)]
    for rid, resp in res2.responses.items():
        assert tuple(resp.tokens) == sim_tokens(
            tuple(5 + (rid - 100) + j for j in range(8)), 12), (
            f"request {rid} diverged from the sim oracle under SIGSTOP")
    assert res2.evicted == (), (
        f"SIGSTOP within the suspect timeout must never evict, "
        f"got {res2.evicted}")
    assert 2 in res2.stopped and 2 in res2.suspected and 2 in res2.resumed, (
        "the stop leg never exercised the suspect -> clear path")

    trace = merge_trace_dicts(res.trace(), res2.trace())
    problems = validate(trace)
    assert not problems, problems
    names = {e.get("name") for e in trace["traceEvents"]}
    assert {"host_kill", "host_suspect", "host_evict", "ulfm_shrink",
            "reroute", "epoch", "host_stop", "host_suspect_clear"} <= names, (
        f"host causality chain incomplete: {sorted(names)}")
    with open(out_path, "w") as f:
        json.dump(trace, f)
    print(f"multihost smoke: {len(res.responses)}/{n} answered after "
          f"SIGKILL of worker 1 (bit-exact, {len(res.rerouted)} re-routed, "
          f"evict {lat:.2f}s <= {2 * suspect_timeout:.2f}s, {len(mid)} "
          f"survivor retires in-window); SIGSTOP leg suspected+cleared, "
          f"0 evictions -> {out_path}, {ledger_path}")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        if "--overlap" in sys.argv:
            smoke_overlap()
        elif "--paged" in sys.argv:
            smoke_paged()
        elif "--spec" in sys.argv:
            smoke_spec()
        elif "--trace" in sys.argv:
            smoke_trace()
        elif "--elastic" in sys.argv:
            smoke_elastic()
        elif "--tp" in sys.argv:
            smoke_tp()
        elif "--multihost" in sys.argv:
            smoke_multihost()
        else:
            smoke()
    else:
        for name, derived, us in run():
            print(f"{name},{us:.2f},{derived}")
