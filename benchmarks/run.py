"""Benchmark harness: one experiment per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV:
  * Table I analogue  -> transport_latency (barrier, plain vs ULFM mode)
  * Figure 2 analogue -> error_propagation (black channel vs ULFM revoke)
  * beyond paper      -> detection_overhead (in-band device channel cost)
  * recovery costs    -> LFLR vs optimizer-reset vs rollback vs buddy store
  * roofline bounds   -> per-cell dominant-term bound from dry-run artifacts
  * serving           -> repro.serve steady-state tokens/s + latency
                         percentiles, clean vs injected-fault traffic, for
                         the per-token and decode-window engines

Flags:
  --json [PATH]   also append the serving benchmark to the run history in
                  PATH (default: BENCH_serving.json) as machine-readable
                  JSON — ``{"runs": [...]}``, one record per invocation with
                  the git rev + config, so the perf trajectory is tracked
                  across PRs instead of overwritten
  --only NAME     run a single section (e.g. --only serving)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 - benchmarks must not die on metadata
        return "unknown"


def _append_history(path: str, record: dict) -> None:
    """Append ``record`` to the run history at ``path``.

    The file is ``{"benchmark": "serving", "runs": [...]}``; a pre-history
    file (one bare record, the PR-2 format) is migrated by becoming the
    first entry of the list.
    """
    record = dict(record)
    record["git_rev"] = _git_rev()
    record["date"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    history: dict = {"benchmark": record.get("benchmark", "serving"),
                     "runs": []}
    try:
        with open(path) as f:
            prev = json.load(f)
        if isinstance(prev, dict) and isinstance(prev.get("runs"), list):
            history["runs"] = prev["runs"]
        elif isinstance(prev, dict) and prev:
            history["runs"] = [prev]     # migrate the pre-history format
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    history["runs"].append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    from . import (detection_overhead, error_propagation, recovery,
                   roofline_table, serving, transport_latency)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_serving.json",
                    default=None, metavar="PATH",
                    help="write serving results to PATH as JSON")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run a single section")
    args = ap.parse_args()

    serving_record = {}

    def serving_rows():
        rows, record = serving.bench_all()
        serving_record.update(record)
        return rows

    print("name,us_per_call,derived")
    sections = [
        ("transport_latency", lambda: transport_latency.run(ranks=(2, 4, 8, 16))),
        ("error_propagation", lambda: error_propagation.run(ranks=(4, 8, 16, 32))),
        ("detection_overhead", detection_overhead.run),
        ("recovery", recovery.run),
        ("roofline", roofline_table.run),
        ("serving", serving_rows),
    ]
    if args.only:
        sections = [(n, f) for n, f in sections if n == args.only]
        if not sections:
            raise SystemExit(f"unknown section: {args.only}")
    for name, fn in sections:
        try:
            for row_name, derived, us in fn():
                print(f"{row_name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}", file=sys.stderr)
            print(f"{name}_FAILED,0,0")
    if args.json and serving_record:
        _append_history(args.json, serving_record)
        print(f"appended run to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
