"""Benchmark harness: one experiment per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV:
  * Table I analogue  -> transport_latency (barrier, plain vs ULFM mode)
  * Figure 2 analogue -> error_propagation (black channel vs ULFM revoke)
  * beyond paper      -> detection_overhead (in-band device channel cost)
  * recovery costs    -> LFLR vs optimizer-reset vs rollback vs buddy store
  * roofline bounds   -> per-cell dominant-term bound from dry-run artifacts
  * serving           -> repro.serve steady-state tokens/s + latency
                         percentiles, clean vs injected-fault traffic
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import (detection_overhead, error_propagation, recovery,
                   roofline_table, serving, transport_latency)

    print("name,us_per_call,derived")
    sections = [
        ("transport_latency", lambda: transport_latency.run(ranks=(2, 4, 8, 16))),
        ("error_propagation", lambda: error_propagation.run(ranks=(4, 8, 16, 32))),
        ("detection_overhead", detection_overhead.run),
        ("recovery", recovery.run),
        ("roofline", roofline_table.run),
        ("serving", serving.run),
    ]
    for name, fn in sections:
        try:
            for row_name, derived, us in fn():
                print(f"{row_name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}", file=sys.stderr)
            print(f"{name}_FAILED,0,0")


if __name__ == "__main__":
    main()
