"""Benchmark harness: one experiment per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV:
  * Table I analogue  -> transport_latency (barrier, plain vs ULFM mode)
  * Figure 2 analogue -> error_propagation (black channel vs ULFM revoke)
  * beyond paper      -> detection_overhead (in-band device channel cost)
  * recovery costs    -> LFLR vs optimizer-reset vs rollback vs buddy store
  * roofline bounds   -> per-cell dominant-term bound from dry-run artifacts
  * serving           -> repro.serve steady-state tokens/s + latency
                         percentiles, clean vs injected-fault traffic, for
                         the per-token and decode-window engines

Flags:
  --json [PATH]   also append the serving benchmark to the run history in
                  PATH (default: BENCH_serving.json) as machine-readable
                  JSON — ``{"runs": [...]}``, one record per invocation with
                  the git rev + config, so the perf trajectory is tracked
                  across PRs instead of overwritten. The record is validated
                  against the serving schema before the file is touched, and
                  a dirty working tree is refused without ``--allow-dirty``
                  (a run that doesn't correspond to a commit would poison
                  the bench-regression gate's history).
  --allow-dirty   record a run even with uncommitted changes in the tree
  --only NAME     run a single section (e.g. --only serving)
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 - benchmarks must not die on metadata
        return "unknown"


def _dirty_paths(exclude: str) -> list[str]:
    """Uncommitted changes (`git status --porcelain`), minus the history file
    itself — appending run N+1 after run N inevitably dirties that one file."""
    try:
        out = subprocess.run(
            ["git", "status", "--porcelain"], cwd=_REPO,
            capture_output=True, text=True, timeout=10).stdout
    except Exception:  # noqa: BLE001 - no git ⇒ nothing to refuse on
        return []
    excl = os.path.relpath(os.path.abspath(exclude), _REPO)
    paths = []
    for line in out.splitlines():
        p = line[3:].split(" -> ")[-1].strip().strip('"')
        if p and p != excl:
            paths.append(p)
    return paths


# Required numeric keys per engine × scenario cell — the contract the
# bench-regression gate (scripts/bench_gate.py) depends on.
_CELL_KEYS = ("tokens_per_s", "latency_p50_s", "latency_p99_s",
              "ttft_p50_s", "ttft_p99_s", "wall_s", "timed_tokens")
_SCENARIOS = ("steady", "faulted")


def validate_serving_record(record: dict) -> list[str]:
    """Schema check for one serving run record; returns the violations
    (empty = valid). Extra keys are always allowed — the schema only pins
    what downstream tooling reads."""
    errs: list[str] = []
    if record.get("benchmark") != "serving":
        errs.append(f"benchmark must be 'serving', got "
                    f"{record.get('benchmark')!r}")
    if not isinstance(record.get("config"), dict):
        errs.append("config must be a dict")
    engines = record.get("engines")
    if not isinstance(engines, dict) or not engines:
        errs.append("engines must be a non-empty dict")
        return errs
    for engine, cells in engines.items():
        if not isinstance(cells, dict):
            errs.append(f"engines[{engine!r}] must be a dict")
            continue
        for scen in _SCENARIOS:
            cell = cells.get(scen)
            if not isinstance(cell, dict):
                errs.append(f"engines[{engine!r}] missing scenario {scen!r}")
                continue
            for key in _CELL_KEYS:
                v = cell.get(key)
                if not isinstance(v, (int, float)) or isinstance(v, bool) \
                        or not math.isfinite(v) or v < 0:
                    errs.append(f"engines[{engine!r}][{scen!r}][{key!r}] "
                                f"must be a finite number >= 0, got {v!r}")
    paged = record.get("paged")
    if paged is not None:
        for side in ("contiguous", "paged"):
            cell = paged.get(side) if isinstance(paged, dict) else None
            if not isinstance(cell, dict) or not isinstance(
                    cell.get("tokens_per_s"), (int, float)):
                errs.append(f"paged[{side!r}] must carry tokens_per_s")
    return errs


def _append_history(path: str, record: dict, *,
                    allow_dirty: bool = False) -> None:
    """Append ``record`` to the run history at ``path``.

    The file is ``{"benchmark": "serving", "runs": [...]}``; a pre-history
    file (one bare record, the PR-2 format) is migrated by becoming the
    first entry of the list. The record is schema-validated and the working
    tree must be clean (modulo the history file itself) unless
    ``allow_dirty`` — both guards keep the bench-gate history trustworthy.
    """
    errs = validate_serving_record(record)
    if errs:
        raise ValueError(
            "refusing to record a malformed serving run:\n  "
            + "\n  ".join(errs))
    dirty = _dirty_paths(exclude=path)
    if dirty and not allow_dirty:
        raise SystemExit(
            f"refusing to record a bench run from a dirty working tree "
            f"({len(dirty)} changed paths, e.g. {dirty[:3]}): the history "
            "maps runs to commits for the regression gate — commit first, "
            "or pass --allow-dirty to record anyway")
    record = dict(record)
    record["git_rev"] = _git_rev()
    record["date"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    history: dict = {"benchmark": record.get("benchmark", "serving"),
                     "runs": []}
    try:
        with open(path) as f:
            prev = json.load(f)
        if isinstance(prev, dict) and isinstance(prev.get("runs"), list):
            history["runs"] = prev["runs"]
        elif isinstance(prev, dict) and prev:
            history["runs"] = [prev]     # migrate the pre-history format
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    history["runs"].append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    from . import (detection_overhead, error_propagation, recovery,
                   roofline_table, serving, transport_latency)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_serving.json",
                    default=None, metavar="PATH",
                    help="write serving results to PATH as JSON")
    ap.add_argument("--allow-dirty", action="store_true",
                    help="record a run even with uncommitted changes")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run a single section")
    args = ap.parse_args()

    if args.json and not args.allow_dirty:
        # fail BEFORE the multi-minute bench run, not after it
        dirty = _dirty_paths(exclude=args.json)
        if dirty:
            raise SystemExit(
                f"refusing to record a bench run from a dirty working tree "
                f"({len(dirty)} changed paths, e.g. {dirty[:3]}): commit "
                "first, or pass --allow-dirty to record anyway")

    serving_record = {}

    def serving_rows():
        rows, record = serving.bench_all()
        serving_record.update(record)
        return rows

    print("name,us_per_call,derived")
    sections = [
        ("transport_latency", lambda: transport_latency.run(ranks=(2, 4, 8, 16))),
        ("error_propagation", lambda: error_propagation.run(ranks=(4, 8, 16, 32))),
        ("detection_overhead", detection_overhead.run),
        ("recovery", recovery.run),
        ("roofline", roofline_table.run),
        ("serving", serving_rows),
    ]
    if args.only:
        sections = [(n, f) for n, f in sections if n == args.only]
        if not sections:
            raise SystemExit(f"unknown section: {args.only}")
    for name, fn in sections:
        try:
            for row_name, derived, us in fn():
                print(f"{row_name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}", file=sys.stderr)
            print(f"{name}_FAILED,0,0")
    if args.json and serving_record:
        _append_history(args.json, serving_record,
                        allow_dirty=args.allow_dirty)
        print(f"appended run to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
