"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles, with
shape/dtype sweeps (hypothesis for the fault probe's value-pattern space)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="kernel property tests need hypothesis "
                    "(pip install repro[test])")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.errors import ErrorCode
from repro.kernels.fault_probe.kernel import probe_rows
from repro.kernels.fault_probe.ref import probe_array_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import sdpa_ref
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_assoc, rglru_scan_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_chunked, ssd_naive_ref

NF = int(ErrorCode.NONFINITE_GRAD)
OV = int(ErrorCode.OVERFLOW)


# ------------------------------------------------------------------ fault probe
@pytest.mark.parametrize("rows,block_rows", [(256, 256), (512, 256), (1024, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fault_probe_clean(rows, block_rows, dtype):
    x = jnp.ones((rows, 128), dtype)
    w = probe_rows(x, jnp.asarray(1e4), nonfinite_code=NF, overflow_code=OV,
                   block_rows=block_rows, interpret=True)
    assert int(w) == 0


@pytest.mark.parametrize("poison,expected", [
    (jnp.nan, NF), (jnp.inf, NF), (-jnp.inf, NF), (1e6, OV), (-1e6, OV),
])
def test_fault_probe_detects(poison, expected):
    x = jnp.ones((512, 128), jnp.float32).at[300, 77].set(poison)
    w = probe_rows(x, jnp.asarray(1e4), nonfinite_code=NF, overflow_code=OV,
                   block_rows=256, interpret=True)
    assert int(w) == expected
    ref = probe_array_ref(x, 1e4, nonfinite_code=NF, overflow_code=OV)
    assert int(w) == int(ref)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 511), st.integers(0, 127),
                          st.sampled_from(["nan", "inf", "big", "ok"])),
                min_size=0, max_size=4))
def test_fault_probe_property(faults):
    """Kernel == oracle for arbitrary fault patterns (hypothesis)."""
    x = np.ones((512, 128), np.float32)
    for r, c, kind in faults:
        x[r, c] = {"nan": np.nan, "inf": np.inf, "big": 9e5, "ok": 1.0}[kind]
    xj = jnp.asarray(x)
    w = probe_rows(xj, jnp.asarray(1e4), nonfinite_code=NF, overflow_code=OV,
                   block_rows=256, interpret=True)
    ref = probe_array_ref(xj, 1e4, nonfinite_code=NF, overflow_code=OV)
    assert int(w) == int(ref)


# -------------------------------------------------------------- flash attention
FLASH_CASES = [
    # (B, S, T, Hq, Hkv, D, causal, window, bq, bkv)
    (1, 16, 16, 2, 2, 128, True, 0, 8, 8),
    (2, 32, 32, 4, 2, 128, True, 0, 16, 16),     # GQA
    (1, 32, 32, 4, 1, 128, True, 8, 16, 8),      # MQA + sliding window
    (1, 24, 24, 2, 2, 128, False, 0, 8, 8),      # bidirectional (encoder)
    (1, 20, 20, 2, 1, 128, True, 0, 8, 8),       # padding (S % bq != 0)
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, S, T, Hq, Hkv, D, causal, window, bq, bkv = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_kv=bkv)
    want = sdpa_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


# --------------------------------------------------------------------- ssd scan
SSD_CASES = [
    # (b, s, h, p, g, n, chunk)
    (1, 16, 2, 8, 1, 8, 8),
    (2, 32, 4, 8, 2, 8, 8),
    (1, 24, 2, 16, 1, 8, 8),
    (1, 32, 2, 8, 1, 8, 16),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_vs_naive(case):
    b, s, h, p, g, n, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n), jnp.float32) * 0.5
    C = jax.random.normal(jax.random.fold_in(ks[3], 1), (b, s, g, n),
                          jnp.float32) * 0.5
    got = ssd_scan(x, dt, A, B, C, chunk=chunk)
    naive = ssd_naive_ref(x, dt, A, B, C)
    chunked = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(naive),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- rglru scan
@pytest.mark.parametrize("B,S,W,blk", [(1, 16, 128, 128), (2, 32, 256, 128),
                                       (1, 64, 128, 64)])
def test_rglru_kernel_vs_refs(B, S, W, blk):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    x = jax.random.normal(ks[0], (B, S, W), jnp.float32)
    log_a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, W), jnp.float32))
    got = rglru_scan(x, log_a, block_w=blk)
    seq = rglru_scan_ref(x, log_a)
    assoc = rglru_scan_assoc(x, log_a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(seq),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(assoc), np.asarray(seq),
                               rtol=1e-5, atol=1e-5)
