"""Black-Channel protocol tests (paper §III-B): deadlock preclusion, propagation,
corrupted-communicator detection, simultaneous signalling, channel reuse."""
import pytest

from repro.core import (
    ANY_SOURCE,
    Comm,
    CommCorruptedError,
    ErrorCode,
    PropagatedError,
    TimeoutError_,
    initialize,
    run_ranks,
)

T = 20.0  # generous protocol timeout; tests fail fast on deadlock instead of hanging


def _world(ctx):
    return initialize(ctx, default_timeout=T).comm_world()


def test_basic_send_recv():
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank == 0:
            f = comm.send(42, dst=1)
        else:
            f = comm.recv(src=0)
        out = f.wait()
        return out

    res = run_ranks(2, fn)
    assert res[0].exception is None and res[1].exception is None
    assert res[1].value == 42


def test_propagation_releases_waiting_ranks():
    """Paper's core claim: a local exception no longer deadlocks remote waits."""
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank == 0:
            try:
                raise ValueError("local failure on rank 0")  # local C++ exception
            except ValueError:
                with pytest.raises(PropagatedError):
                    comm.signal_error(666)
            return "signalled"
        else:
            # rank 1..n-1 block in a receive that will never be matched
            f = comm.recv(src=0)
            with pytest.raises(PropagatedError) as ei:
                f.wait()
            assert ei.value.errors[0].rank == 0
            assert ei.value.errors[0].code == 666
            return "released"

    res = run_ranks(4, fn)
    for r in res:
        assert r.exception is None, r.exception
    assert res[0].value == "signalled"
    assert all(r.value == "released" for r in res[1:])


def test_without_channel_deadlocks():
    """Control experiment: the raw transport (no black channel) deadlocks — the
    situation the paper's technique precludes."""
    def fn(ctx):
        if ctx.rank == 0:
            return "rank0 threw and sent nothing"
        req = ctx.irecv(ctx.world, 0, 0)
        with pytest.raises(TimeoutError_):
            ctx.wait(req, timeout=0.3)
        return "timed out"

    res = run_ranks(2, fn)
    assert res[1].value == "timed out"


def test_simultaneous_signalling():
    """Two ranks signal at once (the reason the paper uses MPI_Issend)."""
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank in (0, 1):
            with pytest.raises(PropagatedError) as ei:
                comm.signal_error(100 + comm.rank)
        else:
            f = comm.recv(src=0)
            with pytest.raises(PropagatedError) as ei:
                f.wait()
        errs = sorted((e.rank, e.code) for e in ei.value.errors)
        return errs

    res = run_ranks(6, fn)
    expected = [(0, 100), (1, 101)]
    for r in res:
        assert r.exception is None, r.exception
        assert r.value == expected, r.value


def test_enumeration_order_and_codes():
    """Every rank gets the full, identically-ordered (rank, code) table."""
    signallers = {1: 7, 3: 9, 4: 11}

    def fn(ctx):
        comm = _world(ctx)
        if comm.rank in signallers:
            with pytest.raises(PropagatedError) as ei:
                comm.signal_error(signallers[comm.rank])
        else:
            f = comm.recv(src=(comm.rank + 1) % comm.size)
            with pytest.raises(PropagatedError) as ei:
                f.wait()
        return [(e.rank, e.code) for e in ei.value.errors]

    res = run_ranks(6, fn)
    expected = sorted((r, c) for r, c in signallers.items())
    for r in res:
        assert r.exception is None, r.exception
        assert sorted(r.value) == expected
        # paper's scan assigns indices in rank order → table is rank-ordered
        assert r.value == expected


def test_corrupted_communicator_on_unwinding():
    """Exception escaping the Comm scope ⇒ every rank throws CommCorruptedError."""
    def fn(ctx):
        inst = initialize(ctx, default_timeout=T)
        if ctx.rank == 0:
            with pytest.raises(RuntimeError):
                with inst.comm_world() as comm:
                    raise RuntimeError("unwinding through comm scope")
            return "unwound"
        else:
            with inst.comm_world() as comm:
                f = comm.recv(src=0)
                with pytest.raises(CommCorruptedError):
                    f.wait()
                return "corrupted observed"

    res = run_ranks(3, fn)
    for r in res:
        assert r.exception is None, r.exception
    assert res[0].value == "unwound"
    assert res[1].value == "corrupted observed"


def test_channel_reuse_after_propagated_error():
    """A recoverable (propagated) error leaves the communicator usable — the paper:
    'Reacting to these exceptions does not require to revoke and set up a new
    communicator.'"""
    def fn(ctx):
        comm = _world(ctx)
        # round 1: rank 0 signals
        if comm.rank == 0:
            with pytest.raises(PropagatedError):
                comm.signal_error(5)
        else:
            f = comm.recv(src=0)
            with pytest.raises(PropagatedError):
                f.wait()
        # round 2: normal communication must work again
        if comm.rank == 0:
            comm.send(99, dst=1).wait()
            return "ok"
        elif comm.rank == 1:
            return comm.recv(src=0).wait()
        return "ok"

    def body(ctx):
        out = fn(ctx)
        return out

    res = run_ranks(3, body)
    for r in res:
        assert r.exception is None, r.exception
    assert res[1].value == 99


def test_wait_sees_error_even_after_own_completion():
    """Paper: after Waitany completes the user request, MPI_Test(err_req) still
    detects a concurrent error signal."""
    import threading

    release = threading.Event()

    def fn(ctx):
        comm = _world(ctx)
        if comm.rank == 0:
            # complete a matched pair first, then signal
            comm.send(1, dst=1).wait()
            release.wait(timeout=T)
            with pytest.raises(PropagatedError):
                comm.signal_error(13)
            return "signalled"
        else:
            f = comm.recv(src=0)
            # ensure the message is already deliverable, then let rank 0 signal
            while not f.test():
                pass
            release.set()
            # wait() must still surface the error signalled after completion —
            # via the barrier-joined error epoch on a subsequent wait
            f.wait()  # completes fine (request already done, maybe no error yet)
            g = comm.recv(src=0)
            with pytest.raises(PropagatedError):
                g.wait()
            return "saw error"

    res = run_ranks(2, fn)
    for r in res:
        assert r.exception is None, r.exception


def test_cancel_semantics():
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank == 0:
            f = comm.recv(src=1, tag=5)
            assert f.cancel() is True  # unmatched: cancellable
            comm.barrier()
        else:
            comm.barrier()
        return "ok"

    res = run_ranks(2, fn)
    for r in res:
        assert r.exception is None, r.exception


@pytest.mark.parametrize("nranks", [2, 3, 8, 16])
def test_scales_with_ranks(nranks):
    def fn(ctx):
        comm = _world(ctx)
        if comm.rank == nranks - 1:
            with pytest.raises(PropagatedError) as ei:
                comm.signal_error(1)
        else:
            f = comm.recv(src=(comm.rank + 1) % comm.size)
            with pytest.raises(PropagatedError) as ei:
                f.wait()
        return [(e.rank, e.code) for e in ei.value.errors]

    res = run_ranks(nranks, fn)
    for r in res:
        assert r.exception is None, r.exception
        assert r.value == [(nranks - 1, 1)]
