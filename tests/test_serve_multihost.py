"""End-to-end tests for the multi-host fault domain (DESIGN §3.9).

Every "host" here is a real OS process: the supervisor spawns
``scripts/worker.py`` subprocesses, talks to them over the length-prefixed
socket protocol, runs the heartbeat failure detector, and executes host
faults by *signalling the processes* (SIGKILL / SIGSTOP+SIGCONT). The
acceptance contract under test:

* a SIGKILL'd worker is *detected* (missed heartbeats → suspect → evict,
  within ``2 × suspect_timeout``), *mapped* (``RANK_FAILED`` latched into
  the surviving group word) and *repaired* (epoch shrink agreed over the
  socket transport, outstanding requests re-routed from the durable WAL) —
  zero drops, bit-exact token streams;
* survivors keep decoding *during* detection (they never block on the dead
  peer — the star-topology emax has no collective to hang in);
* a SIGSTOP'd worker resumed within ``suspect_timeout`` is suspected and
  then cleared, never evicted (slow-but-alive ≠ dead).

The sim backend (deterministic arithmetic decode, no jit) keeps these fast;
one test runs the real Replica engine across the process boundary to pin
param-rebuild bit-exactness.
"""
import os

import pytest

from repro.core.faults import FaultSchedule, FaultSpec
from repro.obs import validate
from repro.serve import (
    AgreeDecision,
    EngineConfig,
    MultiHostSupervisor,
    Request,
    agree_round,
    sim_tokens,
)

SUSPECT_TIMEOUT = 0.6
N = 12


def mk_requests(n=N, prompt_len=8, max_new=12, id0=0):
    return [Request(id=id0 + i,
                    prompt=tuple(5 + i + j for j in range(prompt_len)),
                    max_new_tokens=max_new) for i in range(n)]


def mk_staggered(n=N, prompt_len=8):
    """Heterogeneous generation lengths: early ids retire quickly (arming
    the retire-count fault trigger) while late ids are still mid-decode, so
    a host kill always finds outstanding work to re-route."""
    return [Request(id=i, prompt=tuple(5 + i + j for j in range(prompt_len)),
                    max_new_tokens=6 + 4 * i) for i in range(n)]


def sim_oracle(reqs):
    return {r.id: sim_tokens(r.prompt, r.max_new_tokens) for r in reqs}


def sim_supervisor(nranks=3, **kw):
    kw.setdefault("suspect_timeout", SUSPECT_TIMEOUT)
    kw.setdefault("heartbeat_interval", 0.05)
    kw.setdefault("sim_tokens_per_step", 2)
    kw.setdefault("sim_step_delay_s", 0.01)
    kw.setdefault("timeout", 90.0)
    return MultiHostSupervisor(nranks, backend="sim", **kw)


# ---------------------------------------------------------------- agreement
def test_agree_round_decisions():
    # a higher agreed epoch always wins, before any close consideration
    assert agree_round(0, 3, 2) == AgreeDecision("reconfigure", 3)
    assert agree_round(5, 3, 2) == AgreeDecision("reconfigure", 3)
    # drained + agreement settled: close (or hold while a join is pending)
    assert agree_round(0, 2, 2) == AgreeDecision("close", 2)
    assert agree_round(0, 2, 2, hold_close=True) == AgreeDecision("hold", 2)
    # work remaining on the agreed epoch: keep serving
    assert agree_round(4, 2, 2) == AgreeDecision("continue", 2)


# -------------------------------------------------------------- construction
def test_supervisor_validates_eagerly():
    with pytest.raises(ValueError):
        MultiHostSupervisor(1)                       # needs >= 2 workers
    with pytest.raises(ValueError):
        MultiHostSupervisor(3, backend="gpu")        # unknown backend
    with pytest.raises(ValueError):
        MultiHostSupervisor(3, suspect_timeout=0.0)  # detector params
    with pytest.raises(ValueError):
        MultiHostSupervisor(3, evict_factor=3.0)


def test_rejects_device_fault_kinds():
    sup = sim_supervisor()
    with pytest.raises(ValueError, match="host faults"):
        sup.serve(mk_requests(2), faults=FaultSchedule(
            [FaultSpec(step=1, kind="kill", rank=0)]))


# ------------------------------------------------------------------ clean run
def test_clean_run_is_bit_exact_and_stable():
    reqs = mk_requests()
    res = sim_supervisor(trace=True).serve(reqs)
    assert sorted(res.responses) == [r.id for r in reqs]
    assert all(r.ok for r in res.responses.values())
    oracle = sim_oracle(reqs)
    for rid, resp in res.responses.items():
        assert tuple(resp.tokens) == oracle[rid]
    assert res.evicted == () and res.suspected == () and res.rerouted == ()
    assert res.epoch == 0
    assert not validate(res.trace())


# --------------------------------------------------------- SIGKILL: the story
def test_sigkill_detect_map_repair_zero_drop():
    """The tentpole contract end to end: SIGKILL a worker process
    mid-decode; survivors keep retiring during detection; the dead host is
    suspected, evicted within the latency bound, membership repaired through
    an epoch shrink, outstanding work re-routed from the WAL — zero drops,
    every stream bit-exact."""
    reqs = mk_staggered()
    sup = sim_supervisor(trace=True)
    res = sup.serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=3, kind="host_kill", rank=2)]))

    # zero drops, bit-exact
    assert sorted(res.responses) == [r.id for r in reqs]
    assert all(r.ok for r in res.responses.values())
    oracle = sim_oracle(reqs)
    for rid, resp in res.responses.items():
        assert tuple(resp.tokens) == oracle[rid], (
            f"request {rid} diverged across the host loss")

    # detected + repaired
    assert res.evicted == (2,)
    assert res.rerouted, "nothing re-routed off the dead worker"
    assert res.epoch >= 1, "membership was never repaired"
    det = res.detection[2]
    assert det["suspect_ts"] > det["kill_ts"]
    assert det["evict_ts"] - det["kill_ts"] <= 2 * SUSPECT_TIMEOUT, (
        "detection-to-evict exceeded the 2x suspect_timeout bound")

    # survivors never block: retirements land INSIDE the detection window
    in_window = [rid for (ts, rank, rid) in res.retires
                 if det["kill_ts"] < ts < det["evict_ts"] and rank != 2]
    assert in_window, ("no survivor retired a response between the kill and "
                       "the eviction — survivors blocked on the dead peer")

    # the trace tells the whole causal story and passes the post-mortem
    # rules (host_evict needs a preceding host_suspect + a following epoch
    # that excludes the dead rank)
    trace = res.trace()
    names = {e.get("name") for e in trace["traceEvents"]}
    assert {"host_kill", "host_suspect", "host_evict", "replica_kill",
            "ulfm_shrink", "reroute", "epoch", "rank_failed"} <= names, (
        f"causality chain incomplete: {sorted(names)}")
    assert not validate(trace)
    # RANK_FAILED was latched by the *survivors* (the mapped group word)
    latched = [e for e in trace["traceEvents"]
               if e.get("name") == "rank_failed"]
    assert latched and all(e["pid"] != 2 for e in latched)


def test_sigkill_with_wal_reroutes_durably(tmp_path):
    """The re-route across the process loss is WAL-backed: every request has
    a retire record, the dead worker's outstanding ones have route records
    onto survivors, and the replayed ledger agrees with the live outcome."""
    from repro.serve.ledger import replay as replay_ledger

    wal = str(tmp_path / "multihost.wal")
    reqs = mk_staggered()
    res = sim_supervisor(ledger_path=wal).serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=3, kind="host_kill", rank=1)]))
    assert sorted(res.responses) == [r.id for r in reqs]
    assert res.evicted == (1,)
    assert res.rerouted
    rep = replay_ledger(wal)
    assert sorted(rep.responses) == [r.id for r in reqs]
    assert rep.outstanding() == []
    assert rep.epoch >= 1
    assert 1 not in rep.members
    # the dead worker's outstanding requests were re-routed on the record:
    # their last known owner in the replayed WAL is a survivor
    for rid in res.rerouted:
        assert rep.routes[rid] != 1


# ------------------------------------------------- SIGSTOP: false positives
def test_sigstop_within_timeout_is_never_evicted():
    """The acceptance criterion's guard: a worker stopped for less than
    ``suspect_timeout`` and resumed must be suspected (the detector noticed)
    and cleared (the late beat proved liveness) but NEVER evicted — and the
    run stays zero-drop bit-exact."""
    reqs = mk_requests()
    res = sim_supervisor(trace=True).serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=2, kind="host_stop", rank=1,
                   magnitude=0.5 * SUSPECT_TIMEOUT)]))
    assert sorted(res.responses) == [r.id for r in reqs]
    oracle = sim_oracle(reqs)
    for rid, resp in res.responses.items():
        assert tuple(resp.tokens) == oracle[rid]
    assert res.stopped == (1,)
    assert res.evicted == (), (
        f"SIGSTOP under suspect_timeout evicted {res.evicted} — the "
        "slow-but-alive false-positive guard is broken")
    assert 1 in res.suspected and 1 in res.resumed
    assert res.epoch == 0, "membership changed without a death"
    trace = res.trace()
    names = {e.get("name") for e in trace["traceEvents"]}
    assert {"host_stop", "host_resume", "host_suspect",
            "host_suspect_clear"} <= names
    assert "host_evict" not in names
    assert not validate(trace)


def test_stop_then_kill_interleaving():
    """A stopped-and-resumed worker and a killed one on the same run: only
    the killed one is evicted, the resumed one finishes its share."""
    reqs = mk_requests()
    res = sim_supervisor(trace=True).serve(reqs, faults=FaultSchedule([
        FaultSpec(step=1, kind="host_stop", rank=0,
                  magnitude=0.4 * SUSPECT_TIMEOUT),
        FaultSpec(step=4, kind="host_kill", rank=2),
    ]))
    assert sorted(res.responses) == [r.id for r in reqs]
    oracle = sim_oracle(reqs)
    for rid, resp in res.responses.items():
        assert tuple(resp.tokens) == oracle[rid]
    assert res.evicted == (2,)
    assert res.stopped == (0,)
    assert 0 not in res.evicted
    assert not validate(res.trace())


# ------------------------------------------------------- real engine backend
@pytest.mark.slow
def test_replica_backend_bit_exact_across_process_kill():
    """The real engine across real process boundaries: every worker process
    rebuilds params from the shared PRNGKey, one is SIGKILL'd mid-decode,
    and the surviving streams stay token-bit-exact vs an in-process
    single-replica reference (proving param rebuild + eviction + re-route
    never leak into the model's token stream)."""
    import jax

    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serve import Replica

    arch = "qwen3-1.7b"
    engine = EngineConfig(num_slots=2, max_len=32)
    reqs = mk_requests(n=8, max_new=8)

    cfg = smoke_config(arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    ref_rep = Replica(cfg, params=params, config=engine)
    ref, steps = {}, 0
    for r in mk_requests(n=8, max_new=8):
        assert ref_rep.submit(r) is None
    while not ref_rep.idle():
        for resp in ref_rep.step():
            ref[resp.id] = resp
        steps += 1
        assert steps < 2000

    sup = MultiHostSupervisor(3, backend="replica", arch=arch, config=engine,
                              suspect_timeout=0.8, heartbeat_interval=0.05,
                              timeout=180.0)
    res = sup.serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=2, kind="host_kill", rank=1)]))
    assert sorted(res.responses) == [r.id for r in reqs]
    assert all(r.ok for r in res.responses.values())
    assert res.evicted == (1,)
    for rid, resp in res.responses.items():
        assert tuple(resp.tokens) == tuple(ref[rid].tokens), (
            f"request {rid} diverged from the in-process reference")
    det = res.detection[1]
    assert det["evict_ts"] - det["kill_ts"] <= 2 * 0.8


# ------------------------------------------------------------- entry points
def test_worker_script_exists_and_is_default_cmd():
    from repro.serve.multihost import _default_worker_cmd

    cmd = _default_worker_cmd()
    assert cmd[-1].endswith(("worker.py", "repro.serve.multihost"))
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    assert os.path.exists(os.path.join(here, "scripts", "worker.py"))
