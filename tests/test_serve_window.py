"""Zero-sync decode windows: token-identical to the stepwise path on clean
traffic, bit-exact LFLR recovery from mid-window faults, EOS/budget boundary
handling (trailing tokens discarded, lanes backfilled), and the host-sync
budget (syncs scale with steps / K, not steps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import count_syncs

from repro.configs import smoke_config
from repro.core.errors import ErrorCode
from repro.launch.steps import PerfOptions, make_cache_prefill
from repro.models import build_model
from repro.serve import FAILED, OK, EngineConfig, Replica, Request, ServeGroup
from repro.serve.replica import SERVE_PROBES

MAX_LEN = 64


@pytest.fixture(scope="module")
def env():
    cfg = smoke_config("recurrentgemma-2b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _replica(env, window, **kw):
    cfg, params = env
    conf = {k: kw.pop(k) for k in list(kw) if k in EngineConfig.__dataclass_fields__}
    conf.setdefault("num_slots", 2)
    conf.setdefault("max_len", MAX_LEN)
    return Replica(cfg, params=params,
                   config=EngineConfig(window=window, **conf), **kw)


def _requests(n, max_new=12):
    return [Request(id=i, prompt=(10 + i, 20 + i, 30 + i),
                    max_new_tokens=max_new) for i in range(n)]


def _serve_all(rep, reqs, inject_at=None):
    for r in reqs:
        assert rep.submit(r) is None
    out, steps = [], 0
    while not rep.idle():
        if inject_at is not None and steps == inject_at:
            assert rep.inject_state_fault(0) == 0
        out.extend(rep.step())
        steps += 1
        assert steps < 1000
    return {r.id: r for r in out}


# ------------------------------------------------------------- clean traffic
def test_window_decode_token_identical_to_stepwise(env):
    """The K-step on-device scan must reproduce the per-token path exactly,
    including backfill chains (5 requests over 2 slots)."""
    clean = _serve_all(_replica(env, 0), _requests(5))
    for K in (1, 4, 8):
        rep = _replica(env, K)
        got = _serve_all(rep, _requests(5))
        assert sorted(got) == sorted(clean)
        for i in clean:
            assert got[i].status == OK
            assert got[i].tokens == clean[i].tokens, (K, i)
        m = rep.metrics
        assert m.windows > 0
        # every committed decode token came through a window, none per-token
        assert m.decode_tokens == sum(len(r.tokens) for r in got.values())


def test_window_perf_options_knobs():
    perf = PerfOptions.parse("window=8,donate=1")
    assert perf.window == 8 and perf.donate is True
    assert PerfOptions.parse("win=4,donate=0") == PerfOptions(
        window=4, donate=False)
    assert PerfOptions().window == 0        # stepwise default


def test_fused_prefill_matches_loop_prefill(env):
    """The fori_loop-fused prefill (window mode's admission/LFLR path) must
    be bit-identical to the PR-1 per-token loop across lengths."""
    cfg, params = env
    loop = make_cache_prefill(cfg, SERVE_PROBES)
    fused = make_cache_prefill(cfg, SERVE_PROBES, fused=True)
    for prompt in [(11, 22, 33), (5, 6, 7, 8), (3,) * 7,
                   tuple(range(1, 14))]:
        toks = np.asarray([prompt], np.int32)
        l1, c1, w1 = loop(params, toks, MAX_LEN)
        l2, c2, w2 = fused(params, toks, MAX_LEN)
        assert int(w1) == int(w2) == 0
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        for a, b in zip(jax.tree_util.tree_leaves(c1),
                        jax.tree_util.tree_leaves(c2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------- faults
@pytest.mark.parametrize("inject_at", [1, 2, 3])
def test_midwindow_fault_recovers_exact_trajectory(env, inject_at):
    """A STATE_FAULT latched mid-window is attributed to its exact (step,
    slot) at the boundary; LFLR re-prefill replays greedy from the last
    committed token, so the final trajectory is the fault-free one."""
    clean = _serve_all(_replica(env, 0), _requests(2, max_new=14))
    rep = _replica(env, 4)
    faulty = _serve_all(rep, _requests(2, max_new=14), inject_at=inject_at)
    assert faulty[0].status == OK and faulty[0].retries == 1
    assert faulty[0].tokens == clean[0].tokens
    # deferred detection is still per-sequence: the co-batched lane committed
    # its whole window and never noticed
    assert faulty[1].status == OK and faulty[1].retries == 0
    assert faulty[1].tokens == clean[1].tokens
    assert rep.metrics.fault_counts().get("STATE_FAULT") == 1


def test_persistent_fault_evicts_without_stale_refault(env):
    """A lane that re-faults on every window is answered FAILED after the
    retry budget — and the eviction also invalidates the lane in the in-flight
    speculative window, so the already-computed stale fault is not recorded a
    second time (which would spuriously escalate the policy toward ROLLBACK)."""
    rep = _replica(env, 4, num_slots=2)
    real_win = rep._decode_window

    def cursed(params, caches, tokens, pos, *chunk_args):
        toks, words, next_tok, caches = real_win(params, caches, tokens, pos,
                                                 *chunk_args)
        words = words.at[1, 0].set(
            words[1, 0] | jnp.uint32(int(ErrorCode.STATE_FAULT)))
        return toks, words, next_tok, caches

    rep._decode_window = cursed
    out = _serve_all(rep, _requests(2, max_new=16))
    assert out[0].status == FAILED and out[0].retries == 3
    assert out[1].status == OK and len(out[1].tokens) == 16
    # 3 real faults (one per LFLR retry); the stale speculative windows —
    # both the mid-recovery ones and the post-eviction one — record nothing
    assert len(rep.metrics.faults) == 3, rep.metrics.faults


def test_window_group_kill_zero_dropped_requests(env):
    """The PR-1 fault contract survives the window engine: a replica kill
    mid-serve shrinks the group and re-routes — zero dropped requests."""
    from repro.core.faults import FaultSchedule, FaultSpec

    cfg, _ = env
    group = ServeGroup(cfg, 3, config=EngineConfig(num_slots=2,
                                                   max_len=MAX_LEN, window=4))
    reqs = [Request(id=i, prompt=(5 + i, 6 + i, 7 + i), max_new_tokens=6)
            for i in range(9)]
    res = group.serve(reqs, faults=FaultSchedule(
        [FaultSpec(step=2, kind="kill", rank=1)]))
    assert [r.rank for r in res.reports if r.killed] == [1]
    assert sorted(res.responses) == list(range(9))
    assert all(r.ok for r in res.responses.values())
    assert {r.replica for r in res.responses.values()} <= {0, 2}


# ------------------------------------------------------- window boundaries
def test_eos_midwindow_discards_trailing_and_backfills(env):
    """EOS inside a window: the lane commits up to EOS, the over-decoded
    trailing tokens are discarded, and the freed slot is backfilled at the
    boundary. (Blocking engine: the injected EOS step index assumes the
    window carries no prompt chunk; the overlapped equivalent lives in
    test_serve_overlap.py.)"""
    rep = _replica(env, 4, num_slots=2, eos_id=777, overlap=False)
    real_win = rep._decode_window
    fired = []

    def eos_at_step1(params, caches, tokens, pos, *chunk_args):
        toks, words, next_tok, caches = real_win(params, caches, tokens, pos,
                                                 *chunk_args)
        if not fired:           # first dispatched window only
            fired.append(True)
            toks = toks.at[1, 0].set(777)   # slot 0 emits EOS at step 1
        return toks, words, next_tok, caches

    rep._decode_window = eos_at_step1
    out = _serve_all(rep, _requests(3, max_new=12))
    assert sorted(out) == [0, 1, 2]
    # slot 0's request: prefill token + window step 0 + EOS, trailing dropped
    assert out[0].status == OK
    assert out[0].tokens[-1] == 777 and len(out[0].tokens) == 3
    assert rep.metrics.discarded_tokens > 0
    # the freed lane was backfilled: the queued request completed in full
    assert out[2].status == OK and len(out[2].tokens) == 12
    # co-batched lane unaffected
    assert out[1].status == OK and len(out[1].tokens) == 12


def test_budget_finish_midwindow_discards_trailing(env):
    """max_new_tokens not divisible by K: the finishing window commits only
    the remaining budget and discards the over-decoded tail."""
    rep = _replica(env, 8, num_slots=1)
    out = _serve_all(rep, _requests(1, max_new=10))
    assert out[0].status == OK and len(out[0].tokens) == 10
    assert rep.metrics.discarded_tokens > 0


# ---------------------------------------------------------- host-sync budget
def test_host_sync_budget_scales_with_steps_over_K(env, monkeypatch):
    """Regression fence for the zero-sync contract: a serve run's host syncs
    must scale with ``steps / K`` (+ one-off prefills), not with ``steps`` —
    a future edit that sneaks a per-token readback back in fails this."""
    reqs = lambda: _requests(4, max_new=16)  # noqa: E731

    def run(window):
        rep = _replica(env, window, num_slots=4)
        return rep, _serve_all(rep, reqs())

    # warm the compiles outside the counted region
    run(8), run(4), run(0)
    syncs = {}
    for K in (0, 4, 8):
        syncs[K], (rep, out) = count_syncs(monkeypatch, lambda: run(K))
        assert all(r.status == OK for r in out.values())
        if K:
            m = rep.metrics
            # ≤ 2 syncs per retired window (word + token block) and ≤ 2 per
            # prefill (word + first-token argmax), plus slack for jit-internal
            # transfers — nothing may scale per token.
            assert syncs[K] <= 2 * m.windows + 2 * m.prefills + 4, (
                K, syncs[K], m.windows, m.prefills)
    # bigger windows → strictly fewer syncs; stepwise pays per token
    assert syncs[8] < syncs[4] < syncs[0], syncs
