"""Stall-free serving: chunked prefill fused into decode windows.

The overlapped engine (``Replica(window=K, overlap=True)``) must be
bit-exact vs the blocking engine while never stalling the host: admission and
LFLR recovery ride the fused decode+prefill window as background lanes
(``make_prefill_decode_window``), a fault mid-chunk re-queues the lane without
blocking, host syncs stay O(steps / K) even with a lane active, and the TTFT
of a late-admitted request is bounded by its chunk windows — not by a
blocking full-prompt prefill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import count_syncs

from repro.configs import smoke_config
from repro.core.device_channel import DeviceFuture
from repro.launch.steps import (
    PerfOptions,
    make_cache_prefill,
    make_chunked_prefill,
)
from repro.models import build_model
from repro.serve import (
    EXPIRED,
    OK,
    AdmissionPolicy,
    ContinuousBatchingScheduler,
    EngineConfig,
    Replica,
    Request,
    RequestQueue,
)
from repro.serve.replica import SERVE_PROBES

MAX_LEN = 64


@pytest.fixture(scope="module")
def env():
    cfg = smoke_config("recurrentgemma-2b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _replica(env, window, **kw):
    cfg, params = env
    conf = {k: kw.pop(k) for k in list(kw) if k in EngineConfig.__dataclass_fields__}
    conf.setdefault("num_slots", 2)
    conf.setdefault("max_len", MAX_LEN)
    return Replica(cfg, params=params,
                   config=EngineConfig(window=window, **conf), **kw)


def _requests(n, max_new=12, prompt_len=3):
    return [Request(id=i, prompt=tuple(10 + i + j for j in range(prompt_len)),
                    max_new_tokens=max_new) for i in range(n)]


def _serve_all(rep, reqs, inject_at=None, inject_slot=0):
    for r in reqs:
        assert rep.submit(r) is None
    out, steps = {}, 0
    while not rep.idle():
        if inject_at is not None and steps == inject_at:
            assert rep.inject_state_fault(inject_slot) == inject_slot
        for resp in rep.step():
            out[resp.id] = resp
        steps += 1
        assert steps < 1000
    return out


# ---------------------------------------------------------------- bit-exactness
@pytest.mark.parametrize("prompt_len", [3, 11])
def test_overlap_token_identical_to_blocking(env, prompt_len):
    """Chunked prefill fused into the window must reproduce the blocking
    engine's token streams exactly — including prompts longer than K (multi-
    window chunking) and backfill chains (5 requests over 2 slots) — while
    never calling the blocking prefill at all."""
    blocking = _serve_all(_replica(env, 4, overlap=False),
                          _requests(5, prompt_len=prompt_len))
    for K in (1, 4, 8):
        rep = _replica(env, K, overlap=True)
        got = _serve_all(rep, _requests(5, prompt_len=prompt_len))
        assert sorted(got) == sorted(blocking)
        for i in blocking:
            assert got[i].status == OK
            assert got[i].tokens == blocking[i].tokens, (K, i)
        m = rep.metrics.summary()
        # the stall-free contract: zero blocking prefills, zero host stalls,
        # every prompt token fed through a fused chunk
        assert m["prefills"] == 0 and m["host_stalls"] == 0
        assert m["prefill_chunk_tokens"] == 5 * prompt_len
        assert m["decode_tokens"] == sum(len(r.tokens) for r in got.values())


def test_chunked_prefill_chain_matches_full_prefill(env):
    """make_chunked_prefill chained over an existing cache is bit-identical
    to the one-shot fused prefill — the property that makes a prefill split
    across decode windows reproduce the synchronous trajectory exactly."""
    cfg, params = env
    full = make_cache_prefill(cfg, SERVE_PROBES, fused=True)
    model = build_model(cfg)
    for C, prompt in [(4, tuple(range(3, 14))), (5, (7, 8, 9)),
                      (3, tuple(range(2, 8)))]:
        chunked = make_chunked_prefill(cfg, SERVE_PROBES, chunk=C)
        l_ref, c_ref, w_ref = full(params, np.asarray([prompt], np.int32),
                                   MAX_LEN)
        cache = model.init_cache(1, MAX_LEN)
        word = jnp.uint32(0)
        logits = None
        for lo in range(0, len(prompt), C):
            part = prompt[lo:lo + C]
            padded = np.zeros((1, C), np.int32)
            padded[0, :len(part)] = part
            logits, cache, w = chunked(params, cache, padded,
                                       jnp.int32(len(part)), jnp.int32(lo))
            word = word | w
        assert int(word) == int(w_ref) == 0
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(l_ref))
        for a, b in zip(jax.tree_util.tree_leaves(cache),
                        jax.tree_util.tree_leaves(c_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_overlap_perf_options_knob():
    assert PerfOptions.parse("window=8,overlap=1").overlap is True
    assert PerfOptions.parse("window=8,overlap=0") == PerfOptions(
        window=8, overlap=False)
    assert PerfOptions().overlap is True


# --------------------------------------------------------------------- faults
@pytest.mark.parametrize("inject_at", [1, 2])
def test_fault_mid_chunked_prefill_recovers_without_stall(env, inject_at):
    """A STATE_FAULT latched while a lane is mid-chunked-prefill (12-token
    prompt over K=4 → three chunk windows) re-queues the lane from position 0
    and replays to the exact clean trajectory; the co-batched slot never
    stalls (its stream is bit-identical) and the host never blocks."""
    reqs = lambda: [Request(id=0, prompt=(3, 5, 7), max_new_tokens=14),  # noqa: E731
                    Request(id=1, prompt=tuple(range(20, 32)),
                            max_new_tokens=8)]
    clean = _serve_all(_replica(env, 4, overlap=False), reqs())
    rep = _replica(env, 4, overlap=True)
    got = _serve_all(rep, reqs(), inject_at=inject_at, inject_slot=1)
    assert got[1].status == OK and got[1].retries == 1
    assert got[1].tokens == clean[1].tokens
    assert got[0].status == OK and got[0].retries == 0
    assert got[0].tokens == clean[0].tokens
    m = rep.metrics.summary()
    assert m["prefills"] == 0 and m["host_stalls"] == 0
    assert rep.metrics.fault_counts().get("STATE_FAULT") == 1


def test_eos_midwindow_overlap_discards_trailing_and_backfills(env):
    """Overlapped engine window boundaries: EOS emitted in the same window a
    lane flips from prefill to decode commits up to EOS and discards the
    rest; the freed slot is backfilled with a fresh lane."""
    rep = _replica(env, 4, num_slots=2, eos_id=777)
    real_win = rep._decode_window
    fired = []

    def eos_late(params, caches, tokens, pos, chunk, rem):
        toks, words, nxt, caches = real_win(params, caches, tokens, pos,
                                            chunk, rem)
        if not fired:           # first dispatched window only
            fired.append(True)
            toks = toks.at[3, 0].set(777)   # step 3 ≥ flip step (rem-1 = 2)
        return toks, words, nxt, caches

    rep._decode_window = eos_late
    out = _serve_all(rep, _requests(3, max_new=12))
    assert sorted(out) == [0, 1, 2]
    # slot 0: prompt chunk fed steps 0-2, flip at step 2, EOS at step 3
    assert out[0].status == OK
    assert out[0].tokens[-1] == 777 and len(out[0].tokens) == 2
    # freed lane backfilled; co-batched lane unaffected
    assert out[2].status == OK and len(out[2].tokens) == 12
    assert out[1].status == OK and len(out[1].tokens) == 12


def test_deadline_expiry_mid_prefill_lane(env):
    """A lane whose deadline passes mid-chunked-prefill is evicted EXPIRED at
    the next boundary — a half-built lane can never wedge the replica."""
    t = [0.0]
    rep = _replica(env, 4, overlap=True, clock=lambda: t[0])
    assert rep.submit(Request(id=0, prompt=tuple(range(30, 42)),
                              max_new_tokens=8, deadline=0.5)) is None
    assert rep.submit(Request(id=1, prompt=(4, 5, 6),
                              max_new_tokens=6)) is None
    out = {}
    steps = 0
    while not rep.idle():
        for resp in rep.step():
            out[resp.id] = resp
        t[0] += 0.3             # deadline passes after the first chunk window
        steps += 1
        assert steps < 200
    assert out[0].status == EXPIRED
    assert out[1].status == OK and len(out[1].tokens) == 6


# ------------------------------------------------------------ host-sync budget
def test_host_sync_budget_with_lane_active(env, monkeypatch):
    """Host syncs stay O(steps / K) *while lanes are prefilling*: admission
    and recovery cost zero syncs and zero stalls on the overlapped engine,
    while the blocking engine pays ≥ 2 syncs and one host stall per prefill
    on the identical workload."""
    reqs = lambda: _requests(6, max_new=12, prompt_len=9)  # noqa: E731

    def run(overlap):
        rep = _replica(env, 4, num_slots=2, overlap=overlap)
        return rep, _serve_all(rep, reqs())

    run(True), run(False)       # warm both engines' compiles
    syncs_over, (rep_o, out_o) = count_syncs(monkeypatch, lambda: run(True))
    syncs_block, (rep_b, out_b) = count_syncs(monkeypatch, lambda: run(False))
    assert all(r.status == OK for r in out_o.values())
    for i in out_b:
        assert out_o[i].tokens == out_b[i].tokens
    m = rep_o.metrics
    # overlapped: ≤ 2 syncs per retired window (word + token block) + slack;
    # nothing scales with admission count — lanes are free of host round
    # trips and the host never stalls
    assert m.prefills == 0 and m.host_stalls == 0
    assert syncs_over <= 2 * m.windows + 4, (syncs_over, m.windows)
    # blocking: the same traffic pays per-prefill syncs (word + first-token
    # argmax) and a host stall per admission on top of its window syncs
    mb = rep_b.metrics
    assert mb.prefills == 6 and mb.host_stalls == 6
    assert syncs_block >= 2 * mb.windows + 2 * mb.prefills, (
        syncs_block, mb.windows, mb.prefills)


# ----------------------------------------------------------------------- TTFT
def test_late_admission_ttft_bounded_and_non_interfering(env):
    """A request admitted mid-stream gets its first token within its chunk
    windows + pipeline depth (here: prompt ≤ K → 3 scheduler steps), and the
    already-decoding slot's trajectory is bit-exact vs an undisturbed run —
    admission never stalls or perturbs the healthy lanes."""
    cfg, params = env

    def run(admit_late):
        rep = _replica(env, 4, num_slots=2, overlap=True)
        assert rep.submit(Request(id=0, prompt=(9, 8, 7),
                                  max_new_tokens=20)) is None
        out, late_at, late_done = {}, None, None
        steps = 0
        while not rep.idle():
            if admit_late and steps == 3:
                assert rep.submit(Request(id=1, prompt=(40, 41, 42),
                                          max_new_tokens=1)) is None
                late_at = steps
            for resp in rep.step():
                out[resp.id] = resp
                if resp.id == 1:
                    late_done = steps
            steps += 1
            assert steps < 500
        return rep, out, late_at, late_done

    _, alone, _, _ = run(False)
    rep, both, late_at, late_done = run(True)
    assert both[0].tokens == alone[0].tokens          # non-interference
    assert both[1].status == OK and len(both[1].tokens) == 1
    # chunk rides the next dispatched window; its flip token retires one
    # window later (double-buffered pipeline) — never a blocking prefill
    assert late_done - late_at <= 3, (late_at, late_done)
    assert rep.metrics.summary()["host_stalls"] == 0


# ------------------------------------------------------------- window planning
def test_prefill_budget_staggers_lane_starts():
    """The per-window token budget splits decode steps vs prefill chunks:
    fresh lanes start oldest-first within the budget, an in-progress lane
    always continues (no-park invariant), and liveness overrides the budget
    when nothing else can make progress."""
    q = RequestQueue(AdmissionPolicy(max_total_len=64))
    sched = ContinuousBatchingScheduler(3, q, prefill_budget=4)
    for i in range(3):
        assert q.submit(Request(id=i, prompt=tuple(range(8 + i, 14 + i)),
                                max_new_tokens=4)) is None
    admitted = sched.backfill()
    assert [slot for slot, _ in admitted] == [0, 1, 2]
    for slot, _ in admitted:
        sched.begin_prefill(slot)

    plan = sched.plan_prefill(window=4)
    # budget 4 = one chunk: oldest lane starts (liveness would force it
    # anyway), the other two defer with rem=0
    assert plan[0].rem == 4 and plan[0].fresh and not plan[0].exhausts
    assert plan[1].rem == 0 and plan[2].rem == 0
    assert plan[0].tokens == tuple(range(8, 12))

    plan = sched.plan_prefill(window=4)
    # in-progress lane 0 continues first (2 remaining of its 6-token prompt)
    # and exhausts; the leftover budget (2) cannot cover lane 1's first chunk
    # (4), so fresh lanes keep deferring — full-chunk-or-defer
    assert plan[0].rem == 2 and plan[0].exhausts and not plan[0].fresh
    assert sched.slots[0].pending is None             # flipped to decoding
    assert plan[1].rem == 0 and plan[2].rem == 0

    plan = sched.plan_prefill(window=4)
    assert plan[1].rem == 4 and plan[1].fresh         # full budget again
    assert plan[2].rem == 0                           # 4-4=0 left, defers
    plan = sched.plan_prefill(window=4)
    assert plan[1].rem == 2 and plan[1].exhausts
    assert plan[2].rem == 0                           # 2 left < 4 first chunk
    plan = sched.plan_prefill(window=4)
    assert plan[2].rem == 4 and plan[2].fresh
    plan = sched.plan_prefill(window=4)
    assert plan[2].rem == 2 and plan[2].exhausts
    assert sched.plan_prefill(window=4) == {}         # all lanes flipped


def test_prefill_budget_below_window_cannot_starve():
    """A budget smaller than one window could never cover any first chunk
    (full-chunk-or-defer), so a fresh lane would defer forever while another
    slot decodes — the effective budget is clamped to ≥ window instead."""
    q = RequestQueue(AdmissionPolicy(max_total_len=64))
    sched = ContinuousBatchingScheduler(2, q, prefill_budget=2)
    for i in range(2):
        assert q.submit(Request(id=i, prompt=tuple(range(8, 14)),
                                max_new_tokens=4)) is None
    for slot, _ in sched.backfill():
        sched.begin_prefill(slot)
    # flip lane 0 to decoding so the liveness override alone cannot save
    # lane 1 — only the clamp admits it
    for _ in range(2):
        sched.plan_prefill(window=4)
    assert sched.slots[0].pending is None
    plan = sched.plan_prefill(window=4)
    assert plan[1].rem == 4 and plan[1].fresh         # started, not starved
    plan = sched.plan_prefill(window=4)
    assert plan[1].rem == 2 and plan[1].exhausts


def test_device_future_done_is_nonblocking_probe():
    fut = DeviceFuture(outputs=jnp.arange(4), word=jnp.uint32(0))
    jax.block_until_ready(fut.word)
    assert fut.done()
    fut.wait()
    assert fut.done()
