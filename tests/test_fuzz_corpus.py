"""Replay the promoted fuzz corpus as deterministic regression tests.

Every entry under ``tests/fuzz_corpus/`` is a self-contained, seeded
trajectory (see DESIGN.md §3.6). ``seed``/``regression`` entries must
replay clean — zero oracle violations and a bit-identical outcome digest.
``counterexample`` entries (promoted by a fuzz campaign for a then-live
bug) must keep *reproducing* their violations; when a fix lands, this test
fails on them — flip the entry's status to ``regression`` and refresh its
digest to pin the fix.
"""
import pathlib

import pytest

from repro.fuzz import load_entry, run_trajectory

CORPUS_DIR = pathlib.Path(__file__).parent / "fuzz_corpus"
ENTRIES = sorted(CORPUS_DIR.glob("*.json"))


def test_corpus_is_seeded():
    """The repo ships a non-empty corpus: campaigns promote into it and CI
    replays it — an empty directory means promotion broke."""
    assert ENTRIES, f"no corpus entries under {CORPUS_DIR}"


@pytest.mark.parametrize("path", ENTRIES, ids=lambda p: p.stem)
def test_corpus_entry_replays(path):
    entry = load_entry(str(path))
    res = run_trajectory(entry["trajectory"])
    if entry["status"] == "counterexample":
        assert res.failed, (
            f"{path.name}: the recorded bug no longer reproduces — if a fix "
            "landed, flip the entry's status to 'regression' and set its "
            "digest to the new outcome")
        return
    assert entry["status"] in ("seed", "regression"), entry["status"]
    assert res.violations == [], (
        f"{path.name}: corpus replay violated the oracles: {res.violations}")
    if entry.get("digest"):
        assert res.digest() == entry["digest"], (
            f"{path.name}: outcome digest drifted — the replay is no longer "
            "bit-for-bit (got {0}, recorded {1})".format(res.digest(),
                                                         entry["digest"]))
